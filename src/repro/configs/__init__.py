"""repro.configs subpackage."""
