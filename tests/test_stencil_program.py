"""Per-op compiled programs: the StencilOp registry end-to-end.

ISSUE 5 tentpole coverage: `compile()` works over REGISTERED stencil ops —
hdiff-only and vadvc-only programs are first-class, their plans carry the
op's declared footprint, `trace_stats.assert_plan_structure` verifies the
traced round for all three ops, and the per-op outputs match their
`ref.py` oracles (hdiff BIT-exactly — the Pallas variants and the stacked
oracle lower to identical arithmetic; vadvc to 1 ulp, its kernel runs the
step-by-step COSMO sweep while the jnp oracle runs the vectorized one —
plus the solver-independent tridiagonal-residual property).

Runs clean under `python -W error::DeprecationWarning` (no legacy shims
left to warn)."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import autotune, memmodel, tiling, trace_stats
from repro.kernels.hdiff import ops as hdiff_ops
from repro.kernels.vadvc import ops as vadvc_ops
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather import dycore, fields
from repro.weather.program import (StencilProgram, compile,
                                   get_stencil_op, register_stencil_op,
                                   registered_stencil_ops)

GRID = (4, 12, 16)


def _plan(op, variant="auto", k_steps=1, grid=GRID, ensemble=2, **kw):
    return compile(StencilProgram(grid_shape=grid, ensemble=ensemble,
                                  op=op, variant=variant, k_steps=k_steps),
                   **kw)


def _state(grid=GRID, ensemble=2, seed=0):
    return fields.initial_state(jax.random.PRNGKey(seed), grid,
                                ensemble=ensemble)


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------


def test_registry_has_the_papers_ops():
    """The three first-class workloads are registered; compile() accepts
    each (the acceptance criterion's 'at least three registered ops')."""
    assert {"dycore", "hdiff", "vadvc"} <= set(registered_stencil_ops())
    for op in ("dycore", "hdiff", "vadvc"):
        plan = _plan(op)
        assert plan.pallas_calls_per_round == 1      # whole_state default
        rep = plan.report()
        assert rep["op"] == op
        fp = rep["footprint"]
        assert fp["op"] == op and fp["rides"], op
    with pytest.raises(KeyError):
        get_stencil_op("not-registered")


def test_footprint_declarations_match_the_math():
    """The registry declares the paper's footprints: hdiff a symmetric
    (2,2)/(2,2) per-field ride, vadvc ONLY wcon's right staggering column
    (the asymmetric (0,1) x-ride), the dycore all three field operands
    plus wcon's k-scaled ragged ride."""
    h = get_stencil_op("hdiff")
    assert h.halo == 2 and h.writes == ("fields",)
    assert h.resolved_rides(1) == (("fields", (2, 2), (2, 2)),)
    assert h.resolved_rides(3) == (("fields", (6, 6), (6, 6)),)

    v = get_stencil_op("vadvc")
    assert v.halo == 0 and v.writes == ("stage_tens",)
    assert v.resolved_rides(1) == (("wcon", (0, 0), (0, 1)),)

    d = get_stencil_op("dycore")
    rides = dict((r[0], r[1:]) for r in d.resolved_rides(2))
    assert rides["fields"] == ((4, 4), (4, 4))
    assert rides["wcon"] == ((4, 4), (4, 5))     # right-only +1, k-scaled
    # flops thread through to the k resolver / models
    assert (h.flops_per_point, v.flops_per_point, d.flops_per_point) == (
        21.0, 38.0, 61.0)


def test_per_op_validation():
    with pytest.raises(ValueError, match="k-step"):
        # vadvc's footprint does not deepen with k: no k-step round
        StencilProgram(grid_shape=GRID, op="vadvc", k_steps=2)
    with pytest.raises(ValueError, match="variant"):
        StencilProgram(grid_shape=GRID, op="vadvc", variant="kstep")
    with pytest.raises(ValueError, match="reach"):
        StencilProgram(grid_shape=GRID, op="vadvc", halo=2)
    # hdiff DOES have a k-step round (k launches on a deep halo)
    assert _plan("hdiff", variant="kstep", k_steps=2).k_steps == 2
    # ...but a halo deeper than the grid refuses at COMPILE time even on a
    # single chip (the wrap pad cannot span more than one period)
    with pytest.raises(ValueError, match="halo"):
        _plan("hdiff", variant="kstep", k_steps=5, grid=(4, 8, 8))


def test_unfused_per_op_reports_model_legal_tiles():
    """report() on oracle (unfused) per-op plans models traffic at a tile
    that is a LEGAL window of the physical grid — not of the padded or
    ensemble-folded compute grid the kernels tile over."""
    for op in ("hdiff", "vadvc"):
        rep = _plan(op, variant="unfused", grid=(16, 64, 64)).report()
        assert rep["tile"] is None
        assert 1 <= rep["traffic_model_ty"] <= 64
        assert 64 % rep["traffic_model_ty"] == 0
        assert rep["traffic"]["stream"] >= rep["traffic"]["ideal"] > 0


def test_registered_tile_spaces_and_snap_drift():
    """Satellite: the standalone hdiff/vadvc OpSpecs live in the autotune
    registry and their ops.plan_tile paths use the unified
    `tiling.snap_to_divisor` rule (largest divisor below the tuned
    extent) — no more private halving loops that drifted from
    `resolve_tile`."""
    assert autotune.get_op("hdiff") is tiling.HDIFF
    assert autotune.get_op("vadvc") is tiling.VADVC
    for ny in (8, 12, 14, 32, 96):
        ty = hdiff_ops.plan_tile((8, ny, 16), "float32")
        assert ny % ty == 0 and ty >= 2, (ny, ty)
    for ny, nx in ((8, 16), (12, 24), (6, 14)):
        tj, ti = vadvc_ops.plan_tile((8, ny, nx), "float32")
        assert ny % tj == 0 and nx % ti == 0, (ny, nx, tj, ti)
    assert tiling.snap_to_divisor(5, 16, lo=2) == 4
    assert tiling.snap_to_divisor(7, 12, lo=2) == 6
    assert tiling.snap_to_divisor(6, 7, lo=2) == 7   # prime: whole extent
    assert tiling.snap_to_divisor(24, 32, lo=1) == 16


# ---------------------------------------------------------------------------
# hdiff-only programs vs the ref.py oracle
# ---------------------------------------------------------------------------


def test_hdiff_plans_bit_match_reference():
    """Acceptance: hdiff-only plans match the reference kernel BIT-exactly
    — the unfused variant IS the ref.py composition, and the Pallas
    per-field/whole-state/kstep variants compute identical arithmetic on
    identically-assembled windows."""
    st = _state()
    ref = _plan("hdiff", variant="unfused").step(st)
    # the oracle variant against the hand-written periodic composition
    want = {n: dycore.hdiff_periodic(st.fields[n], 0.025)
            for n in fields.PROGNOSTIC}
    for n in fields.PROGNOSTIC:
        np.testing.assert_allclose(np.asarray(ref.fields[n]),
                                   np.asarray(want[n]), atol=1e-6)
        # tendencies pass through untouched (hdiff writes fields only)
        assert np.array_equal(np.asarray(ref.stage_tens[n]),
                              np.asarray(st.stage_tens[n]))
    for variant in ("per_field", "whole_state"):
        got = _plan("hdiff", variant=variant).step(st)
        for n in fields.PROGNOSTIC:
            assert np.array_equal(np.asarray(got.fields[n]),
                                  np.asarray(ref.fields[n])), (variant, n)


def test_hdiff_kstep_and_ragged_tail():
    """hdiff k-step rounds (ONE in-kernel launch on a k·2-deep wrap halo)
    equal k sequential whole-state steps bit-for-bit, including the ragged
    tail (5 steps on a k=2 plan = 2 rounds + a 1-step tail)."""
    st = _state(seed=3)
    seq = _plan("hdiff", variant="whole_state")
    kplan = _plan("hdiff", variant="kstep", k_steps=2)
    assert kplan.pallas_calls_per_round == 1         # in-kernel k-step round
    want = seq.run(st, 5)
    got = kplan.run(st, 5)
    for n in fields.PROGNOSTIC:
        assert np.array_equal(np.asarray(got.fields[n]),
                              np.asarray(want.fields[n])), n
    # steps == 0 is a no-op
    same = kplan.run(st, 0)
    assert np.array_equal(np.asarray(same.fields["t"]),
                          np.asarray(st.fields["t"]))


# ---------------------------------------------------------------------------
# vadvc-only programs vs the ref.py oracle
# ---------------------------------------------------------------------------


def test_vadvc_plans_match_reference():
    """vadvc-only plans update ONLY the stage tendencies: every variant
    matches the jnp oracle to 1 ulp (the Pallas kernel runs the
    step-by-step COSMO sweep, the oracle the vectorized formulation; even
    the oracle variant differs from the hand-vmapped helper only in XLA
    fusion order), and every variant leaves fields/tens untouched."""
    st = _state(seed=1)
    want = {n: dycore.vadvc_field(st.fields[n], st.wcon, st.fields[n],
                                  st.tens[n], st.stage_tens[n])
            for n in fields.PROGNOSTIC}
    ref = _plan("vadvc", variant="unfused").step(st)
    for n in fields.PROGNOSTIC:
        np.testing.assert_allclose(np.asarray(ref.stage_tens[n]),
                                   np.asarray(want[n]), atol=1e-6,
                                   err_msg=n)
    for variant in ("per_field", "whole_state"):
        got = _plan("vadvc", variant=variant).step(st)
        for n in fields.PROGNOSTIC:
            np.testing.assert_allclose(
                np.asarray(got.stage_tens[n]), np.asarray(want[n]),
                atol=1e-6, err_msg=f"{variant}/{n}")
            assert np.array_equal(np.asarray(got.fields[n]),
                                  np.asarray(st.fields[n])), (variant, n)


def test_vadvc_pallas_plan_solves_the_system():
    """Solver-independent property: the whole-state vadvc plan's output
    reconstructs x with A x = d (the implicit vertical discretization) —
    bit-level oracle agreement is not assumed, the algebra is checked."""
    st = _state(ensemble=1, seed=2)
    out = _plan("vadvc", variant="whole_state", ensemble=1).step(st)
    wcon_s = np.concatenate([np.asarray(st.wcon[0]),
                             np.asarray(st.wcon[0][..., :1])], axis=-1)
    for n in fields.PROGNOSTIC:
        res = vadvc_ref.tridiagonal_residual(
            np.asarray(st.fields[n][0]), wcon_s,
            np.asarray(st.fields[n][0]), np.asarray(st.tens[n][0]),
            np.asarray(st.stage_tens[n][0]),
            np.asarray(out.stage_tens[n][0], np.float64))
        assert res < 1e-4, (n, res)


# ---------------------------------------------------------------------------
# Footprint-driven models (memmodel satellites)
# ---------------------------------------------------------------------------


def test_packed_exchange_model_reproduces_dycore_cases():
    """The generic footprint-driven byte model IS the old hand-written
    dycore accounting: `kstep_exchange_model` (now a footprint wrapper)
    still produces the exact bytes, and the per-operand split is exposed."""
    for k in (1, 2, 4):
        m = memmodel.kstep_exchange_model((64, 256, 256), "float32",
                                          n_fields=4, k=k, shards=(2, 2))
        assert m["bytes_wcon"] == m["bytes_by_operand"]["wcon"]
        assert (m["bytes_by_operand"]["fields"] + m["bytes_wcon"]
                == m["bytes_kstep"])
        assert m["rounds_kstep"] == 2


def test_packed_exchange_model_vadvc_footprint():
    """vadvc's declared footprint — one right-only wcon column, nothing in
    y — models to a SINGLE active exchange round and exactly one column of
    wire bytes per shard."""
    op = get_stencil_op("vadvc")
    nz, ny, nx = 64, 256, 256
    m = memmodel.packed_exchange_model((nz, ny, nx), "float32",
                                       rides=op.memmodel_rides(4),
                                       k=1, shards=(2, 2),
                                       compute_halo=(0, 0))
    ly = ny // 2
    assert m["rounds_kstep"] == 1                    # x only, one side
    assert m["bytes_kstep"] == nz * 1 * ly * 4       # one fp32 column
    assert m["redundant_flops_frac"] == 0.0          # no halo-ring compute


def test_stencil_op_traffic_per_op_bounds():
    """Per-op traffic bounds derive from the registered OpSpecs: vadvc
    streams 8 field-sized arrays per field (7 in + 1 out), hdiff 2 plus
    its y/x halo re-reads — the per-kernel contrast the paper's table
    shows."""
    grid = (64, 256, 256)
    h = memmodel.stencil_op_traffic(autotune.get_op("hdiff"), grid,
                                    "float32", n_fields=4, tile=(1, 32, 256))
    v = memmodel.stencil_op_traffic(autotune.get_op("vadvc"), grid,
                                    "float32", n_fields=4,
                                    tile=(64, 32, 256))
    fb = 4 * int(np.prod(grid)) * 4                  # 4 fields, fp32
    assert v["ideal"] == 8 * fb
    assert h["ideal"] == 2 * fb
    assert h["stream"] >= h["ideal"]                 # halo re-reads
    assert v["stream"] >= v["ideal"]
    assert h["halo_overhead"] > 0.0
    assert v["flops_per_step"] < h["flops_per_step"] * 4  # 38 vs 21 per pt


# ---------------------------------------------------------------------------
# Distributed: report() == traced structure for ALL registered ops
# ---------------------------------------------------------------------------

_DIST_OPS_SNIPPET = r"""
import jax, numpy as np
from repro.core import trace_stats
from repro.weather import domain, fields
from repro.weather.program import StencilProgram, compile
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
grid = (4, 16, 16)
st = fields.initial_state(jax.random.PRNGKey(0), grid, ensemble=2)

def dist_plan(op, variant, k=1, **kwargs):
    return compile(StencilProgram(grid_shape=grid, ensemble=2, op=op,
                                  variant=variant, k_steps=k, **kwargs),
                   mesh=mesh)

# report() == traced structure for every variant of every registered op —
# the acceptance criterion: assert_plan_structure passes for all three.
cases = [("dycore", "kstep", 2), ("dycore", "whole_state", 1),
         ("hdiff", "whole_state", 1), ("hdiff", "per_field", 1),
         ("hdiff", "unfused", 1), ("hdiff", "kstep", 2),
         ("vadvc", "whole_state", 1), ("vadvc", "per_field", 1),
         ("vadvc", "unfused", 1)]
plans = {}
for op, variant, k in cases:
    plan = dist_plan(op, variant, k)
    trace_stats.assert_plan_structure(jax.make_jaxpr(plan.step)(st),
                                      plan.report())
    plans[(op, variant)] = plan

# vadvc's asymmetric wcon footprint: ONE collective (the right staggering
# column rides backward; the forward direction ships nothing and is
# elided), declared via the registry, visible in the schedule.
vrep = plans[("vadvc", "whole_state")].report()
assert vrep["collectives_per_round"] == 1, vrep["collectives_per_round"]
assert vrep["exchange"]["rides"]["wcon"]["depth_x"] == [0, 1]
assert vrep["exchange_model"]["rounds_kstep"] == 1

# hdiff rides all four collectives at the k-scaled symmetric depth
hrep = plans[("hdiff", "kstep")].report()
assert hrep["collectives_per_round"] == 4
assert hrep["exchange"]["rides"]["fields"]["depth_y"] == [4, 4]
assert hrep["pallas_calls_per_round"] == 1     # ONE launch, ONE exchange

# per-op distributed results == single-chip oracles
single = {op: compile(StencilProgram(grid_shape=grid, ensemble=2, op=op,
                                     variant="unfused"))
          for op in ("hdiff", "vadvc")}
sst = {}
for op, tgt in (("hdiff", "fields"), ("vadvc", "stage_tens")):
    want = single[op].step(st)
    for variant in ("whole_state", "per_field", "unfused"):
        plan = plans[(op, variant)]
        s = domain.shard_state(st, mesh, plan.state_spec)
        out = plan.step(s)
        for n in fields.PROGNOSTIC:
            err = np.abs(np.asarray(getattr(out, tgt)[n])
                         - np.asarray(getattr(want, tgt)[n])).max()
            assert err < 1e-6, (op, variant, n, err)
    sst[op] = domain.shard_state(st, mesh, plans[(op, "whole_state")]
                                 .state_spec)

# hdiff k-step round == 2 sequential exchanged rounds, and the ragged
# tail (3 steps on the k=2 plan) == 3 sequential rounds — bit-for-bit
seq = sst["hdiff"]
for _ in range(3):
    seq = plans[("hdiff", "whole_state")].step(seq)
got = plans[("hdiff", "kstep")].run(sst["hdiff"], 3)
for n in fields.PROGNOSTIC:
    assert np.array_equal(np.asarray(got.fields[n]),
                          np.asarray(seq.fields[n])), n

# bf16 wire policy works on per-op programs too (hdiff packs all variants)
bplan = dist_plan("hdiff", "whole_state", exchange_dtype="bfloat16")
assert bplan.report()["exchange"]["wire_dtype"] == "bfloat16"
trace_stats.assert_plan_structure(jax.make_jaxpr(bplan.step)(st),
                                  bplan.report())
outB = bplan.step(sst["hdiff"])
outF = plans[("hdiff", "whole_state")].step(sst["hdiff"])
errs = [np.abs(np.asarray(outB.fields[n]) - np.asarray(outF.fields[n])).max()
        for n in fields.PROGNOSTIC]
assert max(errs) < 0.1 and max(errs) > 0.0, errs   # cast confined to halo

# a too-deep hdiff k-step refuses loudly at compile time
try:
    dist_plan("hdiff", "kstep", 5)
except ValueError as e:
    assert "halo" in str(e), e
else:
    raise AssertionError("k=5 needs a 10-deep halo on an 8-row slab")
print("STENCIL_DIST_OK")
"""


def _run_forced_device_snippet(snippet: str, marker: str):
    """Run `snippet` in a subprocess with 4 forced host CPU devices."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, r.stderr[-2000:]


def test_distributed_per_op_plans_match_trace_and_oracles():
    """Forced-4-device subprocess: for every registered op and variant the
    plan's report() equals the traced launch/collective counts, vadvc's
    registry-declared (0,1) wcon ride costs exactly ONE collective, hdiff
    k-step rounds (and their ragged tails) are bit-equal to sequential
    exchanged rounds, and bf16 wire + compile-time halo validation work on
    per-op programs."""
    _run_forced_device_snippet(_DIST_OPS_SNIPPET, "STENCIL_DIST_OK")


def test_register_custom_op_compiles():
    """`register_stencil_op` admits a new operator without planner changes:
    a trivial copy op reusing the hdiff lowering hooks compiles, reports,
    and steps."""
    import dataclasses
    base = get_stencil_op("hdiff")
    op = dataclasses.replace(base, name="hdiff_copy",
                             title="registry smoke (hdiff clone)")
    register_stencil_op(op)
    try:
        st = _state()
        plan = _plan("hdiff_copy")
        out = plan.step(st)
        ref = _plan("hdiff").step(st)
        for n in fields.PROGNOSTIC:
            assert np.array_equal(np.asarray(out.fields[n]),
                                  np.asarray(ref.fields[n])), n
        assert plan.report()["op"] == "hdiff_copy"
    finally:
        from repro.weather.stencil_ops import STENCIL_OPS
        STENCIL_OPS.pop("hdiff_copy", None)
