"""TPU memory-hierarchy model — now a thin shim over `core.hwspec`.

NERO (the paper) builds an application-specific scratchpad hierarchy out of the
FPGA's heterogeneous memories (HBM -> URAM -> BRAM -> FF).  On TPU the same
levels exist but are fixed silicon: HBM -> VMEM (software-managed scratchpad)
-> VREG.  The numbers used to live here as literals; they are now loaded from
the versioned `src/repro/specs/tpu_v5e.json` hardware spec, and this module
keeps every historical name pointing at the same values so the tile planner,
perf model, autotuner, and roofline analysis (and any external caller) are
unaffected.  New code should take a `hwspec.HardwareSpec` instead — see
`core/hwspec.py` for POWER9 and NERO specs and the cross-machine model.
"""

from __future__ import annotations

import warnings
from typing import Dict

from repro.core import hwspec
from repro.core.hwspec import (  # noqa: F401  (re-exported compatibility API)
    Hierarchy,
    MemoryLevel,
    dtype_bytes,
)

_V5E = hwspec.load_spec("tpu_v5e")

# ---------------------------------------------------------------------------
# Per-chip hardware constants (TPU v5e), derived from the spec file.
# ---------------------------------------------------------------------------

PEAK_BF16_FLOPS = _V5E.peak_flops["bfloat16"]
PEAK_FP32_FLOPS = _V5E.peak_flops["float32"]
HBM_BYTES = _V5E.main.capacity_bytes
HBM_BW = _V5E.main.bandwidth_bytes_per_s
ICI_BW_PER_LINK = _V5E.collective.bandwidth_bytes_per_s
ICI_LINKS = _V5E.collective.links
VMEM_BYTES = _V5E.near_physical_bytes   # physical VMEM per core
VMEM_USABLE = _V5E.near.capacity_bytes  # budget the planner may claim
VMEM_BW = _V5E.near.bandwidth_bytes_per_s
VREG_BYTES = _V5E.reg.capacity_bytes
MXU_TILE = _V5E.layout["mxu_tile"]
VPU_LANES = _V5E.layout["vpu_lanes"]

# Energy model (pJ/byte moved, pJ/flop) — used by benchmarks/energy.py.
ENERGY_PJ_PER_BYTE: Dict[str, float] = {
    "hbm": _V5E.main.energy_pj_per_byte,
    "vmem": _V5E.near.energy_pj_per_byte,
    "vreg": _V5E.reg.energy_pj_per_byte,
    "ici": _V5E.collective.energy_pj_per_byte,
    "host": _V5E.host_energy_pj_per_byte,   # PCIe/host DMA, the OCAPI analogue
}
ENERGY_PJ_PER_FLOP_BF16 = _V5E.energy_pj_per_flop
CHIP_IDLE_WATTS = _V5E.idle_watts
CHIP_PEAK_WATTS = _V5E.peak_watts


def tpu_v5e() -> Hierarchy:
    return _V5E.hierarchy()


# The paper's POWER9 baseline used to live here as two stray literals; it is
# now the full `power9` hardware spec.  The old names still resolve (module
# `__getattr__`) but warn — use `hwspec.load_spec("power9")` instead.
_DEPRECATED = {
    "POWER9_PEAK_FLOPS": lambda: hwspec.load_spec("power9").peak_flops["float32"],
    "POWER9_DRAM_BW": lambda: hwspec.load_spec("power9").main.bandwidth_bytes_per_s,
}


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.core.hierarchy.{name} is deprecated; load the 'power9' "
            f"hardware spec via repro.core.hwspec.load_spec('power9') instead",
            DeprecationWarning, stacklevel=2)
        return _DEPRECATED[name]()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
