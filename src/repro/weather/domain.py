"""Distributed dycore: spatial domain decomposition + halo exchange.

This is NERO's scale-out story made real (paper §5: "HBM provides an
attractive solution for scale-out computation" with one memory channel per
PE): every chip owns an (ny/Py, nx/Px) slab of the horizontal domain in its
own HBM; the compound stencils run chip-locally out of VMEM; the only
communication is a circular halo exchange (`jax.lax.ppermute` over the mesh
axes).  Vertical columns are never split (vadvc's z dependency), matching
the paper's PE design.

With `fused=True, whole_state=True` (default) the communication is **one
stacked halo exchange**: every exchanged operand — all prognostic fields,
their slow tendencies, the stage tendencies, and the raw `wcon` — is
concatenated into a single (E, 3·nf+1, nz, ly, lx) tensor, so each
direction costs exactly one `ppermute` pair per round instead of one pair
per field per input.  The staggered velocity is then built *locally* from
the padded `wcon` (its wrapped last column is garbage, absorbed by one
extra column of x-halo), the single-launch whole-state Pallas kernel runs
on the padded slab, and the interior is cropped.  Wrap-around garbage from
the kernel's periodic windows only ever lands in the cropped ring, so the
same kernel serves both the periodic single-chip domain and the
halo-exchanged shard.

`k_steps > 1` is the **communication-avoiding multi-step** mode: the
stacked exchange is made `k·HALO` deep and the whole round — all k local
steps — runs as ONE Pallas launch (`fused_dycore_kstep_pallas`) whose
kernel body iterates the k steps with the prognostic state held in VMEM
scratch, then the interior is cropped — trading redundant halo-ring flops
for k× fewer collective rounds AND k× fewer launches/HBM state round-trips.
Each local step pollutes at most HALO cells inward from the pad edge, so
after k steps the garbage front has consumed exactly the pad and the
interior is untouched (fp32-rounding-identical to k sequential exchanged
steps).  `k_steps="auto"` picks k per (grid, mesh) from the exchange model
(`core/autotune.py::plan_k_steps`).

The stacked exchange is *ragged*: the 3·nf field operands ship at depth
`k·HALO` in both directions, while `wcon` — whose x-staggering needs one
extra column (`w[c] = wcon[c] + wcon[c+1]`) — ships at `k·HALO + 1` in x
ALONE, instead of forcing the whole stack one column deeper.  Both rides
share one flattened wire buffer per direction, so the collective count
stays at one `ppermute` pair per direction per round (4 total).  With
`exchange_dtype="bfloat16"` the wire buffer is cast to bf16 before the
`ppermute` pair and restored after — the paper's half-precision mode
applied to communication: half the wire bytes for bf16 rounding confined
to the halo ring.

`whole_state=False` keeps the per-field fused pipeline with per-operand
exchanges (the communication-granularity oracle); `fused=False` keeps the
original per-kernel composition.

Ensemble members ride the "pod" axis of the multi-pod mesh: weather centers
run ~50-member ensembles, which is exactly a data-parallel outer axis — see
docs/architecture.md ("Scale-out: domain decomposition and ensemble pods")
for a worked example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map

from repro.core import autotune
from repro.kernels.dycore_fused import ops as fused_ops
from repro.kernels.dycore_fused.fused import (fused_dycore_kstep_pallas,
                                              fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC, WeatherState
from repro.weather.dycore import HALO, _auto_interpret


def _exchange(f: jnp.ndarray, axis_name: str, n: int, halo: int,
              dim: int) -> jnp.ndarray:
    """Circular halo exchange along `dim` over mesh axis `axis_name`.

    Returns f extended by `halo` on both sides of `dim`.  With n == 1 this
    degenerates to periodic wrap-padding (no communication).  `halo` must
    not exceed the local extent (a deeper exchange would need neighbors-of-
    neighbors data — callers check and raise)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    lo = take(f, slice(0, halo))          # my first rows -> neighbor below
    hi = take(f, slice(-halo, None))      # my last rows  -> neighbor above
    if n == 1:
        top, bot = hi, lo
    else:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = jax.lax.ppermute(hi, axis_name, perm=fwd)   # from rank-1
        bot = jax.lax.ppermute(lo, axis_name, perm=bwd)   # from rank+1
    return jnp.concatenate([top, f, bot], axis=dim)


def _exchange_packed(parts, axis_name: str, n: int, dim: int,
                     wire_dtype=None):
    """Circular halo exchange along `dim` for several tensors with
    PER-TENSOR halo depths, packed into one flattened wire buffer per
    direction — exactly one `ppermute` pair regardless of operand count or
    depth raggedness.  This is how `wcon` ships its extra staggering column
    without forcing the whole stacked exchange one column deeper.

    `wire_dtype` (e.g. bf16) casts the packed buffer before the `ppermute`
    pair and restores each tensor's dtype on arrival — half the wire bytes,
    rounding confined to the received halo ring.

    `parts` is a sequence of `(tensor, depth)` with `depth >= 1`; returns
    the tensors extended by their own depth on both sides of `dim`.  With
    n == 1 this degenerates to periodic wrap-padding (no communication,
    no cast)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    for _, h in parts:
        if h < 1:
            raise ValueError(f"packed-exchange depth {h} must be >= 1")
    lo_parts = [take(t, slice(0, h)) for t, h in parts]
    hi_parts = [take(t, slice(-h, None)) for t, h in parts]
    if n == 1:
        top, bot = hi_parts, lo_parts
    else:
        def pack(xs):
            buf = jnp.concatenate([x.reshape(-1) for x in xs])
            return buf.astype(wire_dtype) if wire_dtype is not None else buf

        def unpack(buf):
            out, off = [], 0
            for x in lo_parts:
                seg = buf[off:off + x.size]
                out.append(seg.reshape(x.shape).astype(x.dtype))
                off += x.size
            return out

        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = unpack(jax.lax.ppermute(pack(hi_parts), axis_name, perm=fwd))
        bot = unpack(jax.lax.ppermute(pack(lo_parts), axis_name, perm=bwd))
    return [jnp.concatenate([t_, t, b_], axis=dim)
            for (t, _), t_, b_ in zip(parts, top, bot)]


def _right_column(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """The x-staggered neighbor of the slab's last column: the x-neighbor
    shard's first column (periodic 1-column exchange)."""
    if nx_shards == 1:
        return wcon[..., :1]
    bwd = [(i, (i - 1) % nx_shards) for i in range(nx_shards)]
    return jax.lax.ppermute(wcon[..., :1], ax_x, perm=bwd)


def _staggered_w(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """w = wcon_i + wcon_{i+1} on the local slab (see _right_column)."""
    right = _right_column(wcon, ax_x, nx_shards)
    return wcon + jnp.concatenate([wcon[..., 1:], right], axis=-1)


def _local_hdiff(f: jnp.ndarray, coeff: float, ax_y: str, ax_x: str,
                 ny_shards: int, nx_shards: int) -> jnp.ndarray:
    """f: (E, nz, ly, lx) local slab -> diffused slab."""
    e, nz, ly, lx = f.shape
    g = _exchange(f, ax_y, ny_shards, HALO, dim=2)
    g = _exchange(g, ax_x, nx_shards, HALO, dim=3)
    out = hdiff_ref.hdiff(g.reshape(e * nz, ly + 2 * HALO, lx + 2 * HALO),
                          coeff=coeff)
    out = out.reshape(e, nz, ly + 2 * HALO, lx + 2 * HALO)
    return out[:, :, HALO:HALO + ly, HALO:HALO + lx]


def _local_vadvc(u_stage, wcon, u_pos, utens, utens_stage, ax_x, nx_shards):
    """All (E, nz, ly, lx); staggered wcon column fetched from x-neighbor."""
    wcon_s = jnp.concatenate(
        [wcon, _right_column(wcon, ax_x, nx_shards)], axis=-1)
    # vmap over ensemble; fields already (nz, ly, lx) per member.
    out = jax.vmap(vadvc_ref.vadvc)(u_stage, wcon_s, u_pos, utens,
                                    utens_stage)
    return out


def make_distributed_step(mesh: Mesh, *, coeff: float = 0.025,
                          dt: float = 0.1, ax_e: str | None = "pod",
                          ax_y: str = "data", ax_x: str = "model",
                          fused: bool = True, whole_state: bool = True,
                          k_steps: int | str = 1,
                          exchange_dtype=None,
                          prefetch_w: bool | None = None,
                          interpret: bool | None = None):
    """Build the jitted distributed dycore step for `mesh`.

    Sharding: ensemble over `ax_e` (if present in the mesh), y over `ax_y`,
    x over `ax_x`; z always chip-local.  `fused`/`whole_state` select the
    chip-local compute path (module docstring); `k_steps` advances the state
    by k timesteps per call with ONE stacked halo exchange and ONE Pallas
    launch per round (the communication-avoiding mode; requires the default
    fused whole-state path).  `k_steps="auto"` resolves k per (grid, mesh)
    from the exchange model on the first call (`autotune.plan_k_steps`,
    clamped to what the VMEM budget fits).  `exchange_dtype` (e.g.
    "bfloat16") halves the stacked-exchange wire bytes; `prefetch_w`
    forwards to the k-step kernel's double-buffered `w` DMA pipeline
    (default: on outside interpret mode).  The returned `step` always
    advances `k_steps` timesteps."""
    have_e = ax_e is not None and ax_e in mesh.axis_names
    e_spec = ax_e if have_e else None
    spec = P(e_spec, None, ax_y, ax_x)
    ny_shards = mesh.shape[ax_y]
    nx_shards = mesh.shape[ax_x]
    auto_k = k_steps == "auto"
    if not auto_k and (not isinstance(k_steps, int) or k_steps < 1):
        raise ValueError(f"k_steps={k_steps!r} must be a positive int "
                         f"or 'auto'")
    if (auto_k or k_steps > 1) and not (fused and whole_state):
        raise ValueError("k_steps > 1 requires the fused whole-state path")
    if exchange_dtype is not None and not (fused and whole_state):
        raise ValueError("exchange_dtype requires the stacked (whole-state) "
                         "exchange path")
    if interpret is None:
        interpret = _auto_interpret()
    nf = len(PROGNOSTIC)

    def local_step_unfused(fields, wcon, tens, stage_tens):
        new_fields, new_stage = {}, {}
        for name in PROGNOSTIC:
            f = fields[name]
            stage = _local_vadvc(f, wcon, f, tens[name], stage_tens[name],
                                 ax_x, nx_shards)
            f = f + dt * stage
            f = _local_hdiff(f, coeff, ax_y, ax_x, ny_shards, nx_shards)
            new_fields[name] = f
            new_stage[name] = stage
        return new_fields, new_stage

    def local_step_fused(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape

        def pad(a):
            a = _exchange(a, ax_y, ny_shards, HALO, dim=2)
            return _exchange(a, ax_x, nx_shards, HALO, dim=3)

        # One exchange of the pre-combined staggered velocity serves all
        # fields; the per-field inputs are exchanged so the halo ring's
        # vadvc tendency is recomputed locally (cheaper than a second
        # exchange of the updated field mid-pipeline).
        wp = pad(_staggered_w(wcon, ax_x, nx_shards))
        ty = fused_ops.plan_tile((nz, ly + 2 * HALO, lx + 2 * HALO),
                                 wcon.dtype)
        crop = lambda a: a[:, :, HALO:HALO + ly, HALO:HALO + lx]
        new_fields, new_stage = {}, {}
        for name in PROGNOSTIC:
            f_new, stage = fused_dycore_pallas(
                pad(fields[name]), wp, pad(tens[name]),
                pad(stage_tens[name]), coeff=coeff, dt=dt, ty=ty,
                interpret=interpret)
            new_fields[name] = crop(f_new)
            new_stage[name] = crop(stage)
        return new_fields, new_stage

    def make_local_step_whole_state(k: int):
        def local_step_whole_state(fields, wcon, tens, stage_tens):
            e, nz, ly, lx = wcon.shape
            hy = k * HALO
            # The field operands need exactly the k-step stencil reach; only
            # wcon ships one extra x-column for the staggering
            # w[c] = wcon[c] + wcon[c+1] (the ragged stacked exchange).
            hx = k * HALO
            wx = hx + 1
            if hy > ly or wx > lx:
                raise ValueError(
                    f"k_steps={k} needs a ({hy}, {wx})-deep halo but the "
                    f"local slab is only ({ly}, {lx}); use fewer shards, a "
                    f"bigger grid, or a smaller k_steps")
            # ONE packed exchange per direction covers every operand:
            # fields, slow tendencies, stage tendencies at the field depth
            # and raw wcon at its own (deeper-x) depth, sharing the wire.
            stacked = jnp.stack(
                [fields[n] for n in PROGNOSTIC]
                + [tens[n] for n in PROGNOSTIC]
                + [stage_tens[n] for n in PROGNOSTIC], axis=1)
            stacked, wconp = _exchange_packed(
                [(stacked, hy), (wcon, hy)], ax_y, ny_shards, dim=-2,
                wire_dtype=exchange_dtype)
            stacked, wconp = _exchange_packed(
                [(stacked, hx), (wconp, wx)], ax_x, nx_shards, dim=-1,
                wire_dtype=exchange_dtype)
            fs, ts, ss = (stacked[:, :nf], stacked[:, nf:2 * nf],
                          stacked[:, 2 * nf:])
            # Staggered velocity on the padded slab — valid everywhere: the
            # +1 wcon column supplies the outermost right neighbor.
            w = wconp[..., 1:-1] + wconp[..., 2:]

            grid = (nz, ly + 2 * hy, lx + 2 * hx)
            if k == 1:
                ty = fused_ops.plan_tile_whole_state(grid, wcon.dtype, nf)
                fs, ss = fused_dycore_whole_state_pallas(
                    fs, w, ts, ss, coeff=coeff, dt=dt, ty=ty,
                    interpret=interpret)
            else:
                # The WHOLE round in one launch: the kernel iterates the k
                # local steps with state held in VMEM (no scan of launches,
                # no HBM state round-trips between steps).
                ty = fused_ops.plan_tile_kstep(grid, wcon.dtype, nf, k)
                fs, ss = fused_dycore_kstep_pallas(
                    fs, w, ts, ss, k_steps=k, coeff=coeff, dt=dt, ty=ty,
                    interpret=interpret, prefetch_w=prefetch_w)
            crop = lambda a: a[..., hy:hy + ly, hx:hx + lx]
            new_fields = {n: crop(fs[:, i]) for i, n in enumerate(PROGNOSTIC)}
            new_stage = {n: crop(ss[:, i]) for i, n in enumerate(PROGNOSTIC)}
            return new_fields, new_stage

        return local_step_whole_state

    def build(k: int):
        if fused and whole_state:
            local_step = make_local_step_whole_state(k)
        elif fused:
            local_step = local_step_fused
        else:
            local_step = local_step_unfused
        sharded = _shard_map(
            local_step, mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec))

        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            new_fields, new_stage = sharded(state.fields, state.wcon,
                                            state.tens, state.stage_tens)
            return WeatherState(fields=new_fields, wcon=state.wcon,
                                tens=state.tens, stage_tens=new_stage)

        return step

    if not auto_k:
        return build(k_steps), spec

    # k_steps="auto": the grid is only known from the state, so resolve k
    # (and build the jitted step) lazily per (grid, dtype) — a cached k for
    # one grid may be invalid for another.
    cache: dict = {}
    last_key: list = []

    def auto_step(state: WeatherState) -> WeatherState:
        grid = state.grid_shape
        key = (grid, str(state.wcon.dtype))
        if key not in cache:
            k = autotune.plan_k_steps(grid, state.wcon.dtype,
                                      (ny_shards, nx_shards), n_fields=nf,
                                      halo=HALO)
            while k > 1:   # clamp to what the VMEM budget fits
                try:
                    fused_ops.plan_tile_kstep(
                        (grid[0], grid[1] // ny_shards + 2 * k * HALO,
                         grid[2] // nx_shards + 2 * k * HALO),
                        state.wcon.dtype, nf, k)
                    break
                except ValueError:
                    k -= 1
            cache[key] = (k, build(k))
        last_key[:] = [key]
        return cache[key][1](state)

    auto_step.resolved_k = lambda: (cache[last_key[0]][0] if last_key
                                    else None)
    return auto_step, spec


def shard_state(state: WeatherState, mesh: Mesh, spec: P) -> WeatherState:
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda a: jax.device_put(a, sharding), state)
