"""Fused compound dycore step: vadvc -> point-wise update -> hdiff in one
Pallas dataflow pipeline (NERO's in-fabric fusion, arxiv 2107.08716 §3)."""

from repro.kernels.dycore_fused.fused import (fused_dycore_kstep_pallas,
                                              fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)
from repro.kernels.dycore_fused.ops import (fused_step, fused_step_kstep,
                                            fused_step_whole_state,
                                            plan_tile, plan_tile_kstep,
                                            plan_tile_whole_state, snap_ty,
                                            snap_ty_kstep)
from repro.kernels.dycore_fused.ref import fused_step_ref

__all__ = ["fused_dycore_pallas", "fused_dycore_whole_state_pallas",
           "fused_dycore_kstep_pallas", "fused_step", "fused_step_kstep",
           "fused_step_whole_state", "fused_step_ref", "plan_tile",
           "plan_tile_kstep", "plan_tile_whole_state", "snap_ty",
           "snap_ty_kstep"]
