"""Analytic per-device memory model for dry-run fit checking.

XLA:CPU's memory_analysis() is the only executable-derived number available
in this container, but the CPU backend fuses far less than TPU, so its
temp_size overestimates TPU liveness several-fold (measured ~6-8x on our
cells).  This model provides the TPU-side estimate the fit check uses; both
numbers are recorded in the dry-run JSON.

Accounting (per device):
  train:   param shards (bf16) + opt state (3x f32 shards) + grad shards
           (f32, co-live 1x) + layer-carry residuals (remat=full saves the
           per-layer carry) / microbatches + bwd working set (~2 layers of
           internals) + xent chunk buffers.
  prefill: param shards + KV-cache shards + ~2 layers of activations +
           flash chunk working set.
  decode:  param shards + KV-cache shards + O(B·d) vectors.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import hierarchy as hw
from repro.core import tiling
from repro.parallel import sharding as shd


def _shard_bytes(shapes_tree, shard_tree) -> int:
    """Sum per-device bytes of a pytree given its NamedShardings."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(shapes_tree),
                        jax.tree.leaves(shard_tree, is_leaf=lambda x: hasattr(
                            x, "spec"))):
        shape = leaf.shape
        spec = sh.spec
        mesh = sh.mesh
        n = 1
        for i, s in enumerate(shape):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = math.prod(mesh.shape[a] for a in axes)
            s = -(-s // div)
            n *= s / shape[i]
        total += int(n * math.prod(shape)) * np.dtype(leaf.dtype).itemsize
    return total


def estimate(cfg: ModelConfig, shape: ShapeConfig, mesh, p_shapes, p_shard,
             cache_shapes=None, cache_shard=None, *, microbatches: int = 1,
             xent_chunk: int = 512, spec=None) -> Dict[str, int]:
    model_par = mesh.shape.get("model", 1)
    b_axes = shd.batch_sharding(mesh, shape.global_batch)
    dp = 1
    if b_axes:
        axes = b_axes if isinstance(b_axes, tuple) else (b_axes,)
        dp = math.prod(mesh.shape[a] for a in axes)
    b_loc = -(-shape.global_batch // dp)
    t = shape.seq_len
    d = cfg.d_model
    vocab_loc = -(-cfg.padded_vocab // model_par)

    params_b = _shard_bytes(p_shapes, p_shard)
    out = {"params": params_b}

    if shape.kind == "train":
        out["opt_state"] = params_b * 2 * 3        # 3x f32 vs bf16 shards
        out["grads"] = params_b * 2                # f32 grad shards
        # remat=full checkpoints at scan-carry (superblock) boundaries:
        # one (B, T, D) residual per scan step + remainder blocks, NOT one
        # per layer (intra-period blocks are rematerialized).
        n_carries = cfg.n_repeats + cfg.n_remainder
        carry = n_carries * b_loc * (t // microbatches) * d * 2
        out["remat_carries"] = carry
        ff_loc = max(cfg.d_ff // model_par, d // model_par, 1)
        working = 6 * b_loc * (t // microbatches) * (d + ff_loc) * 4
        out["bwd_working_set"] = working
        out["xent"] = 2 * b_loc * min(xent_chunk, t) * vocab_loc * 4 * 2
    else:
        if cache_shapes is not None and cache_shard is not None:
            out["cache"] = _shard_bytes(cache_shapes, cache_shard)
        if shape.kind == "prefill":
            ff_loc = max(cfg.d_ff // model_par, d // model_par, 1)
            out["activations"] = 4 * b_loc * t * (d + ff_loc) * 2
            out["logits_tail"] = b_loc * vocab_loc * 4
        else:
            out["activations"] = 8 * b_loc * d * 4
            out["logits"] = b_loc * vocab_loc * 4

    out["total"] = sum(out.values())
    # Fit check against the target machine's main memory; the key keeps its
    # historical name (the default spec's HBM is 16 GiB) — dry-run JSON and
    # launch gating consume it.
    if spec is None:
        from repro.core import hwspec
        spec = hwspec.default_spec()
    out["fits_16g"] = bool(out["total"] <= spec.main.capacity_bytes)
    return out


def dycore_step_traffic(grid_shape, dtype, *, n_fields: int = 4,
                        ty: int = 8,
                        k_steps: int = 1) -> Dict[str, Dict[str, int]]:
    """Modeled HBM traffic of one dycore step, fused vs unfused — the NERO
    fusion accounting (arxiv 2107.08716 §3: the baseline's intermediates
    round-trip main memory between kernels; the fused PE streams each field
    once).

    Counts array-level reads/writes actually materialized by each pipeline,
    per ensemble member, for `n_fields` prognostic fields on a (nz, ny, nx)
    grid.  Unfused (the `variant="unfused"` dycore plan):

      vadvc      reads f, wcon, utens, utens_stage; writes stage
      point-wise reads f, stage;                    writes f'
      hdiff      pads (read f' / write padded), reads padded, writes f''

    Fused (kernels/dycore_fused), two bounds:

      "stream" — the dataflow ideal (NERO's line buffers): each input read
      once plus the 2-row y-window halo re-read from the TilePlan, 2 writes;
      plus one shared w = wcon_i + wcon_{i+1} precompute (read wcon, write w).

      "stream_window_reads" — the Pallas formulation as implemented: the
      periodic y-halo comes from three aliased prev/cur/next input refs, and
      each ref fetches a whole ty-row window per grid cell (Pallas only
      elides re-fetches when an operand's *own* block index repeats), so the
      pessimistic bound is 3x input reads.  The truth on real hardware lies
      between the two; the ideal is what a line-buffer/manual-DMA
      formulation of the same pipeline would reach.

    The k-step round (`k_steps > 1`, kernels/dycore_fused
    `fused_dycore_kstep_pallas`) adds the "fused_kstep" bounds: ONE launch
    advances k timesteps with the prognostic state held in VMEM between
    local steps, so the inter-step state traffic — field + stage tendency
    read AND written per step boundary — collapses from once per step to
    once per ROUND: a modeled >= k× reduction on exactly the bytes the PR 2
    scan-of-launches path round-tripped ("interstep_state" vs
    "interstep_state_scan", ratio "interstep_reduction_x").  The price is
    the 3-window working slab each grid cell stages (the kernel's y-halo is
    a whole window per side), reflected in the per-round stream bound.

    Returns {"unfused": {...}, "fused": {...}, "fused_whole": {...},
    "fused_kstep": {...} (when k_steps > 1), "reduction_x": float (ideal),
    "reduction_x_window_reads": float (pessimistic), ...} with per-stage
    byte counts and totals.
    """
    grid_shape = tuple(int(g) for g in grid_shape)
    b = hw.dtype_bytes(dtype)
    pts = math.prod(grid_shape)
    fb = pts * b                                   # one field's HBM bytes

    unfused = {
        "vadvc": n_fields * (4 + 1) * fb,
        "pointwise": n_fields * (2 + 1) * fb,
        "hdiff_pad": n_fields * 2 * fb,            # materialized wrap-pad
        "hdiff": n_fields * 2 * fb,
    }
    unfused["total"] = sum(unfused.values())

    nz, ny, nx = grid_shape
    ty = max(2, min(ty, ny))
    plan = tiling.TilePlan(op=tiling.DYCORE_FUSED, grid_shape=grid_shape,
                           tile=(nz, ty, nx), dtype=str(jax.numpy.dtype(dtype)))
    n_in = tiling.DYCORE_FUSED.fields_in
    n_out = tiling.DYCORE_FUSED.fields_out
    fused = {
        "stream": n_fields * plan.hbm_bytes_total,  # 4 in (+halo) + 2 out
        "w_precompute": 2 * fb,                     # shared across fields
    }
    fused["total"] = sum(fused.values())
    # As-implemented pessimistic bound: 3 whole-window fetches per input.
    fused["stream_window_reads"] = (
        n_fields * (3 * n_in + n_out) * fb + fused["w_precompute"])

    # Whole-state variant (one pallas_call for all fields, shared w): per
    # field only the 3 private streams (f, utens, utens_stage) plus the w
    # slab amortized 1/n_fields — the OpSpec's fractional fields_in — so
    # `n_fields * plan.hbm_bytes_total` already counts w exactly once.
    wplan = tiling.TilePlan(op=tiling.dycore_whole_state_spec(n_fields),
                            grid_shape=grid_shape, tile=(nz, ty, nx),
                            dtype=str(jax.numpy.dtype(dtype)))
    whole = {
        "stream": n_fields * wplan.hbm_bytes_total,
        "w_precompute": 2 * fb,
    }
    whole["total"] = sum(whole.values())
    # Pessimistic aliased-window bound: 3 whole-window fetches per private
    # input per field, but w's 3 windows are fetched once per (e, j) — the
    # shared BlockSpec index map repeats across the field axis.
    whole["stream_window_reads"] = (
        (n_fields * (3 * 3 + n_out) + 3) * fb + whole["w_precompute"])

    out = {"unfused": unfused, "fused": fused, "fused_whole": whole,
           "reduction_x": unfused["total"] / max(fused["total"], 1),
           "reduction_x_window_reads": (
               unfused["total"] / max(fused["stream_window_reads"], 1)),
           "reduction_x_whole": unfused["total"] / max(whole["total"], 1),
           "reduction_x_whole_window_reads": (
               unfused["total"] / max(whole["stream_window_reads"], 1)),
           "halo_overhead": plan.halo_overhead}

    if k_steps > 1:
        kspec = tiling.dycore_kstep_spec(n_fields, k_steps)
        kty = max(2, min(max(ty, k_steps * 2), ny))
        ksplan = tiling.TilePlan(op=kspec, grid_shape=grid_shape,
                                 tile=(nz, kty, nx),
                                 dtype=str(jax.numpy.dtype(dtype)))
        # Per-round carried-state traffic (field + stage tendency, read and
        # written at HBM): once per ROUND in the k-step kernel vs once per
        # STEP in the scan-of-launches path.
        interstep = 4 * n_fields * fb
        kstep = {
            # One k-step round, 3-window per-field streams + shared w.
            "stream": n_fields * ksplan.hbm_bytes_total + 2 * fb,
            # The PR 2 path for the same round: k whole-state launches.
            "scan_total": k_steps * whole["total"],
            "scan_window_reads": k_steps * whole["stream_window_reads"],
            "interstep_state": interstep,
            "interstep_state_scan": k_steps * interstep,
        }
        kstep["total"] = kstep["stream"]
        out["fused_kstep"] = kstep
        out["interstep_reduction_x"] = (
            kstep["interstep_state_scan"] / max(kstep["interstep_state"], 1))
        out["reduction_x_kstep_vs_scan"] = (
            kstep["scan_total"] / max(kstep["total"], 1))
    return out


def packed_exchange_model(grid_shape, dtype, *, rides, k: int = 1,
                          shards=(2, 2), compute_halo=None,
                          exchange_dtype=None) -> Dict[str, float]:
    """Footprint-driven packed-exchange accounting: the wire bytes of one
    deep (depth-k) stacked halo exchange, derived ENTIRELY from declared
    per-operand ride depths — no per-operand special cases.  This is the
    byte model behind every registered stencil op
    (`weather/stencil_ops.py`); `kstep_exchange_model` below is the fused
    dycore's footprint fed through it (its old hand-written
    `bytes_wcon`-style cases are gone).

    `rides` is a sequence of per-operand footprint declarations
    `(name, count, (y_lo, y_hi), (x_lo, x_hi), (y_lo_fix, y_hi_fix),
    (x_lo_fix, x_hi_fix))`: `count` same-shaped tensors ride the packed
    wire with per-SIDE depth `k * base + fixed` (the fixed part models
    staggering columns that do not deepen with k — e.g. wcon's right-only
    `+1`).  A zero side ships nothing (and costs no collective).

    Returns, per shard and per k timesteps:

      bytes_kstep        — bytes ppermuted by the single deep exchange
      bytes_sequential   — bytes of k depth-1 rounds (the k=1 path)
      bytes_by_operand   — each ride's share of bytes_kstep
      bytes_ratio        — bytes_kstep / bytes_sequential
      rounds_kstep / rounds_sequential — exchange rounds (mesh directions
                           with any traffic; 1 collective per active SIDE)
      redundant_flops_frac — extra stencil work on the compute halo ring
                           relative to the interior (`compute_halo` =
                           (hy, hx) one-sided padding of the local compute
                           slab; defaults to the widest y/x ride)
    """
    nz, ny, nx = (int(g) for g in grid_shape)
    py, px = shards
    ly, lx = ny // py, nx // px
    b = hw.dtype_bytes(exchange_dtype if exchange_dtype is not None
                       else dtype)

    def depth(base, fixed, kk):
        return (kk * base[0] + fixed[0], kk * base[1] + fixed[1])

    def operand_bytes(count, dy, dx):
        y = count * nz * (dy[0] + dy[1]) * lx * b
        x = count * nz * (dx[0] + dx[1]) * (ly + dy[0] + dy[1]) * b
        return int(y + x)

    def round_bytes(kk):
        out = {}
        for name, count, ybase, xbase, yfix, xfix in rides:
            out[name] = operand_bytes(count, depth(ybase, yfix, kk),
                                      depth(xbase, xfix, kk))
        return out

    # Validation: every ride must fit the local slab at depth k.
    for name, count, ybase, xbase, yfix, xfix in rides:
        dy, dx = depth(ybase, yfix, k), depth(xbase, xfix, k)
        if max(dy) > ly or max(dx) > lx:
            raise ValueError(
                f"k={k} needs a ({max(dy)}, {max(dx)})-deep halo for "
                f"{name!r}; local slab ({ly}, {lx})")

    per_op = round_bytes(k)
    bytes_kstep = sum(per_op.values())
    bytes_seq = k * sum(round_bytes(1).values())
    # An exchange round per mesh direction with any traffic.
    y_active = any(sum(depth(yb, yf, k)) > 0
                   for _, _, yb, _, yf, _ in rides)
    x_active = any(sum(depth(xb, xf, k)) > 0
                   for _, _, _, xb, _, xf in rides)
    rounds = int(y_active) + int(x_active)
    if compute_halo is None:
        hy = max((depth(yb, yf, k)[1] for _, _, yb, _, yf, _ in rides),
                 default=0)
        hx = max((depth(xb, xf, k)[0] for _, _, _, xb, _, xf in rides),
                 default=0)
    else:
        hy, hx = compute_halo
    padded = (ly + 2 * hy) * (lx + 2 * hx)
    return {
        "bytes_kstep": bytes_kstep,
        "bytes_sequential": bytes_seq,
        "bytes_by_operand": per_op,
        "bytes_ratio": bytes_kstep / max(bytes_seq, 1),
        "rounds_kstep": rounds,
        "rounds_sequential": rounds * k,
        "redundant_flops_frac": padded / (ly * lx) - 1.0,
    }


def kstep_exchange_model(grid_shape, dtype, *, n_fields: int = 4,
                         k: int = 1, shards=(2, 2), halo: int = 2,
                         exchange_dtype=None) -> Dict[str, float]:
    """Communication-avoiding k-step accounting for the fused dycore: one
    RAGGED stacked halo exchange — the `3*n_fields` field operands at depth
    `k*halo` in both directions, `wcon` alone one column deeper in x for
    its staggering (`w[c] = wcon[c] + wcon[c+1]`), and ASYMMETRICALLY so:
    the extra column is needed from the RIGHT neighbor only, so wcon's
    x-ride is `(k*halo, k*halo + 1)`.

    Since the StencilOp registry landed this is just the fused dycore's
    declared footprint fed through `packed_exchange_model` (the generic,
    footprint-driven byte accounting); kept under its historical name and
    output keys (`bytes_wcon` etc.) because benchmarks/plans embed them.

    `exchange_dtype` models the wire cast (bf16 halves the halo bytes,
    independent of the state dtype).  `shards` is (py, px)."""
    h = halo
    rides = (
        ("fields", 3 * n_fields, (h, h), (h, h), (0, 0), (0, 0)),
        ("wcon", 1, (h, h), (h, h), (0, 0), (0, 1)),
    )
    m = packed_exchange_model(grid_shape, dtype, rides=rides, k=k,
                              shards=shards, compute_halo=(k * h, k * h),
                              exchange_dtype=exchange_dtype)
    m["bytes_wcon"] = m["bytes_by_operand"]["wcon"]
    return m


def pipeline_step_traffic(chain_spec, stage_specs, grid_shape, dtype, *,
                          tile=None, k_steps: int = 1) -> Dict[str, float]:
    """Chained-vs-sequential HBM accounting of a fused stage chain
    (`weather/pipeline.py`): the chained bound streams the chain's operand
    UNION once per round (`chain_spec`, synthesized by
    `tiling.pipeline_spec` — intermediates stay resident between stages),
    the sequential bound is the sum of each stage run as its own solo
    program (`stage_specs`: `(OpSpec, n_fields)` pairs — every stage
    re-reads its inputs from and re-writes its outputs to main memory).
    The gap is exactly the inter-stage state round-trip the pipeline
    planner eliminates by ordering launches so stage i's outputs are
    stage i+1's resident inputs.

    Returns the chain's `stencil_op_traffic` dict extended with
    `sequential_per_round`, `sequential_by_stage`, and
    `chained_reduction_x` (sequential / chained; > 1 whenever the chain
    has more than one stage touching shared operands)."""
    n_chain = max(int(nf) for _, nf in stage_specs)
    out = stencil_op_traffic(chain_spec, grid_shape, dtype,
                             n_fields=n_chain, tile=tile, k_steps=k_steps)
    by_stage: Dict[str, int] = {}
    seq = 0
    for i, (spec, nf) in enumerate(stage_specs):
        t = stencil_op_traffic(spec, grid_shape, dtype, n_fields=int(nf),
                               tile=tile, k_steps=k_steps)
        label = spec.name
        if label in by_stage:
            label = f"{label}#{i}"
        by_stage[label] = t["stream_per_round"]
        seq += t["stream_per_round"]
    out["chained_per_round"] = out["stream_per_round"]
    out["sequential_per_round"] = int(seq)
    out["sequential_by_stage"] = by_stage
    out["chained_reduction_x"] = seq / max(out["stream_per_round"], 1)
    return out


def stencil_op_traffic(spec, grid_shape, dtype, *, n_fields: int = 1,
                       tile=None, k_steps: int = 1) -> Dict[str, float]:
    """Modeled HBM traffic of one step of a registered stencil op, derived
    from its `tiling.OpSpec` (streams in/out + halo) — the per-op analogue
    of `dycore_step_traffic`'s fused bounds, used by
    `weather/program.py::ExecutionPlan.report()` for hdiff/vadvc plans.

    `tile` is the (z, y, x) window the plan resolved (defaults to a whole-
    grid window).  Returns per-step stream bytes (x `n_fields` fields), the
    dataflow ideal, the halo re-read overhead, and per-ROUND bytes at
    `k_steps` sequential applications."""
    grid_shape = tuple(int(g) for g in grid_shape)
    if tile is None:
        tile = grid_shape
    plan = tiling.TilePlan(op=spec, grid_shape=grid_shape, tile=tuple(tile),
                           dtype=str(jax.numpy.dtype(dtype)))
    b = hw.dtype_bytes(dtype)
    ideal = int(spec.bytes_moved_per_point * b * math.prod(grid_shape))
    stream = plan.hbm_bytes_total
    return {
        "stream_per_field": stream,
        "stream": n_fields * stream,
        "stream_per_round": k_steps * n_fields * stream,
        "ideal": n_fields * ideal,
        "halo_overhead": plan.halo_overhead,
        "flops_per_step": n_fields * plan.flops_total,
    }
