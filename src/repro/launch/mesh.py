"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import
(see dryrun.py); tests and benches see the real single device.
"""

from __future__ import annotations

import math

import numpy as np

import jax
from jax.sharding import Mesh


def make_mesh(shape, axes) -> Mesh:
    """Mesh over the first prod(shape) available devices (the dry-run env
    exposes 512 host devices; the single-pod mesh uses the first 256)."""
    shape = tuple(int(s) for s in shape)
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dryrun.py does this)")
    arr = np.asarray(devs[:n]).reshape(shape)
    if hasattr(jax.sharding, "AxisType"):   # jax >= 0.5
        return Mesh(arr, tuple(axes),
                    axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return Mesh(arr, tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = ("data", "model") — 256 chips.
    Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.
    The "pod" axis extends to N pods unchanged (data-parallel across pods;
    ICI within a pod, DCN across)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Mesh axes that carry batch/data parallelism."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
