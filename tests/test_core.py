"""core/: tiling planner, autotuner, perf model, roofline parsing."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import autotune, hierarchy, perfmodel, roofline, tiling


def test_candidate_tiles_respect_vmem_and_seq_axes():
    hier = hierarchy.tpu_v5e()
    plans = tiling.candidate_tiles(tiling.VADVC, (64, 256, 256), jnp.float32,
                                   hier)
    assert plans, "no legal plans"
    for p in plans:
        assert p.tile[0] == 64, "vadvc must keep z whole (sequential axis)"
        assert p.vmem_bytes <= hier.vmem.capacity_bytes


def test_autotune_pareto_and_dtype_dependence():
    """Paper Fig.6: the Pareto-optimal tile depends on precision."""
    grid = (64, 256, 256)
    t32 = autotune.tune(tiling.VADVC, grid, jnp.float32)
    t16 = autotune.tune(tiling.VADVC, grid, jnp.bfloat16)
    assert t32.plan.fits(hierarchy.tpu_v5e())
    assert t16.plan.fits(hierarchy.tpu_v5e())
    # bf16 tiles hold 2x the points of fp32 at equal VMEM
    assert (t16.plan.tile_points >= t32.plan.tile_points)


def test_pareto_front_is_nondominated():
    pts = [(1.0, 100, 0), (2.0, 50, 1), (0.5, 200, 2), (3.0, 300, 3)]
    front = autotune.pareto_front(pts)
    chosen = [pts[i] for i in front]
    for a in chosen:
        for b in chosen:
            assert not (b[0] < a[0] and b[1] < a[1])
    assert 3 not in front      # dominated point


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([tiling.HDIFF, tiling.VADVC, tiling.COPY]),
       st.sampled_from(["float32", "bfloat16"]))
def test_perf_estimate_invariants(op, dtype):
    plans = tiling.candidate_tiles(op, (64, 128, 128), dtype)
    for plan in plans[:5]:
        est = perfmodel.estimate(plan)
        assert est.time_s > 0
        assert est.memory_s >= 0 and est.compute_s >= 0
        assert est.energy_j > 0
        frac = perfmodel.roofline_fraction(est)
        assert 0 < frac <= 1.05


def test_halo_overhead_decreases_with_tile_size():
    small = tiling.TilePlan(tiling.HDIFF, (64, 256, 256), (1, 8, 256),
                            "float32")
    big = tiling.TilePlan(tiling.HDIFF, (64, 256, 256), (1, 64, 256),
                          "float32")
    assert big.halo_overhead < small.halo_overhead


def test_collective_parsing():
    hlo = """
  %ar = bf16[128,1024]{1,0} all-reduce(bf16[128,1024] %x), replica_groups={}
  %ag.1 = f32[16,512]{1,0} all-gather(f32[16,32] %y), dimensions={1}
  %cp = (f32[4,4], f32[4,4]) collective-permute-start(f32[4,4] %z)
  %aa = bf16[64]{0} all-to-all(bf16[64] %w)
"""
    coll = roofline.collective_bytes(hlo)
    assert coll["all-reduce"] == 128 * 1024 * 2
    assert coll["all-gather"] == 16 * 512 * 4       # result shape only
    assert coll["all-to-all"] == 64 * 2
    assert "collective-permute" in coll
    wire = roofline.wire_bytes(coll)
    assert wire > coll["all-reduce"]      # AR counts 2x (ring)


def test_roofline_analyze_dominant_term():
    cost = {"flops": 1e12, "bytes accessed": 1e9}
    terms = roofline.analyze(cost, {"all-reduce": int(1e9)}, chips=256,
                             model_flops_total=2e14)
    assert terms.dominant == "collective"
    assert terms.compute_s == pytest.approx(1e12 / hierarchy.PEAK_BF16_FLOPS)
    assert 0 < terms.roofline_fraction < 1


def test_machine_balance_sane():
    h = hierarchy.tpu_v5e()
    mb = h.machine_balance(jnp.bfloat16)
    assert 200 < mb < 300      # 197e12/819e9 ≈ 240
