"""repro.weather subpackage."""
