"""COSMO-like dynamical core built from the paper's compound kernels.

One timestep applies the three computational patterns the paper names
(§1): horizontal stencils (hdiff), tridiagonal solves in the vertical
(vadvc), and point-wise computation (the explicit update).  It is a
*representative* dycore, faithful to the kernels and their composition, not a
full COSMO port.

The execution strategy — unfused oracle / per-field fused / whole-state
fused / in-kernel k-step, tile choice, interpret mode — is resolved by the
declarative plan API in `weather/program.py` (programs over the StencilOp
registry, `weather/stencil_ops.py`):

    from repro.weather.program import DycoreProgram, compile
    plan = compile(DycoreProgram(grid_shape=(16, 64, 64)))
    state = plan.step(state)          # one round
    state = plan.run(state, steps=10)

The legacy flag-soup entry points (`dycore_step`, `run`) are GONE —
retired ROADMAP item; they lived here as `DeprecationWarning` shims until
every caller migrated to plans.  The periodic per-kernel helpers
(`hdiff_periodic`, `vadvc_field`) and the state stack/unstack utilities
stay first-class — the plan lowerings in `weather/stencil_ops.py` build
on them.

The domain is doubly periodic in (y, x) — the standard dycore test setup —
so the distributed version (weather/domain.py + program.py) only needs
circular halo exchanges.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dycore_fused.ref import pad_periodic
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC

HALO = 2   # hdiff needs 2; vadvc needs 1 (staggered wcon)


def hdiff_periodic(src: jnp.ndarray, coeff: float) -> jnp.ndarray:
    """Periodic compound horizontal diffusion of a (..., nz, ny, nx) field."""
    ny, nx = src.shape[-2:]
    flat = src.reshape((-1,) + src.shape[-3:])

    def one(f):
        padded = pad_periodic(f, HALO)
        out = hdiff_ref.hdiff(padded, coeff=coeff)
        return out[:, HALO:HALO + ny, HALO:HALO + nx]

    return jax.vmap(one)(flat).reshape(src.shape)


def vadvc_field(u_stage, wcon, u_pos, utens, utens_stage):
    """vadvc over a (..., nz, ny, nx) field.  `wcon` is (..., nz, ny, nx)
    and is wrap-padded to the staggered (nx+1) extent (periodic domain)."""
    shape = u_stage.shape
    wcon_s = jnp.concatenate([wcon, wcon[..., :1]], axis=-1)
    flat = lambda a: a.reshape((-1,) + a.shape[-3:])
    out = jax.vmap(vadvc_ref.vadvc)(flat(u_stage), flat(wcon_s), flat(u_pos),
                                    flat(utens), flat(utens_stage))
    return out.reshape(shape)


def stack_state(d: dict, names=PROGNOSTIC) -> jnp.ndarray:
    """Stack the per-field dict onto a new axis -4: (..., nf, nz, ny, nx).
    `names` fixes the field order (a program's field set; default: the
    full prognostic set) — the single home of the layout convention the
    plan lowering (`weather/program.py`) builds on."""
    return jnp.stack([d[name] for name in names], axis=-4)


def unstack_state(a: jnp.ndarray, names=PROGNOSTIC) -> dict:
    """Inverse of `stack_state`."""
    return {name: jnp.take(a, i, axis=-4) for i, name in enumerate(names)}
