"""AdamW with mixed precision (bf16 params, fp32 master + moments),
cosine schedule with warmup, global-norm clipping.

Optimizer state is a dict of trees with the *same paths* as params, so the
parameter sharding rules apply verbatim (ZeRO: moments/master inherit the
FSDP+TP layout)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        # copy=True: when params are already fp32, astype would alias the
        # param buffer and donating (params, opt_state) would donate it twice.
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, opt_state, grads
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
    bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                        opt_state["master"])
    m = jax.tree.map(lambda t: t[0], flat,
                     is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat,
                     is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype),
                              master, params)
    new_state = {"m": m, "v": v, "master": master, "step": step + 1}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
