"""NeroEngine: plan caching, dispatch, and oracle agreement."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import NeroEngine
from repro.kernels.hdiff import ref as href
from repro.kernels.vadvc import ref as vref


def test_plan_is_cached_and_fits():
    eng = NeroEngine()
    t1 = eng.plan("hdiff", (8, 64, 64), jnp.float32)
    t2 = eng.plan("hdiff", (8, 64, 64), jnp.float32)
    assert t1 is t2
    assert t1.plan.fits(eng.hier)
    assert t1.est.time_s > 0


def test_precision_changes_pareto_choice():
    eng = NeroEngine()
    p32 = eng.plan("hdiff", (64, 256, 256), jnp.float32).plan
    p16 = eng.plan("hdiff", (64, 256, 256), jnp.bfloat16).plan
    # paper Fig. 6: the chosen window depends on dtype (bf16 fits more)
    assert p16.vmem_bytes <= p32.vmem_bytes * 2
    assert p16.tile != p32.tile or p16.dtype != p32.dtype


def test_run_hdiff_matches_oracle():
    eng = NeroEngine()
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.normal(size=(4, 16, 128)).astype(np.float32))
    tuned = eng.plan("hdiff", src.shape, src.dtype)
    out = eng.run(tuned, src)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(href.hdiff(src)),
                               atol=1e-5)


def test_run_vadvc_matches_oracle():
    eng = NeroEngine()
    rng = np.random.default_rng(1)
    shp = (8, 8, 128)
    f = lambda: jnp.asarray(rng.normal(size=shp).astype(np.float32))
    wcon = jnp.asarray(rng.normal(size=(8, 8, 129)).astype(np.float32))
    u, up, ut, us = f(), f(), f(), f()
    tuned = eng.plan("vadvc", shp, jnp.float32)
    out = eng.run(tuned, u, wcon, up, ut, us)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(vref.vadvc(u, wcon, up, ut, us)),
                               atol=2e-4, rtol=2e-4)


def test_precision_dependent_pareto_under_bram_budget():
    """Paper Fig. 6: the Pareto-optimal window depends on precision when
    the near-memory resource binds (FPGA ~1 MiB BRAM per PE).  At v5e's
    128 MiB VMEM the 256x256x64 domain never binds — also asserted, since
    that hardware-adaptation finding is recorded in EXPERIMENTS.md."""
    from repro.core import hierarchy as hw
    from repro.core.autotune import tune
    from repro.core import tiling

    hier = hw.tpu_v5e()
    small = hw.Hierarchy(
        hbm=hier.hbm,
        vmem=hw.MemoryLevel("vmem", 2**20,
                            hier.vmem.bandwidth_bytes_per_s,
                            hier.vmem.energy_pj_per_byte),
        vreg=hier.vreg)
    grid = (64, 256, 256)
    for op in (tiling.VADVC, tiling.HDIFF):
        c32 = tune(op, grid, "float32", small).plan
        c16 = tune(op, grid, "bfloat16", small).plan
        assert c32.tile != c16.tile, op.name
        assert c16.tile_points > c32.tile_points, op.name
        v32 = tune(op, grid, "float32", hier).plan
        v16 = tune(op, grid, "bfloat16", hier).plan
        assert v32.tile == v16.tile, op.name
