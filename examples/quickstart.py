"""Quickstart: the paper's two kernels through the NERO engine layers.

1. Run hdiff + vadvc oracles on the paper's 256x256x64 domain.
2. Auto-tune the 3-D window (paper Fig. 6) and show the chosen plan.
3. Validate the Pallas TPU kernels (interpret mode) against the oracles.
4. Compile declarative programs — hdiff-only, vadvc-only, and the fused
   dycore, each a registered StencilOp — into ExecutionPlans
   (`repro.weather.program.compile`) and advance them.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hierarchy, perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href
from repro.kernels.hdiff.hdiff import hdiff_pallas
from repro.kernels.vadvc import ref as vref
from repro.kernels.vadvc.vadvc import vadvc_pallas


def main():
    rng = np.random.default_rng(0)
    nz, ny, nx = grid = (64, 256, 256)
    print(f"== NERO quickstart on the paper's {nx}x{ny}x{nz} domain ==")

    src = jnp.asarray(rng.normal(size=grid).astype(np.float32))
    out = jax.jit(href.hdiff)(src)
    print(f"hdiff: out[2,2,2]={float(out[2, 2, 2]):+.4f} "
          f"finite={bool(jnp.isfinite(out).all())}")

    us, up, ut, uts = (jnp.asarray(rng.normal(size=grid).astype(np.float32))
                       for _ in range(4))
    wcon = jnp.asarray(rng.uniform(-0.2, 0.2, size=(nz, ny, nx + 1))
                       .astype(np.float32))
    adv = jax.jit(vref.vadvc)(us, wcon, up, ut, uts)
    res = vref.tridiagonal_residual(us, wcon, up, ut, uts, np.asarray(adv))
    print(f"vadvc: tridiagonal residual {res:.2e} (solves the system)")

    for op, dtype in ((tiling.VADVC, "float32"), (tiling.VADVC, "bfloat16")):
        t = tune(op, grid, dtype)
        pct = 100 * t.plan.vmem_bytes / hierarchy.tpu_v5e().vmem.capacity_bytes
        print(f"autotuned {op.name}/{dtype}: tile={t.plan.tile} "
              f"vmem={pct:.0f}% model_gflops={t.est.gflops:.0f}")

    # Pallas kernels, interpret mode (CPU container; TPU is the target)
    small = (8, 32, 32)
    s2 = jnp.asarray(rng.normal(size=small).astype(np.float32))
    pe = np.asarray(hdiff_pallas(s2, ty=8, interpret=True))
    err = np.abs(pe - np.asarray(href.hdiff(s2))).max()
    print(f"pallas hdiff vs oracle: max err {err:.2e}")

    f = [jnp.asarray(rng.normal(size=small).astype(np.float32))
         for _ in range(4)]
    w2 = jnp.asarray(rng.uniform(-0.2, 0.2, size=(8, 32, 33))
                     .astype(np.float32))
    pv = np.asarray(vadvc_pallas(f[0], w2, f[1], f[2], f[3], tj=8, ti=16,
                                 interpret=True))
    err = np.abs(pv - vref.vadvc_np(f[0], w2, f[1], f[2], f[3])).max()
    print(f"pallas vadvc vs oracle: max err {err:.2e}")

    # Declarative programs over REGISTERED stencil ops: the spec says WHAT
    # (op, grid, fields, k-step policy); compile resolves HOW (variant,
    # auto-tuned tile, footprint-derived exchange, launches per round)
    # once.  The paper's two kernels are first-class programs.
    from repro.weather import fields as wfields
    from repro.weather.program import (DycoreProgram, StencilProgram,
                                       compile)
    st = wfields.initial_state(jax.random.PRNGKey(0), small)
    hplan = compile(StencilProgram(grid_shape=small, op="hdiff"))
    hrep = hplan.report()
    print(f"compile(op=hdiff): variant={hrep['variant']} "
          f"launches/round={hrep['pallas_calls_per_round']} "
          f"footprint={hrep['footprint']['rides'][0]['depth_y']} "
          f"model_gflops={hrep['model']['gflops']:.0f}")
    st = hplan.step(st)
    vplan = compile(StencilProgram(grid_shape=small, op="vadvc"))
    vrep = vplan.report()
    print(f"compile(op=vadvc): variant={vrep['variant']} "
          f"wcon ride={vrep['footprint']['rides'][0]['depth_x']} "
          f"model_gflops={vrep['model']['gflops']:.0f}")
    st = vplan.step(st)
    plan = compile(DycoreProgram(grid_shape=small, variant="kstep",
                                 k_steps=2))
    rep = plan.report()
    print(f"compile(op=dycore): variant={rep['variant']} "
          f"k_steps={rep['k_steps']} tile={rep['tile']['tile']} "
          f"launches/round={rep['pallas_calls_per_round']}")
    st = plan.run(st, 3)   # 1 k-step round + a ragged 1-step tail round
    ok = bool(jnp.isfinite(st.fields["t"]).all())
    print(f"plan.run(3 steps): finite={ok}")

    # Chain registered ops into ONE plan: the planner back-propagates the
    # stages' reach into a single fused exchange and runs the launches in
    # order on resident operands — bit-identical to the solo programs.
    from repro.weather.pipeline import PipelineProgram
    pplan = compile(PipelineProgram(
        grid_shape=small, coeff=0.05,
        stages=("hadv_upwind", "vadvc_update", "hdiff")))
    prep = pplan.report()
    print(f"compile(pipeline): stages=3 "
          f"launches/round={prep['pallas_calls_per_round']} "
          f"merged fields ride="
          f"{prep['footprint']['rides'][0]['depth_y']} "
          f"hbm_reduction={prep['traffic']['chained_reduction_x']:.2f}x")
    st = pplan.step(st)
    print("quickstart OK")


if __name__ == "__main__":
    main()
