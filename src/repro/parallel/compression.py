"""Gradient compression codecs + compressed cross-replica reduction.

`int8_rowwise` quantizes each row (last axis) to int8 with a per-row fp32
scale and stochastic rounding (unbiased).  `compressed_psum` is the manual
data-parallel reduction used by the shard_map training path: encode ->
psum(int32) -> decode, which actually shrinks wire bytes 4x vs fp32 / 2x vs
bf16 (the GSPMD auto path cannot intercept its implicit reductions, so
compression there is a no-op by design — documented in DESIGN.md §5)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_rowwise_encode(key, x: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    flat = xf.reshape(-1, xf.shape[-1]) if xf.ndim > 1 else xf.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    y = flat / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    q = q.reshape(x.shape)
    scale_shape = (x.shape[:-1] + (1,)) if x.ndim > 1 else scale.shape
    return q, scale.reshape(scale_shape)


def int8_rowwise_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str, method: str = "none", key=None):
    """Reduce a gradient pytree across `axis_name` inside shard_map.

    method: "none" (fp32 psum) | "bf16" | "int8".  int8: psum the int8
    payload in int32 (sum of quantized values is exact) and the scales in
    fp32, then decode — unbiased stochastic rounding keeps E[grad] exact.
    """
    n = jax.lax.psum(1, axis_name)
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g.astype(jnp.float32),
                                                   axis_name) / n, tree)
    if method == "bf16":
        return jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
            .astype(jnp.float32) / n, tree)
    if method == "int8":
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = []
        for k, g in zip(keys, leaves):
            q, s = int8_rowwise_encode(k, g)
            qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
            ss = jax.lax.psum(s, axis_name)          # sum of row maxima
            # decode: each replica contributed q_i * s_i; we approximate the
            # sum with mean scale (valid since scales are near-equal across
            # replicas for IID grads) — exact variant ships both tensors.
            out.append(qs.astype(jnp.float32) * (ss / n) / n)
        return jax.tree.unflatten(treedef, out)
    raise ValueError(method)


def exact_compressed_psum(tree, axis_name: str, key):
    """Exact int8 wire compression: all-gather (q, s) pairs and decode-sum.
    Wire bytes: 1B/elem + 4B/row vs 4B/elem for fp32 psum."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    n = jax.lax.psum(1, axis_name)
    out = []
    for k, g in zip(keys, leaves):
        q, s = int8_rowwise_encode(k, g)
        qg = jax.lax.all_gather(q, axis_name)        # (n, ...)
        sg = jax.lax.all_gather(s, axis_name)
        dec = (qg.astype(jnp.float32)
               * sg.reshape((n,) + s.shape)).sum(axis=0) / n
        out.append(dec)
    return jax.tree.unflatten(treedef, out)
