import os

# Tests run on the single real CPU device; only the dry-run process forces
# 512 host devices (never set that here — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
