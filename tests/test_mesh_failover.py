"""Elastic mesh failover + mesh-elastic engine restore (ISSUE 8).

Two recovery paths share one mechanism (gather to unsharded-logical,
recompile with the pinned round strategy, reshard through the new plan's
`state_spec`):

* **In-place failover** — a device falls out of the fabric mid-round; the
  engine rebuilds a mesh from the survivors and resumes every in-flight
  request from the last round boundary.  The kill-a-device test asserts
  the strongest form of the contract: every request completes
  ``status=="ok"`` BIT-identical to a solo run compiled on the ORIGINAL
  mesh, with ``lane_failures == 0``.
* **Elastic restore** — `ForecastEngine.restore(mesh=...)` accepts a
  checkpoint written on ANY device count.  The transition sweep
  (1→4, 4→1, 4→2) asserts bitwise identity to an uninterrupted run.

Bitwise caveat the sweep encodes (see docs/robustness.md for the full
matrix): collapsing a SHARDED mesh axis to one shard switches that axis
from halo-exchange to wrap-padding lowering and changes result bits for
ops that are not sharding-transparent (dycore, vadvc) — while shrinking a
sharded axis (2x2 → 2x1) keeps bits, and hdiff is bitwise mesh-invariant
everywhere.  So the 1↔4 legs run hdiff and the 4→2 leg adds dycore.

Mesh-level chaos (wire corruption caught by the fingerprint guard,
stragglers caught by the round-deadline watchdog) runs in-process below.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import domain, fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram

GRID = (3, 8, 8)
PROG = StencilProgram(grid_shape=GRID, ensemble=1)


def _state(seed, grid=GRID):
    return fields.initial_state(jax.random.PRNGKey(seed), grid, ensemble=1)


def _assert_bits(result, state, prog=None):
    prog = prog or result.program
    want = wprog.compile(prog).run(state, result.steps)
    for name in prog.fields:
        np.testing.assert_array_equal(np.asarray(result.state.fields[name]),
                                      np.asarray(want.fields[name]),
                                      err_msg=name)


def _run_snippet(snippet, marker, extra_env=None):
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu"}
    env.update(extra_env or {})
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])


_FORCE4 = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}

_COMMON = r"""
import os, numpy as np, jax
from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import domain, fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram

def make_mesh(py, px):
    kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
          if hasattr(jax.sharding, "AxisType") else {})
    return jax.make_mesh((py, px), ("data", "model"), **kw)
"""


# ---------------------------------------------------------------------------
# Kill a device: in-place failover, in-flight work preserved bit-for-bit
# ---------------------------------------------------------------------------

_KILL_DEVICE_SNIPPET = _COMMON + r"""
assert len(jax.devices()) == 4
mesh = make_mesh(2, 2)
grid = (4, 16, 16)
prog = StencilProgram(grid_shape=grid, ensemble=1)
states = [fields.initial_state(jax.random.PRNGKey(s), grid, ensemble=1)
          for s in (0, 1, 2)]
steps = (5, 3, 4)

# The reference: solo runs compiled on the ORIGINAL (pre-failure) mesh.
solo = wprog.compile(prog, mesh=mesh)
refs = [solo.run(domain.shard_state(s, mesh, solo.state_spec), n)
        for s, n in zip(states, steps)]

# Device 3 falls out of the fabric at round 1 and STAYS dead: the spec
# fires on every round while device 3 is part of the mesh the engine
# steps on, so only an actual failover clears it.
inj = FaultInjector([FaultSpec(kind="device_loss", round=1, device=3,
                               once=False)])
eng = ForecastEngine(slots=2, mesh=mesh, fault_injector=inj,
                     max_round_retries=1, retry_backoff_s=0.01)
rids = [eng.submit(ForecastRequest(program=prog, state=s, steps=n))
        for s, n in zip(states, steps)]
res = eng.drain()
st = eng.stats()

assert st["mesh_failovers"] >= 1, st
assert st["lane_failures"] == 0, st
assert st["recovery_rounds"] >= 1 and st["requests_preserved"] >= 1, st
fo = st["failovers"][0]
assert fo["lost_device"] == 3
assert 3 not in fo["to_devices"]
# 3 survivors cannot carry a 16x16 grid (16 % 3 != 0); the chosen shape
# must keep the y axis sharded (the bitwise-safe direction): 2x2 -> 2x1.
assert fo["from_shape"] == [2, 2] and fo["to_shape"] == [2, 1], fo
assert st["mesh_devices"] is not None and len(st["mesh_devices"]) == 2

for rid, ref in zip(rids, refs):
    assert res[rid].status == "ok", res[rid].diagnosis
    for name in prog.fields:
        assert np.array_equal(np.asarray(res[rid].state.fields[name]),
                              np.asarray(ref.fields[name])), (rid, name)
print("FAILOVER_KILL_OK")
"""


def test_kill_device_failover_preserves_inflight_forced_4dev():
    """A persistent device loss on a forced-4-device 2x2 mesh: every
    in-flight request completes ok, bit-identical to a solo run on the
    ORIGINAL mesh, without a single lane failure."""
    _run_snippet(_KILL_DEVICE_SNIPPET, "FAILOVER_KILL_OK", _FORCE4)


# ---------------------------------------------------------------------------
# Elastic restore: checkpoint written on one mesh, resumed on another
# ---------------------------------------------------------------------------

# Phase A runs under WRITE_MESH (or single-chip), pumps a couple of rounds
# and checkpoints mid-flight; phase B restores under READ_MESH and asserts
# every drained result is bit-identical to an uninterrupted solo run
# compiled on REF_MESH (empty = single-chip).
_RESTORE_WRITE_SNIPPET = _COMMON + r"""
def mesh_of(env):
    v = os.environ.get(env, "")
    return make_mesh(*map(int, v.split("x"))) if v else None

grid = (4, 16, 16)
ops = os.environ["RESTORE_OPS"].split(",")
eng = ForecastEngine(slots=2, mesh=mesh_of("WRITE_MESH"),
                     ckpt_dir=os.environ["RESTORE_CKPT"])
for i, op in enumerate(ops * 2):
    st = fields.initial_state(jax.random.PRNGKey(i), grid, ensemble=1)
    prog = StencilProgram(grid_shape=grid, ensemble=1, op=op)
    eng.submit(ForecastRequest(program=prog, state=st, steps=6 + i))
eng.pump()
eng.pump()
eng.checkpoint()
assert eng.has_work(), "checkpoint must land mid-flight"
print("RESTORE_WRITE_OK")
"""

_RESTORE_READ_SNIPPET = _COMMON + r"""
def mesh_of(env):
    v = os.environ.get(env, "")
    return make_mesh(*map(int, v.split("x"))) if v else None

grid = (4, 16, 16)
ops = os.environ["RESTORE_OPS"].split(",")
eng = ForecastEngine.restore(os.environ["RESTORE_CKPT"],
                             mesh=mesh_of("READ_MESH"))
res = eng.drain()
ref_mesh = mesh_of("REF_MESH")
for i, op in enumerate(ops * 2):
    st = fields.initial_state(jax.random.PRNGKey(i), grid, ensemble=1)
    prog = StencilProgram(grid_shape=grid, ensemble=1, op=op)
    solo = wprog.compile(prog, mesh=ref_mesh)
    if ref_mesh is not None:
        st = domain.shard_state(st, ref_mesh, solo.state_spec)
    want = solo.run(st, 6 + i)
    assert res[i].status == "ok", res[i].diagnosis
    for name in prog.fields:
        assert np.array_equal(np.asarray(res[i].state.fields[name]),
                              np.asarray(want.fields[name])), (i, op, name)
print("RESTORE_READ_OK")
"""


@pytest.mark.parametrize(
    "write,read,ref,ops",
    [
        ("", "2x2", "", "hdiff"),          # 1 -> 4: scale up
        ("2x2", "", "", "hdiff"),          # 4 -> 1: scale down to a chip
        ("2x2", "2x1", "2x2", "dycore,hdiff"),   # 4 -> 2: lose a node
    ],
    ids=["1to4", "4to1", "4to2"])
def test_elastic_restore_transition_bitwise(tmp_path, write, read, ref, ops):
    """The mesh-transition restore sweep: a checkpoint written on one
    mesh shape resumes on another and drains bit-identical to an
    uninterrupted solo run.  The 1↔4 legs use hdiff (bitwise
    mesh-invariant everywhere); 4→2 shrinks a sharded axis — the
    bitwise-safe direction — so dycore rides too."""
    env = dict(_FORCE4)
    env.update({"RESTORE_CKPT": str(tmp_path), "RESTORE_OPS": ops,
                "WRITE_MESH": write, "READ_MESH": read, "REF_MESH": ref})
    _run_snippet(_RESTORE_WRITE_SNIPPET, "RESTORE_WRITE_OK", env)
    _run_snippet(_RESTORE_READ_SNIPPET, "RESTORE_READ_OK", env)


# ---------------------------------------------------------------------------
# The fingerprint guard is sharding-invariant (the property failover and
# the wire-corruption detector both lean on)
# ---------------------------------------------------------------------------

_FP_INVARIANT_SNIPPET = _COMMON + r"""
from jax.sharding import NamedSharding, PartitionSpec as P
grid = (4, 16, 16)
batch = fields.initial_state(jax.random.PRNGKey(7), grid, ensemble=2)
ok_solo, fp_solo = map(np.asarray, wprog.slot_guard(batch, 1e6))
mesh = make_mesh(2, 2)
sharded = jax.tree.map(
    lambda a: jax.device_put(a, NamedSharding(mesh,
                                              P(None, None, "data",
                                                "model"))), batch)
ok_sh, fp_sh = map(np.asarray, wprog.slot_guard(sharded, 1e6))
assert np.array_equal(ok_solo, ok_sh)
assert np.array_equal(fp_solo, fp_sh), (fp_solo, fp_sh)
print("FP_INVARIANT_OK")
"""


def test_slot_guard_fingerprint_is_sharding_invariant_forced_4dev():
    _run_snippet(_FP_INVARIANT_SNIPPET, "FP_INVARIANT_OK", _FORCE4)


def test_slot_guard_detects_inplace_corruption():
    """The digest sees what magnitude checks cannot: finite, in-bounds
    damage to one slot changes ONLY that slot's fingerprint, and element
    swaps (which preserve every per-element statistic) change it too."""
    batch = fields.initial_state(jax.random.PRNGKey(3), GRID, ensemble=3)
    ok0, fp0 = map(np.asarray, wprog.slot_guard(batch, 1e6))
    assert ok0.all()

    inj = FaultInjector([FaultSpec(kind="wire_corrupt", round=0, slot=1)])
    poisoned = inj.poison(batch, "dycore", 0, (0, 1, 2),
                          nonparticipants=(1,))
    ok1, fp1 = map(np.asarray, wprog.slot_guard(poisoned, 1e6))
    assert ok1.all(), "wire corruption must PASS the validity guard"
    assert fp1[1] != fp0[1], "corrupted slot's digest must change"
    assert fp1[0] == fp0[0] and fp1[2] == fp0[2], \
        "healthy slots' digests must not change"

    u = np.array(batch.fields["u"])
    a, b = u[1, 0, 1, 1].copy(), u[1, 2, 5, 3].copy()
    assert a != b
    u[1, 0, 1, 1], u[1, 2, 5, 3] = b, a
    swapped = jax.tree_util.tree_map(lambda x: x, batch)
    swapped.fields = dict(swapped.fields)
    swapped.fields["u"] = u
    _, fp2 = map(np.asarray, wprog.slot_guard(swapped, 1e6))
    assert fp2[1] != fp0[1], "position-blind digests would miss swaps"


# ---------------------------------------------------------------------------
# Wire corruption: caught by the fingerprint at the boundary it occurs
# ---------------------------------------------------------------------------


def test_wire_corrupt_idle_slot_scrubbed_not_served():
    """Corruption landing in an IDLE slot (stale bits a dead wire buffer
    would scribble on) is scrubbed at the next round boundary and counted
    — the in-flight request is untouched, bit-for-bit."""
    inj = FaultInjector([FaultSpec(kind="wire_corrupt", round=1)])
    eng = ForecastEngine(slots=2, fault_injector=inj)
    s = _state(10)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=3))
    res = eng.drain()
    st = eng.stats()
    assert inj.fired("wire_corrupt") == 1
    assert st["fingerprint_divergence"] == 1
    assert st["scrubbed_idle_slots"] == 1
    assert st["quarantined"] == 0
    assert res[rid].status == "ok"
    _assert_bits(res[rid], s)


def test_wire_corrupt_rolled_back_slot_quarantines():
    """A rolled-back slot's bits provably must not change across the
    round — corruption there quarantines that request with a
    `fingerprint_divergence` diagnosis while its lane-mate completes
    bit-identically.  (k_steps=2 with steps 4 vs 3 forces the deep slot
    to be rolled back on the ragged round — the corruption target.)"""
    prog = StencilProgram(grid_shape=GRID, ensemble=1, variant="kstep",
                          k_steps=2)
    inj = FaultInjector([FaultSpec(kind="wire_corrupt", round=1, slot=0)])
    eng = ForecastEngine(slots=2, fault_injector=inj)
    s0, s1 = _state(11), _state(12)
    r0 = eng.submit(ForecastRequest(program=prog, state=s0, steps=4))
    r1 = eng.submit(ForecastRequest(program=prog, state=s1, steps=3))
    res = eng.drain()
    st = eng.stats()
    assert st["fingerprint_divergence"] == 1
    assert res[r0].status == "failed"
    d = res[r0].diagnosis
    assert d["reason"] == "fingerprint_divergence"
    assert d["expected_fp"] != d["observed_fp"]
    assert res[r1].status == "ok"
    _assert_bits(res[r1], s1, prog)


def test_guard_off_lets_wire_corruption_through():
    """guard=False documents what the fingerprint buys: the same
    corruption flows into an `ok` result."""
    inj = FaultInjector([FaultSpec(kind="wire_corrupt", round=1, slot=0)])
    prog = StencilProgram(grid_shape=GRID, ensemble=1, variant="kstep",
                          k_steps=2)
    eng = ForecastEngine(slots=2, guard=False, fault_injector=inj)
    r0 = eng.submit(ForecastRequest(program=prog, state=_state(13), steps=4))
    eng.submit(ForecastRequest(program=prog, state=_state(14), steps=3))
    res = eng.drain()
    assert res[r0].status == "ok"
    assert eng.stats()["fingerprint_divergence"] == 0


# ---------------------------------------------------------------------------
# Straggler: the round-deadline watchdog
# ---------------------------------------------------------------------------


def test_straggler_hits_round_deadline_and_recovers():
    """A hung collective (straggler sleep > round_deadline_s) counts as a
    failed attempt: the watchdog records the overrun, the retry serves
    the round, nothing is lost.  The deadline is armed only after a
    warm-up request so plan compile time never counts against it."""
    inj = FaultInjector([FaultSpec(kind="straggler", round=2, delay_s=0.3)])
    eng = ForecastEngine(slots=1, fault_injector=inj, retry_backoff_s=0.0)
    warm = eng.submit(ForecastRequest(program=PROG, state=_state(20),
                                      steps=2))
    eng.drain()                             # rounds 0..1 compile the plan
    eng.round_deadline_s = 0.05
    s = _state(21)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=3))
    res = eng.drain()
    st = eng.stats()
    assert inj.fired("straggler") == 1
    assert st["round_deadline_hits"] == 1
    assert st["round_retries"] == 1
    assert st["lane_failures"] == 0
    assert res[warm].status == "ok" and res[rid].status == "ok"
    _assert_bits(res[rid], s)


def test_straggler_under_deadline_is_not_flagged():
    inj = FaultInjector([FaultSpec(kind="straggler", round=0,
                                   delay_s=0.01)])
    eng = ForecastEngine(slots=1, fault_injector=inj, round_deadline_s=30.0)
    rid = eng.submit(ForecastRequest(program=PROG, state=_state(22),
                                     steps=2))
    res = eng.drain()
    assert res[rid].status == "ok"
    assert eng.stats()["round_deadline_hits"] == 0


# ---------------------------------------------------------------------------
# Failover mesh candidates (the shape-selection policy)
# ---------------------------------------------------------------------------


def test_failover_meshes_prefers_pattern_preserving_shapes():
    """Survivor shapes are ordered: most devices first, then shapes whose
    sharded-axis pattern matches the dying mesh (the bitwise-safe
    transitions), then taller-y.  With one real device only (1, 1) is
    offered — the policy is exercised at scale in the subprocess tests,
    via the failover detail's to_shape."""
    dev = jax.devices()[:1]
    meshes = domain.failover_meshes(dev, [(4, 16, 16)], like=(2, 2))
    assert [m.devices.shape for m in meshes] == [(1, 1)]
    # no survivors -> no candidates rather than a broken mesh
    assert domain.failover_meshes([], [(4, 16, 16)]) == []


def test_failover_disabled_fails_lane_as_before():
    """failover=False restores the pre-ISSUE-8 contract: a persistent
    loss fails the lane (diagnosed), never silently reshapes the mesh."""
    inj = FaultInjector([FaultSpec(kind="device_loss", round=1,
                                   once=False)])
    eng = ForecastEngine(slots=2, failover=False, max_round_retries=1,
                         retry_backoff_s=0.0, fault_injector=inj)
    rid = eng.submit(ForecastRequest(program=PROG, state=_state(30),
                                     steps=3))
    res = eng.drain()
    st = eng.stats()
    assert st["lane_failures"] == 1 and st["mesh_failovers"] == 0
    assert res[rid].status == "failed"
    assert res[rid].diagnosis["reason"] == "round_failure"
