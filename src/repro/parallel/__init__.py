"""repro.parallel subpackage."""
