"""Declarative dycore programs: spec → plan → launch.

NERO's key design move (paper §4) is separating the *what* — compound
vadvc+hdiff stencils over a field set — from the *how* — a synthesized
dataflow: tiling, line buffers, burst schedule — so the host calls ONE
compiled accelerator action instead of threading per-kernel knobs.  This
module is that split for the Pallas reproduction:

* `DycoreProgram` is the *what*: grid shape, ensemble, field set + halo
  depth, precision policy (state dtype + exchange wire dtype), boundary,
  and the steps-per-round policy (`k_steps`, possibly `"auto"`).
* `compile_dycore(program, mesh=None, ...)` is the planner: it resolves
  the whole execution strategy ONCE — execution variant (per-field /
  whole-state / in-kernel k-step / unfused oracle), the tile plan from
  `core/tiling` (folding the three `plan_tile*` paths into one resolver,
  `kernels/dycore_fused/ops.py::resolve_tile`), the communication-avoiding
  depth (`core/autotune.py::resolve_k_steps`, VMEM-clamped), the ragged
  stacked-exchange schedule (per-operand halo depths, `wcon`'s right-only
  staggering column, wire dtype), and interpret/prefetch resolution.
* `ExecutionPlan` is the *how*, immutable: `plan.step(state)` advances one
  round (`k_steps` timesteps), `plan.run(state, steps)` advances any step
  count (a shorter ragged TAIL round `k' = steps mod k` is compiled on
  demand), and `plan.report()` returns the machine-readable strategy —
  modeled HBM traffic (`core/memmodel`), exchange-model bytes, and the
  structural launch/collective counts that `core/trace_stats` can verify
  against the traced jaxpr — which benchmarks embed verbatim in
  `BENCH_dycore.json`.

The legacy flag-soup entry points (`weather/dycore.py::dycore_step/run`,
`weather/domain.py::make_distributed_step`) survive as deprecated shims
that build a program and call `compile_dycore` under the hood, so every
oracle/equivalence test keeps its meaning bit-for-bit.  New scenarios —
field sets, meshes, dtypes — are a spec change, not another keyword.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core import autotune, memmodel, tiling
from repro.kernels.dycore_fused import ops as fused_ops
from repro.kernels.dycore_fused.fused import (fused_dycore_kstep_pallas,
                                              fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)
from repro.weather import domain as _domain
from repro.weather import dycore as _dycore
from repro.weather.dycore import HALO
from repro.weather.fields import PROGNOSTIC, WeatherState

VARIANTS = ("auto", "unfused", "per_field", "whole_state", "kstep")


@dataclasses.dataclass(frozen=True)
class DycoreProgram:
    """The *what* of a dycore run: field set + grid + policies, no knobs.

    `variant` names the execution strategy, `"auto"` lets the planner pick
    (k-step when `k_steps > 1` resolves, else whole-state).  `k_steps` is
    the steps-per-round policy: a positive int, or `"auto"` to let the
    planner resolve it from the exchange model (distributed; single-chip
    `"auto"` resolves to 1 — there are no collectives to amortize).
    `dtype` is the state/compute precision policy; `exchange_dtype` the
    wire precision of the stacked halo exchange (e.g. `"bfloat16"`)."""

    grid_shape: Tuple[int, int, int]            # (nz, ny, nx)
    ensemble: int = 1
    fields: Tuple[str, ...] = PROGNOSTIC        # field set (fields.py)
    halo: int = HALO                            # stencil reach per step
    dtype: str = "float32"
    boundary: str = "periodic"
    coeff: float = 0.025
    dt: float = 0.1
    variant: str = "auto"
    k_steps: Any = "auto"                       # int or "auto"
    exchange_dtype: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "grid_shape",
                           tuple(int(g) for g in self.grid_shape))
        object.__setattr__(self, "fields", tuple(self.fields))
        # Normalize dtype spellings (jnp.float32, np.dtype, "float32") to
        # the canonical string so plan comparison, _check_state, and
        # report()'s JSON stay consistent.
        object.__setattr__(self, "dtype", str(jnp.dtype(self.dtype)))
        if self.exchange_dtype is not None:
            object.__setattr__(self, "exchange_dtype",
                               str(jnp.dtype(self.exchange_dtype)))
        if len(self.grid_shape) != 3 or min(self.grid_shape) < 1:
            raise ValueError(f"grid_shape={self.grid_shape} must be a "
                             f"positive (nz, ny, nx) triple")
        if not self.fields:
            raise ValueError("a DycoreProgram needs at least one field")
        if self.ensemble < 1:
            raise ValueError(f"ensemble={self.ensemble} must be >= 1")
        if self.boundary != "periodic":
            raise ValueError(f"boundary={self.boundary!r}: only 'periodic' "
                             f"is implemented (the paper's dycore test "
                             f"setup; halo exchange supplies shard edges)")
        if self.halo != HALO:
            raise ValueError(f"halo={self.halo}: the compound kernels have "
                             f"a fixed stencil reach of {HALO} (hdiff needs "
                             f"2, vadvc 1)")
        if self.variant not in VARIANTS:
            raise ValueError(f"variant={self.variant!r} not in {VARIANTS}")
        if self.k_steps != "auto" and (not isinstance(self.k_steps, int)
                                       or self.k_steps < 1):
            raise ValueError(f"k_steps={self.k_steps!r} must be a positive "
                             f"int or 'auto'")
        if (self.variant in ("unfused", "per_field", "whole_state")
                and self.k_steps not in ("auto", 1)):
            raise ValueError(f"variant={self.variant!r} with "
                             f"k_steps={self.k_steps}: k_steps > 1 is the "
                             f"in-kernel k-step strategy — use "
                             f"variant='kstep' (or 'auto')")
        if self.variant == "kstep" and self.k_steps == 1:
            raise ValueError("variant='kstep' needs k_steps >= 2 (or "
                             "'auto'); k_steps=1 IS the whole-state step")

    @property
    def n_fields(self) -> int:
        return len(self.fields)


@dataclasses.dataclass(frozen=True)
class ExchangeSchedule:
    """Resolved halo-exchange strategy of a distributed plan.

    `mode="packed"` is the stacked ragged exchange: every operand shares
    one flattened wire buffer per direction (one `ppermute` pair each);
    the `3·nf` field operands ride at `depth_y`/`depth_x`, `wcon` at its
    own asymmetric x-depth `wcon_depth_x = (left, right)` — the `+1`
    staggering column (`w[c] = wcon[c] + wcon[c+1]`) is needed from the
    RIGHT neighbor only.  `mode="per_operand"` is the legacy per-field
    exchange of the per-field/unfused variants."""

    mode: str                                   # "packed" | "per_operand"
    shards: Tuple[int, int]                     # (py, px)
    depth_y: int
    depth_x: int
    wcon_depth_x: Tuple[int, int]               # (left-pad, right-pad)
    wire_dtype: Optional[str]

    def describe(self) -> Dict[str, Any]:
        return {"mode": self.mode, "shards": list(self.shards),
                "depth_y": self.depth_y, "depth_x": self.depth_x,
                "wcon_depth_x": list(self.wcon_depth_x),
                "wire_dtype": self.wire_dtype}


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The *how*: an immutable, fully-resolved execution strategy.

    Produced by `compile_dycore`; exposes `step(state)` (one round =
    `k_steps` timesteps), `run(state, steps)` (any step count; a shorter
    tail round is compiled for `steps % k_steps`), and `report()` (the
    machine-readable strategy benchmarks embed verbatim)."""

    program: DycoreProgram
    variant: str                                # resolved, never "auto"
    k_steps: int                                # resolved int
    tile_ty: Optional[int]                      # None for unfused
    tile_plan: Optional[tiling.TilePlan]
    local_grid: Tuple[int, int, int]            # per-shard (nz, ly, lx)
    compute_grid: Tuple[int, int, int]          # grid the kernel tiles over
    interpret: bool
    prefetch_w: bool
    exchange: Optional[ExchangeSchedule]        # None on a single chip
    pallas_calls_per_round: int
    collectives_per_round: int
    mesh: Optional[Mesh] = dataclasses.field(default=None, repr=False,
                                             compare=False)
    mesh_axes: Tuple[Optional[str], str, str] = ("pod", "data", "model")
    _cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                     compare=False)

    # -- public API ---------------------------------------------------------
    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    @property
    def state_spec(self) -> Optional[P]:
        """PartitionSpec for `domain.shard_state`; None on a single chip."""
        if self.mesh is None:
            return None
        ax_e, ax_y, ax_x = self.mesh_axes
        have_e = ax_e is not None and ax_e in self.mesh.axis_names
        return P(ax_e if have_e else None, None, ax_y, ax_x)

    def step(self, state: WeatherState) -> WeatherState:
        """Advance ONE round: `k_steps` timesteps in the plan's strategy."""
        self._check_state(state)
        return self._step_fn()(state)

    def run(self, state: WeatherState, steps: int) -> WeatherState:
        """Advance `steps` timesteps: `steps // k_steps` full rounds plus,
        when `steps % k_steps != 0`, one shorter TAIL round at
        `k' = steps mod k_steps` (a derived plan, compiled on demand) —
        no step count is rejected."""
        if not isinstance(steps, int) or steps < 0:
            raise ValueError(f"steps={steps!r} must be a non-negative int")
        self._check_state(state)
        rounds, tail = divmod(steps, self.k_steps)
        if rounds:
            if self.mesh is None:
                state = self._rounds_fn(rounds)(state)
            else:
                # Deliberately a Python loop, not a scan: each round is one
                # jitted shard_map program, which keeps run() composable
                # with host-side work between rounds (checkpoints, I/O) and
                # keeps the traced round — what the structural tests and
                # report() describe — the unit of execution.
                step = self._step_fn()
                for _ in range(rounds):
                    state = step(state)
        if tail:
            state = self._tail_plan(tail).step(state)
        return state

    def report(self) -> Dict[str, Any]:
        """Machine-readable strategy: the resolved variant/tile/k/exchange,
        the structural launch/collective counts per round (verifiable
        against a traced jaxpr via `trace_stats.assert_plan_structure`),
        and the modeled HBM-traffic / exchange-model numbers.  Plain
        JSON-serializable types only — benchmarks embed it verbatim."""
        prog = self.program
        rep: Dict[str, Any] = {
            "program": {
                "grid_shape": list(prog.grid_shape),
                "ensemble": prog.ensemble,
                "fields": list(prog.fields),
                "halo": prog.halo,
                "dtype": prog.dtype,
                "boundary": prog.boundary,
                "coeff": prog.coeff,
                "dt": prog.dt,
                "variant": prog.variant,
                "k_steps": prog.k_steps,
                "exchange_dtype": prog.exchange_dtype,
            },
            "variant": self.variant,
            "k_steps": self.k_steps,
            "tile": (None if self.tile_plan is None
                     else {"ty": self.tile_ty, **self.tile_plan.describe()}),
            "interpret": self.interpret,
            "prefetch_w": self.prefetch_w,
            "distributed": self.distributed,
            "mesh_axes": list(self.mesh_axes),
            "local_grid": list(self.local_grid),
            "compute_grid": list(self.compute_grid),
            "exchange": (None if self.exchange is None
                         else self.exchange.describe()),
            "pallas_calls_per_round": self.pallas_calls_per_round,
            "collectives_per_round": self.collectives_per_round,
        }
        # The traffic model needs a fused tile; unfused plans have none, so
        # model at the whole-state tile the planner WOULD resolve (recorded
        # as traffic_model_ty so the artifact is self-describing; cached —
        # it is an autotune sweep and report() is advertised as cheap).
        model_ty = self.tile_ty
        if model_ty is None:
            model_ty = self._cache.get("traffic_model_ty")
            if model_ty is None:
                model_ty = fused_ops.resolve_tile(
                    "whole_state", self.compute_grid, prog.dtype,
                    prog.n_fields)
                self._cache["traffic_model_ty"] = model_ty
        rep["traffic_model_ty"] = model_ty
        rep["traffic"] = memmodel.dycore_step_traffic(
            prog.grid_shape, prog.dtype, n_fields=prog.n_fields,
            ty=model_ty, k_steps=self.k_steps)
        if (self.exchange is not None and self.exchange.mode == "packed"):
            rep["exchange_model"] = memmodel.kstep_exchange_model(
                prog.grid_shape, prog.dtype, n_fields=prog.n_fields,
                k=self.k_steps, shards=self.exchange.shards, halo=prog.halo,
                exchange_dtype=prog.exchange_dtype)
        else:
            rep["exchange_model"] = None
        return rep

    # -- internals ----------------------------------------------------------
    def _check_state(self, state: WeatherState) -> None:
        if state.grid_shape != self.program.grid_shape:
            raise ValueError(
                f"state grid {state.grid_shape} does not match the "
                f"program's {self.program.grid_shape}; compile a plan for "
                f"this grid")
        if str(state.wcon.dtype) != self.program.dtype:
            raise ValueError(
                f"state dtype {state.wcon.dtype} does not match the "
                f"program's precision policy {self.program.dtype!r}")
        if (state.wcon.ndim == 4
                and int(state.wcon.shape[0]) != self.program.ensemble):
            raise ValueError(
                f"state ensemble {int(state.wcon.shape[0])} does not match "
                f"the program's ensemble={self.program.ensemble} (the "
                f"report() must describe what actually runs)")
        missing = [n for n in self.program.fields if n not in state.fields]
        if missing:
            raise ValueError(f"state is missing program fields {missing}")

    def _step_fn(self):
        fn = self._cache.get("step")
        if fn is None:
            fn = (_build_distributed_step(self) if self.mesh is not None
                  else _build_local_step(self))
            self._cache["step"] = fn
        return fn

    def _rounds_fn(self, rounds: int):
        """Jitted scan of `rounds` full rounds (single-chip), cached per
        round count so repeated `run` calls don't re-trace the scan."""
        fn = self._cache.get(("rounds", rounds))
        if fn is None:
            step = self._step_fn()

            @jax.jit
            def fn(state):
                def body(s, _):
                    return step(s), ()
                out, _ = jax.lax.scan(body, state, (), length=rounds)
                return out
            self._cache[("rounds", rounds)] = fn
        return fn

    def _tail_plan(self, k_tail: int) -> "ExecutionPlan":
        plan = self._cache.get(("tail", k_tail))
        if plan is None:
            prog = dataclasses.replace(self.program, variant="auto",
                                       k_steps=k_tail)
            ax_e, ax_y, ax_x = self.mesh_axes
            plan = compile_dycore(prog, mesh=self.mesh, ax_e=ax_e,
                                  ax_y=ax_y, ax_x=ax_x,
                                  interpret=self.interpret,
                                  prefetch_w=self.prefetch_w)
            self._cache[("tail", k_tail)] = plan
        return plan


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


def compile_dycore(program: DycoreProgram, mesh: Optional[Mesh] = None, *,
                   ax_e: Optional[str] = "pod", ax_y: str = "data",
                   ax_x: str = "model", interpret: Optional[bool] = None,
                   prefetch_w: Optional[bool] = None) -> ExecutionPlan:
    """Resolve `program`'s whole execution strategy once; return the plan.

    With `mesh`, the plan shards y over `ax_y`, x over `ax_x`, the
    ensemble over `ax_e` when present (z always chip-local), and its step
    runs the distributed round: ONE ragged packed halo exchange + the
    chip-local kernel + interior crop.  Overrides: `interpret` (default:
    auto — native Pallas on TPU, interpreter elsewhere) and `prefetch_w`
    (the k-step kernel's double-buffered `w` DMA pipeline; default: on
    outside interpret mode)."""
    if not isinstance(program, DycoreProgram):
        raise TypeError(f"compile_dycore wants a DycoreProgram, got "
                        f"{type(program).__name__}")
    nz, ny, nx = program.grid_shape
    nf = program.n_fields
    halo = program.halo
    if interpret is None:
        interpret = fused_ops._auto_interpret()

    if mesh is not None:
        for ax in (ax_y, ax_x):
            if ax not in mesh.axis_names:
                raise ValueError(f"mesh {dict(mesh.shape)} has no axis "
                                 f"{ax!r}")
        py, px = int(mesh.shape[ax_y]), int(mesh.shape[ax_x])
        if ny % py or nx % px:
            raise ValueError(f"grid (ny={ny}, nx={nx}) does not divide over "
                             f"(py={py}, px={px}) shards")
    else:
        py = px = 1
    ly, lx = ny // py, nx // px

    # --- steps-per-round: the communication-avoiding k (one resolver) ---
    k = program.k_steps
    if k == "auto":
        if program.variant not in ("auto", "kstep") or mesh is None:
            # The variant is pinned to a one-step-per-round strategy (or
            # there are no collectives at all): nothing to amortize.
            k = 1
        else:
            k = autotune.resolve_k_steps(program.grid_shape, program.dtype,
                                         (py, px), n_fields=nf, halo=halo)

    # --- execution variant ---
    variant = program.variant
    if variant == "auto":
        variant = "kstep" if k > 1 else "whole_state"
    if variant == "kstep" and k == 1:
        variant = "whole_state"    # k resolved to 1: same round, one step
    if k > 1 and variant != "kstep":
        raise ValueError(f"k_steps={k} requires the fused whole-state path "
                         f"(variant {variant!r} steps one at a time)")
    if program.exchange_dtype is not None and variant not in ("whole_state",
                                                              "kstep"):
        raise ValueError("exchange_dtype requires the stacked (whole-state) "
                         "exchange path")

    # --- exchange schedule + the grid the kernel actually tiles over ---
    exchange = None
    if mesh is not None:
        if variant in ("whole_state", "kstep"):
            hy = hx = k * halo
            if hy > ly or hx + 1 > lx:
                raise ValueError(
                    f"k_steps={k} needs a ({hy}, {hx + 1})-deep halo but "
                    f"the local slab is only ({ly}, {lx}); use fewer "
                    f"shards, a bigger grid, or a smaller k_steps")
            exchange = ExchangeSchedule(
                mode="packed", shards=(py, px), depth_y=hy, depth_x=hx,
                wcon_depth_x=(hx, hx + 1),
                wire_dtype=program.exchange_dtype)
            compute_grid = (nz, ly + 2 * hy, lx + 2 * hx)
        else:
            exchange = ExchangeSchedule(
                mode="per_operand", shards=(py, px), depth_y=halo,
                depth_x=halo, wcon_depth_x=(0, 1), wire_dtype=None)
            compute_grid = (nz, ly + 2 * halo, lx + 2 * halo)
    else:
        compute_grid = program.grid_shape

    # --- tile plan: ONE resolver for every fused tile space ---
    ty = fused_ops.resolve_tile(variant, compute_grid, program.dtype, nf, k)
    tile_plan = None
    if ty is not None:
        spec = {"per_field": tiling.DYCORE_FUSED,
                "whole_state": tiling.dycore_whole_state_spec(nf),
                "kstep": tiling.dycore_kstep_spec(nf, k)}[variant]
        tile_plan = tiling.TilePlan(op=spec, grid_shape=compute_grid,
                                    tile=(compute_grid[0], ty,
                                          compute_grid[2]),
                                    dtype=str(jnp.dtype(program.dtype)))

    # --- structural costs per round (trace-verifiable, see trace_stats) ---
    pallas_calls = {"unfused": 0, "per_field": nf,
                    "whole_state": 1, "kstep": 1}[variant]
    ey = 2 if py > 1 else 0          # one ppermute pair per active direction
    ex = 2 if px > 1 else 0
    rc = 1 if px > 1 else 0          # wcon's right-column fetch
    if mesh is None:
        collectives = 0
    elif variant in ("whole_state", "kstep"):
        collectives = ey + ex        # the packed exchange: 4 on a 2-D mesh
    elif variant == "per_field":
        # shared staggered-w pad + 3 per-operand pads per field
        collectives = rc + (ey + ex) + nf * 3 * (ey + ex)
    else:                            # unfused: per-field vadvc + hdiff pads
        collectives = nf * (rc + ey + ex)

    resolved_prefetch = (not interpret) if prefetch_w is None else prefetch_w

    return ExecutionPlan(
        program=program, variant=variant, k_steps=k, tile_ty=ty,
        tile_plan=tile_plan, local_grid=(nz, ly, lx),
        compute_grid=compute_grid, interpret=interpret,
        prefetch_w=resolved_prefetch, exchange=exchange,
        pallas_calls_per_round=pallas_calls,
        collectives_per_round=collectives, mesh=mesh,
        mesh_axes=(ax_e, ax_y, ax_x))


# ---------------------------------------------------------------------------
# Lowering: plan -> step callable
# ---------------------------------------------------------------------------


def _build_local_step(plan: ExecutionPlan):
    """Single-chip lowering: the periodic-domain kernels at the plan's
    resolved tile/precision/interpret settings.  Every variant is wrapped
    in ONE jax.jit so a round is a single dispatch (stack/unstack and the
    per-field loop trace into the same computation)."""
    prog = plan.program
    names, coeff, dt = prog.fields, prog.coeff, prog.dt
    variant, ty, interp = plan.variant, plan.tile_ty, plan.interpret
    stack = lambda d: _dycore.stack_state(d, names)
    unstack = lambda a: _dycore.unstack_state(a, names)

    if variant == "unfused":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            new_fields, new_stage = {}, {}
            for name in names:
                f = state.fields[name]
                stage = _dycore.vadvc_field(
                    u_stage=f, wcon=state.wcon, u_pos=f,
                    utens=state.tens[name],
                    utens_stage=state.stage_tens[name])
                f = f + dt * stage
                f = _dycore.hdiff_periodic(f, coeff)
                new_fields[name] = f
                new_stage[name] = stage
            return WeatherState(fields=new_fields, wcon=state.wcon,
                                tens=state.tens, stage_tens=new_stage)
        return step

    if variant == "per_field":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            new_fields, new_stage = {}, {}
            for name in names:
                f_new, stage = fused_ops.fused_step(
                    state.fields[name], state.wcon, state.tens[name],
                    state.stage_tens[name], coeff=coeff, dt=dt, ty=ty,
                    interpret=interp)
                new_fields[name] = f_new
                new_stage[name] = stage
            return WeatherState(fields=new_fields, wcon=state.wcon,
                                tens=state.tens, stage_tens=new_stage)
        return step

    if variant == "whole_state":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            f_new, stage = fused_ops.fused_step_whole_state(
                stack(state.fields), state.wcon, stack(state.tens),
                stack(state.stage_tens), coeff=coeff, dt=dt, ty=ty,
                interpret=interp)
            return WeatherState(fields=unstack(f_new), wcon=state.wcon,
                                tens=state.tens, stage_tens=unstack(stage))
        return step

    k = plan.k_steps

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        f_new, stage = fused_ops.fused_step_kstep(
            stack(state.fields), state.wcon, stack(state.tens),
            stack(state.stage_tens), k_steps=k, coeff=coeff, dt=dt, ty=ty,
            interpret=interp, prefetch_w=plan.prefetch_w)
        return WeatherState(fields=unstack(f_new), wcon=state.wcon,
                            tens=state.tens, stage_tens=unstack(stage))
    return step


def _build_distributed_step(plan: ExecutionPlan):
    """Distributed lowering: halo exchange (per the plan's schedule) +
    chip-local kernel + interior crop, shard_mapped over the mesh.

    See `weather/domain.py` for the exchange primitives and the design
    rationale (NERO's scale-out story)."""
    prog = plan.program
    mesh = plan.mesh
    ax_e, ax_y, ax_x = plan.mesh_axes
    names, nf = prog.fields, prog.n_fields
    coeff, dt, halo = prog.coeff, prog.dt, prog.halo
    k, ty, interp = plan.k_steps, plan.tile_ty, plan.interpret
    py, px = plan.exchange.shards
    spec = plan.state_spec

    def local_step_unfused(fields, wcon, tens, stage_tens):
        new_fields, new_stage = {}, {}
        for name in names:
            f = fields[name]
            stage = _domain._local_vadvc(f, wcon, f, tens[name],
                                         stage_tens[name], ax_x, px)
            f = f + dt * stage
            f = _domain._local_hdiff(f, coeff, ax_y, ax_x, py, px)
            new_fields[name] = f
            new_stage[name] = stage
        return new_fields, new_stage

    def local_step_per_field(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape

        def pad(a):
            a = _domain._exchange(a, ax_y, py, halo, dim=2)
            return _domain._exchange(a, ax_x, px, halo, dim=3)

        # One exchange of the pre-combined staggered velocity serves all
        # fields; the per-field inputs are exchanged so the halo ring's
        # vadvc tendency is recomputed locally.
        wp = pad(_domain._staggered_w(wcon, ax_x, px))
        crop = lambda a: a[:, :, halo:halo + ly, halo:halo + lx]
        new_fields, new_stage = {}, {}
        for name in names:
            f_new, stage = fused_dycore_pallas(
                pad(fields[name]), wp, pad(tens[name]),
                pad(stage_tens[name]), coeff=coeff, dt=dt, ty=ty,
                interpret=interp)
            new_fields[name] = crop(f_new)
            new_stage[name] = crop(stage)
        return new_fields, new_stage

    def local_step_packed(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape
        sched = plan.exchange
        hy, hx = sched.depth_y, sched.depth_x
        # ONE packed exchange per direction covers every operand: fields,
        # slow tendencies, stage tendencies at the k-step stencil reach and
        # raw wcon at its own RAGGED depth — the +1 staggering column
        # (w[c] = wcon[c] + wcon[c+1]) comes from the RIGHT neighbor only,
        # so wcon's x-ride is (hx, hx+1), not a symmetric hx+1.
        stacked = jnp.stack(
            [fields[n] for n in names]
            + [tens[n] for n in names]
            + [stage_tens[n] for n in names], axis=1)
        stacked, wconp = _domain._exchange_packed(
            [(stacked, hy), (wcon, hy)], ax_y, py, dim=-2,
            wire_dtype=sched.wire_dtype)
        stacked, wconp = _domain._exchange_packed(
            [(stacked, hx), (wconp, sched.wcon_depth_x)], ax_x, px, dim=-1,
            wire_dtype=sched.wire_dtype)
        fs, ts, ss = (stacked[:, :nf], stacked[:, nf:2 * nf],
                      stacked[:, 2 * nf:])
        # Staggered velocity on the padded slab — valid everywhere: the
        # right-only extra wcon column supplies the outermost neighbor.
        w = wconp[..., :-1] + wconp[..., 1:]

        if k == 1:
            fs, ss = fused_dycore_whole_state_pallas(
                fs, w, ts, ss, coeff=coeff, dt=dt, ty=ty, interpret=interp)
        else:
            # The WHOLE round in one launch: the kernel iterates the k
            # local steps with state held in VMEM (no scan of launches,
            # no HBM state round-trips between steps).
            fs, ss = fused_dycore_kstep_pallas(
                fs, w, ts, ss, k_steps=k, coeff=coeff, dt=dt, ty=ty,
                interpret=interp, prefetch_w=plan.prefetch_w)
        crop = lambda a: a[..., hy:hy + ly, hx:hx + lx]
        new_fields = {n: crop(fs[:, i]) for i, n in enumerate(names)}
        new_stage = {n: crop(ss[:, i]) for i, n in enumerate(names)}
        return new_fields, new_stage

    local_step = {"unfused": local_step_unfused,
                  "per_field": local_step_per_field,
                  "whole_state": local_step_packed,
                  "kstep": local_step_packed}[plan.variant]
    sharded = _shard_map(local_step, mesh,
                         in_specs=(spec, spec, spec, spec),
                         out_specs=(spec, spec))

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        new_fields, new_stage = sharded(state.fields, state.wcon,
                                        state.tens, state.stage_tens)
        return WeatherState(fields=new_fields, wcon=state.wcon,
                            tens=state.tens, stage_tens=new_stage)

    return step
