"""Hardware-spec subsystem: spec loading, validation, model threading,
deprecation shims, and the measured-autotune persistent cache.

The specs under src/repro/specs/ are the single source of truth for every
machine the perf models can describe; these tests pin (a) the schema
validator's error reporting, (b) the content fingerprint, (c) backward
compatibility of the hierarchy shim and the v5e-default perfmodel path,
(d) the paper's cross-machine table out of `model_by_hardware`, and
(e) the two-process measured-tuning cache round trip with a spy on
`autotune.measure_walltime` (no re-measurement on a cache hit)."""

import json
import os
import subprocess
import sys
import warnings

import pytest

from repro.core import autotune, hwspec, perfmodel, tiling
from repro.weather.program import StencilProgram, compile as compile_program


# ---------------------------------------------------------------- loading

def test_available_specs_and_load():
    names = hwspec.available_specs()
    assert set(names) >= {"tpu_v5e", "power9", "nero_ad9h7"}
    for n in names:
        spec = hwspec.load_spec(n)
        assert spec.name == n
        assert len(spec.fingerprint) == 12
        # load is cached: same object back
        assert hwspec.load_spec(n) is spec


def test_fingerprint_is_content_hash(tmp_path):
    src = os.path.join(hwspec.spec_dir(), "power9.json")
    with open(src) as fh:
        d = json.load(fh)
    with open(tmp_path / "power9.json", "w") as fh:
        json.dump(d, fh)
    copy = hwspec.load_spec("power9", directory=str(tmp_path))
    assert copy.fingerprint == hwspec.load_spec("power9").fingerprint
    d["idle_watts"] = 61.0
    with open(tmp_path / "tweaked.json", "w") as fh:
        json.dump(dict(d, name="tweaked"), fh)
    tweaked = hwspec.load_spec("tweaked", directory=str(tmp_path))
    assert tweaked.fingerprint != copy.fingerprint


def test_spec_name_must_match_filename(tmp_path):
    with open(tmp_path / "mismatch.json", "w") as fh:
        json.dump({"name": "other"}, fh)
    with pytest.raises(hwspec.SpecValidationError):
        hwspec.load_spec("mismatch", directory=str(tmp_path))


def test_default_spec_env(monkeypatch):
    assert hwspec.default_spec_name() == "tpu_v5e"
    monkeypatch.setenv("REPRO_HWSPEC", "power9")
    assert hwspec.default_spec_name() == "power9"
    assert hwspec.default_spec().jax_backend == "cpu"


# ------------------------------------------------------------- validation

def _valid_dict():
    with open(os.path.join(hwspec.spec_dir(), "tpu_v5e.json")) as fh:
        return json.load(fh)


def _level(d, role):
    return next(e for e in d["memory_levels"] if e["role"] == role)


@pytest.mark.parametrize("breakage,field", [
    (lambda d: d.pop("peak_flops"), "peak_flops"),
    (lambda d: d["memory_levels"].remove(_level(d, "main")),
     "memory_levels"),
    (lambda d: _level(d, "main").pop("bandwidth_bytes_per_s"),
     "bandwidth_bytes_per_s"),
    (lambda d: _level(d, "near").__setitem__("capacity_bytes", -1),
     "capacity_bytes"),
    (lambda d: d["kernel_classes"]["streaming"].__setitem__(
        "bw_utilization", 1.5), "kernel_classes.streaming.bw_utilization"),
    (lambda d: d["collective"].pop("latency_s"), "collective.latency_s"),
    (lambda d: d.__setitem__("schema_version", 99), "schema_version"),
    (lambda d: d.__setitem__("idle_watts", 1e6), "idle_watts"),
])
def test_validation_names_bad_field(breakage, field):
    d = _valid_dict()
    breakage(d)
    with pytest.raises(hwspec.SpecValidationError) as exc:
        hwspec.spec_from_dict(d, where="test")
    assert field in str(exc.value)


def test_unknown_kernel_class_name_rejected():
    with pytest.raises(KeyError):
        hwspec.kernel_class_name("warp")


# --------------------------------------------------- hierarchy shim compat

def test_hierarchy_constants_derive_from_v5e_spec():
    from repro.core import hierarchy as hw
    spec = hwspec.load_spec("tpu_v5e")
    assert hw.PEAK_BF16_FLOPS == spec.peak_flops["bfloat16"]
    assert hw.HBM_BW == spec.main.bandwidth_bytes_per_s
    assert hw.VMEM_USABLE == spec.near.capacity_bytes
    assert hw.VMEM_BYTES == spec.near_physical_bytes
    assert hw.CHIP_PEAK_WATTS == spec.peak_watts
    h = hw.tpu_v5e()
    assert h.hbm.capacity_bytes == spec.main.capacity_bytes


def test_power9_deprecation_shims_warn():
    from repro.core import hierarchy as hw
    p9 = hwspec.load_spec("power9")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        flops = hw.POWER9_PEAK_FLOPS
        bw = hw.POWER9_DRAM_BW
    assert flops == p9.peak_flops["float32"]
    assert bw == p9.main.bandwidth_bytes_per_s
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 2
    assert "power9" in str(deps[0].message)
    with pytest.raises(AttributeError):
        hw.POWER9_NONSENSE


# --------------------------------------------------------- model threading

def test_estimate_default_spec_matches_legacy():
    plan = autotune.tune(tiling.HDIFF, (64, 256, 256), "float32").plan
    legacy = perfmodel.estimate(plan)
    v5e = perfmodel.estimate(plan, spec=hwspec.load_spec("tpu_v5e"))
    assert legacy.time_s == v5e.time_s
    assert legacy.gflops == v5e.gflops
    assert legacy.energy_j == v5e.energy_j


def _zeroed(est):
    import dataclasses
    return dataclasses.replace(est, time_s=0.0)


def test_gflops_per_watt_zero_time():
    est = perfmodel.estimate(
        autotune.tune(tiling.HDIFF, (8, 128, 128), "float32").plan)
    assert perfmodel.gflops_per_watt(est) > 0.0
    assert perfmodel.gflops_per_watt(_zeroed(est)) == 0.0


def test_roofline_zero_flop_copy_is_bandwidth_bound():
    plan = autotune.tune(tiling.COPY, (8, 128, 128), "float32").plan
    est = perfmodel.estimate(plan)
    assert plan.op.flops_per_point == 0.0
    assert est.gflops == 0.0
    assert est.bottleneck == "memory"
    assert est.time_s > 0.0
    # copy kernels score as fraction of peak HBM bandwidth, in (0, 1]
    frac = perfmodel.roofline_fraction(est)
    assert 0.0 < frac <= 1.0
    assert perfmodel.roofline_fraction(_zeroed(est)) == 0.0


def test_roofline_zero_time_flop_kernel():
    est = perfmodel.estimate(
        autotune.tune(tiling.HDIFF, (8, 128, 128), "float32").plan)
    assert perfmodel.roofline_fraction(est) > 0.0
    assert perfmodel.roofline_fraction(_zeroed(est)) == 0.0


def test_kernel_class_assignment():
    assert hwspec.kernel_class_name(tiling.HDIFF) == "streaming"
    assert hwspec.kernel_class_name(tiling.VADVC) == "solver"
    p9 = hwspec.load_spec("power9")
    tuned = autotune.tune(tiling.VADVC, (64, 256, 256), "float32", spec=p9)
    est = perfmodel.estimate(tuned.plan, spec=p9)
    assert est.hardware == "power9"
    assert est.kernel_class == "solver"
    # solver class carries a measured wall-power calibration
    watts = est.energy_j / est.time_s
    assert watts == pytest.approx(p9.kernel_class("solver").watts)


def test_program_hardware_field_validated():
    with pytest.raises(ValueError):
        StencilProgram(grid_shape=(4, 16, 16), hardware="cray1")
    prog = StencilProgram(grid_shape=(4, 16, 16), hardware="power9")
    plan = compile_program(prog, interpret=True)
    rep = plan.report()
    assert rep["program"]["hardware"] == "power9"
    assert rep["model"]["hardware"] == "power9"
    assert rep["model"]["spec_fingerprint"] == \
        hwspec.load_spec("power9").fingerprint


def test_model_by_hardware_reproduces_paper_table():
    plan = compile_program(StencilProgram(grid_shape=(4, 16, 16)),
                           interpret=True)
    mbh = plan.model_by_hardware((64, 256, 256))
    assert set(mbh["specs"]) == set(hwspec.available_specs())
    assert mbh["baseline"] == "power9"
    for kernel in ("hdiff", "vadvc"):
        rows = mbh["kernels"][kernel]
        t_p9 = rows["power9"]["time_us"]
        assert rows["power9"]["speedup_vs_power9"] == pytest.approx(1.0)
        for name, row in rows.items():
            # speedup is arithmetic over the same table's times
            assert row["speedup_vs_power9"] == pytest.approx(
                t_p9 / row["time_us"], rel=1e-6)
    # the paper's headline numbers (Table: NERO vs POWER9)
    hd = mbh["kernels"]["hdiff"]["nero_ad9h7"]
    va = mbh["kernels"]["vadvc"]["nero_ad9h7"]
    assert hd["speedup_vs_power9"] == pytest.approx(12.7, rel=0.15)
    assert hd["gflops_per_watt"] == pytest.approx(21.01, rel=0.15)
    assert va["speedup_vs_power9"] == pytest.approx(5.3, rel=0.15)
    assert va["gflops_per_watt"] == pytest.approx(1.61, rel=0.15)
    assert mbh["kernels"]["hdiff"]["power9"]["gflops"] == \
        pytest.approx(58.5, rel=0.05)
    assert mbh["kernels"]["vadvc"]["power9"]["gflops"] == \
        pytest.approx(29.1, rel=0.05)


def test_execution_fidelity_block():
    fid = hwspec.execution_fidelity()
    assert fid["spec"] == hwspec.default_spec_name()
    assert fid["spec_fingerprint"] == hwspec.default_spec().fingerprint
    assert isinstance(fid["interpret"], bool)
    assert isinstance(fid["walltime_trustworthy"], bool)
    import jax
    if jax.default_backend() != "tpu":
        assert fid["interpret"] and not fid["walltime_trustworthy"]


# ------------------------------------------------- measured-autotune cache

_TUNE_SNIPPET = r"""
import json
from repro.core import autotune
calls = {"n": 0}
_real = autotune.measure_walltime
def _spy(fn, repeats=3):
    calls["n"] += 1
    return _real(fn, repeats=1)
autotune.measure_walltime = _spy
from repro.weather import program as P
plan = P.compile(P.StencilProgram(grid_shape=(4, 16, 16)), tune="measure")
print("TUNE=" + json.dumps({"tile_ty": plan.tile_ty,
                            "measure_calls": calls["n"],
                            "stats": autotune.TUNE_CACHE_STATS}))
"""


def _tune_subprocess(cache_dir):
    env = dict(os.environ)
    env["REPRO_TUNE_CACHE"] = str(cache_dir)
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _TUNE_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=600)
    for line in r.stdout.splitlines():
        if line.startswith("TUNE="):
            return json.loads(line[len("TUNE="):])
    raise AssertionError(f"tune subprocess failed: {r.stderr[-2000:]}")


def test_measured_tune_persistent_cache_spy(tmp_path):
    first = _tune_subprocess(tmp_path)
    assert first["measure_calls"] > 0
    assert first["stats"] == {"hits": 0, "misses": 1, "stores": 1}
    second = _tune_subprocess(tmp_path)
    assert second["measure_calls"] == 0          # no re-measurement
    assert second["stats"] == {"hits": 1, "misses": 0, "stores": 0}
    assert second["tile_ty"] == first["tile_ty"]
    files = list(tmp_path.iterdir())
    assert len(files) == 1 and files[0].suffix == ".json"


def test_tune_cache_key_depends_on_spec_and_backend():
    v5e = hwspec.load_spec("tpu_v5e")
    p9 = hwspec.load_spec("power9")
    k1 = autotune.tune_cache_key("prog", v5e, "cpu")
    assert k1 == autotune.tune_cache_key("prog", v5e, "cpu")
    assert k1 != autotune.tune_cache_key("prog", p9, "cpu")
    assert k1 != autotune.tune_cache_key("prog", v5e, "tpu")
    assert k1 != autotune.tune_cache_key("prog2", v5e, "cpu")


def test_tune_invalid_mode_rejected():
    with pytest.raises(ValueError):
        compile_program(StencilProgram(grid_shape=(4, 16, 16)),
                        interpret=True, tune="magic")
