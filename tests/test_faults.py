"""Fault-injection harness + supervised-engine failure paths (ISSUE 7).

Every failure mode the serving stack claims to survive is rehearsed here
deterministically: NaN/Inf slot poisoning (quarantine), injected compile
failures (fallback chain), injected device loss (retry with backoff, lane
failure on persistence — mesh FAILOVER lives in test_mesh_failover.py),
checkpoint file corruption (manifest verification), crash-window swap
atomicity, restore fallback past a corrupt newest checkpoint,
bounded-queue backpressure, and per-request deadlines.  CI's chaos job
runs this module under ``-W error::DeprecationWarning``.
"""

import time

import jax
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.ckpt.checkpoint import CheckpointCorruptError
from repro.serve.forecast import (ForecastEngine, ForecastRequest,
                                  QueueFullError)
from repro.testing import faults
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram

GRID = (3, 8, 8)
PROG = StencilProgram(grid_shape=GRID, ensemble=1)


def _state(seed, grid=GRID, dtype="float32"):
    return fields.initial_state(jax.random.PRNGKey(seed), grid, ensemble=1,
                                dtype=dtype)


def _solo(prog, state, steps):
    return wprog.compile(prog).run(state, steps)


def _assert_bits(result, state):
    want = _solo(result.program, state, result.steps)
    for name in result.program.fields:
        np.testing.assert_array_equal(np.asarray(result.state.fields[name]),
                                      np.asarray(want.fields[name]),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# The harness itself
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="meteor_strike")


def test_injector_poison_is_deterministic():
    """Same (specs, seed) => the same elements poisoned — the whole point
    of a seedable harness."""
    batch = fields.initial_state(jax.random.PRNGKey(0), GRID, ensemble=3)

    def poisoned():
        inj = FaultInjector([FaultSpec(kind="poison_nan", round=0)], seed=9)
        out = inj.poison(batch, "dycore", 0, (0, 1, 2))
        return np.asarray(out.fields["u"]), inj.log[0]["slot"]

    a, slot_a = poisoned()
    b, slot_b = poisoned()
    assert slot_a == slot_b
    np.testing.assert_array_equal(a, b)
    assert np.isnan(a[slot_a]).any()
    # other slots untouched, bitwise
    for s in range(3):
        if s != slot_a:
            np.testing.assert_array_equal(a[s],
                                          np.asarray(batch.fields["u"][s]))


def test_injector_once_retires_spec():
    inj = FaultInjector([FaultSpec(kind="device_loss", round=1)])
    inj.on_round("dycore", 0)                    # wrong round: no fire
    with pytest.raises(faults.InjectedDeviceLoss):
        inj.on_round("dycore", 1)
    inj.on_round("dycore", 1)                    # spec spent: no fire
    assert inj.fired("device_loss") == 1


# ---------------------------------------------------------------------------
# Compile fallback chain
# ---------------------------------------------------------------------------


def test_compile_with_fallback_stages():
    def fail(stages):
        def hook(prog, stage):
            if stage in stages:
                raise faults.InjectedCompileError(stage)
        return hook

    plan, fb, errors = wprog.compile_with_fallback(PROG)
    assert fb is None and errors == []

    plan, fb, errors = wprog.compile_with_fallback(
        PROG, attempt_hook=fail({"native"}))
    assert fb == "interpret" and plan.interpret
    assert [s for s, _ in errors] == ["native"]

    plan, fb, errors = wprog.compile_with_fallback(
        PROG, attempt_hook=fail({"native", "interpret"}))
    assert fb == "reference"
    assert plan.variant == "unfused" and plan.k_steps == 1

    with pytest.raises(RuntimeError, match="exhausted"):
        wprog.compile_with_fallback(
            PROG, attempt_hook=fail({"native", "interpret", "reference"}))


def test_reference_program_is_conservative():
    prog = StencilProgram(grid_shape=GRID, ensemble=1, variant="kstep",
                          k_steps=2, exchange_dtype="bfloat16")
    ref = wprog.reference_program(prog)
    assert ref.variant == "unfused" and ref.k_steps == 1
    assert ref.exchange_dtype is None
    wprog.compile(ref)                           # must be compilable


def test_engine_forced_lowering_fallback_bit_identical():
    """An injected native-compile failure degrades to the interpreter —
    on CPU the identical plan — and every result stays bit-identical."""
    inj = FaultInjector([FaultSpec(kind="compile_fail", op="dycore",
                                   attempt="native")])
    eng = ForecastEngine(slots=2, fault_injector=inj)
    sts = [_state(40 + i) for i in range(3)]
    rids = [eng.submit(ForecastRequest(program=PROG, state=s, steps=2))
            for s in sts]
    res = eng.drain()
    assert eng.stats()["fallback_compiles"] == 1
    assert eng.stats()["plan_fallbacks"] == {"dycore": "interpret"}
    assert inj.fired("compile_fail") == 1
    for rid, s in zip(rids, sts):
        assert res[rid].status == "ok"
        _assert_bits(res[rid], s)


# ---------------------------------------------------------------------------
# Device loss: transient retry, persistent lane failure
# ---------------------------------------------------------------------------


def test_transient_device_loss_retries_and_serves():
    inj = FaultInjector([FaultSpec(kind="device_loss", round=1)])
    eng = ForecastEngine(slots=2, retry_backoff_s=0.0, fault_injector=inj)
    sts = [_state(50 + i) for i in range(2)]
    rids = [eng.submit(ForecastRequest(program=PROG, state=s, steps=3))
            for s in sts]
    res = eng.drain()
    assert eng.stats()["round_retries"] == 1
    assert eng.stats()["lane_failures"] == 0
    for rid, s in zip(rids, sts):
        assert res[rid].status == "ok"
        _assert_bits(res[rid], s)


def test_persistent_device_loss_fails_lane_not_engine():
    """A fault that survives every retry fails ONLY the lane's in-flight
    requests (each with a round_failure diagnosis) — the engine itself
    keeps draining and stays usable."""
    inj = FaultInjector([FaultSpec(kind="device_loss", round=1, once=False)])
    eng = ForecastEngine(slots=2, max_round_retries=1, retry_backoff_s=0.0,
                         fault_injector=inj)
    sts = [_state(60 + i) for i in range(2)]
    rids = [eng.submit(ForecastRequest(program=PROG, state=s, steps=3))
            for s in sts]
    res = eng.drain()
    assert not eng.has_work()
    assert eng.stats()["lane_failures"] == 1
    for rid in rids:
        assert res[rid].status == "failed"
        assert res[rid].diagnosis["reason"] == "round_failure"
        assert "InjectedDeviceLoss" in res[rid].diagnosis["error"]
    # the engine is still alive once the fault clears ("device replaced"):
    inj.specs.clear()
    s = _state(70)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=2))
    r = eng.drain()[rid]
    assert r.status == "ok"
    _assert_bits(r, s)


# ---------------------------------------------------------------------------
# Guard + quarantine
# ---------------------------------------------------------------------------


def test_poisoned_field_diagnosis_names_the_leaf():
    inj = FaultInjector([FaultSpec(kind="poison_inf", round=0, slot=0,
                                   field="u")])
    eng = ForecastEngine(slots=1, fault_injector=inj)
    s = _state(80)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=4))
    r = eng.drain()[rid]
    assert r.status == "failed"
    d = r.diagnosis
    assert d["reason"] == "validity_guard"
    assert set(d["bad_leaves"]) == {"fields/u"}
    assert d["bad_leaves"]["fields/u"]["inf"] > 0
    assert d["first_bad"] == "fields/u"
    assert r.steps_done < r.steps
    assert eng.stats()["quarantined"] == 1


def test_guard_bounds_catch_nonfinite_free_blowup():
    """The guard is a physics bound, not just isfinite: huge-but-finite
    values quarantine too."""
    eng = ForecastEngine(slots=1, guard_limit=10.0)   # tight physics bound
    s = _state(81)
    big = jax.tree_util.tree_map(lambda a: a * 1e3, s)
    rid = eng.submit(ForecastRequest(program=PROG, state=big, steps=2))
    r = eng.drain()[rid]
    assert r.status == "failed"
    assert r.diagnosis["reason"] == "validity_guard"
    bad = r.diagnosis["bad_leaves"]
    assert any(v["out_of_bounds"] > 0 for v in bad.values()), bad


def test_guard_off_returns_poison_as_ok():
    """guard=False is the unsupervised engine: poison flows through to the
    result (status 'ok', NaNs and all) — documents what the guard buys."""
    inj = FaultInjector([FaultSpec(kind="poison_nan", round=0, slot=0)])
    eng = ForecastEngine(slots=1, guard=False, fault_injector=inj)
    s = _state(82)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=2))
    r = eng.drain()[rid]
    assert r.status == "ok"
    assert any(np.isnan(np.asarray(a)).any()
               for a in jax.tree_util.tree_leaves(r.state))


# ---------------------------------------------------------------------------
# Backpressure + deadlines
# ---------------------------------------------------------------------------


def test_bounded_queue_backpressure():
    eng = ForecastEngine(slots=1, max_queue=2)
    for i in range(2):
        eng.submit(ForecastRequest(program=PROG, state=_state(90 + i),
                                   steps=1))
    with pytest.raises(QueueFullError, match="queue is full"):
        eng.submit(ForecastRequest(program=PROG, state=_state(93), steps=1))
    assert eng.stats()["rejected"] == 1
    eng.drain()                                  # queue drains; space again
    eng.submit(ForecastRequest(program=PROG, state=_state(94), steps=1))
    with pytest.raises(ValueError, match="max_queue"):
        ForecastEngine(slots=1, max_queue=0)


def test_deadline_expires_queued_and_in_flight():
    eng = ForecastEngine(slots=1)
    s0, s1 = _state(95), _state(96)
    # r0's budget outlives admission (sub-ms) but not a 1000-step run
    r0 = eng.submit(ForecastRequest(program=PROG, state=s0, steps=1000,
                                    deadline_s=0.2))
    r1 = eng.submit(ForecastRequest(program=PROG, state=s1, steps=1,
                                    deadline_s=1e-6))
    eng.pump()             # admits r0; r1 sits behind it in the queue
    time.sleep(0.25)       # r0's wall-clock budget runs out mid-flight
    res = eng.drain()
    assert res[r0].status == "expired"
    assert res[r0].diagnosis["where"] == "in_flight"
    assert 0 < res[r0].steps_done < res[r0].steps
    # r1 sat behind it in the queue and expires there
    assert res[r1].status == "expired"
    assert res[r1].diagnosis["where"] == "queue"
    assert eng.stats()["deadline_expired"] == 2
    with pytest.raises(ValueError, match="deadline_s"):
        ForecastRequest(program=PROG, state=s0, steps=1,
                        deadline_s=-1.0).validate()


# ---------------------------------------------------------------------------
# Checkpoint integrity (manifest + CheckpointCorruptError)
# ---------------------------------------------------------------------------


def _tree():
    return {"a": np.arange(512, dtype=np.float32).reshape(4, 128),
            "b": np.full((64,), 2.5, np.float32)}


def test_checkpoint_manifest_roundtrip(tmp_path):
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree(), extra={"k": 1})
    meta = ckpt.read_meta(d, 0)
    assert set(meta["manifest"]) == {"a", "b"}
    for ent in meta["manifest"].values():
        assert {"crc32", "nbytes", "shape", "dtype"} <= set(ent)
    tree, extra = ckpt.restore_tree(d, 0, _tree())
    assert extra == {"k": 1}
    np.testing.assert_array_equal(np.asarray(tree["a"]), _tree()["a"])


@pytest.mark.parametrize("mode", ["truncate", "bitflip"])
def test_corrupt_checkpoint_raises_named_error(tmp_path, mode):
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree(), extra=None)
    faults.corrupt_checkpoint(d, 0, mode, seed=3)
    with pytest.raises(CheckpointCorruptError) as ei:
        ckpt.restore_tree(d, 0, _tree())
    msg = str(ei.value)
    # the error names WHAT is bad: a specific entry or the archive itself
    assert ("entry" in msg and ("'a'" in msg or "'b'" in msg)) \
        or "arrays.npz" in msg, msg


def test_corrupt_engine_checkpoint_fails_loud(tmp_path):
    """End-to-end through the engine: a corrupted engine checkpoint must
    raise CheckpointCorruptError from restore(), not resume on garbage."""
    d = str(tmp_path)
    eng = ForecastEngine(slots=1, ckpt_dir=d)
    eng.submit(ForecastRequest(program=PROG, state=_state(97), steps=3))
    eng.pump()
    step = eng.checkpoint()
    faults.corrupt_checkpoint(d, step, "bitflip", seed=5)
    with pytest.raises(CheckpointCorruptError):
        ForecastEngine.restore(d, step)


def test_legacy_checkpoint_without_manifest_still_loads(tmp_path):
    """Pre-manifest checkpoints (no integrity sidecar) load unverified —
    upgrading must not strand old snapshots."""
    import json, os
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree(), extra={"old": True})
    meta_path = os.path.join(d, "step_00000000", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["manifest"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    tree, extra = ckpt.restore_tree(d, 0, _tree())
    assert extra == {"old": True}


# ---------------------------------------------------------------------------
# Atomic swap: the crash window must never eat BOTH checkpoints
# ---------------------------------------------------------------------------


def test_swap_crash_window_keeps_previous_checkpoint(tmp_path, monkeypatch):
    """Kill the writer between 'rename old aside' and 'rename tmp in'
    (the worst point of the swap): the previous checkpoint must survive —
    reinstated by the recovery sweep on the next listing — instead of
    being rmtree'd before its replacement landed."""
    import os
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree(), extra={"v": 1})
    real_rename = os.rename

    def dying_rename(src, dst):
        if src.endswith(".tmp"):
            raise OSError("simulated crash mid-swap")
        return real_rename(src, dst)

    monkeypatch.setattr(ckpt.os, "rename", dying_rename)
    two = {k: v + 100.0 for k, v in _tree().items()}
    with pytest.raises(OSError, match="mid-swap"):
        ckpt.save_tree(d, 0, two, extra={"v": 2})
    monkeypatch.undo()
    # On disk now: step_0.old (complete, v1) + step_0.tmp; no step_0.
    # all_steps' recovery sweep reinstates the .old.
    assert ckpt.all_steps(d) == [0]
    tree, extra = ckpt.restore_tree(d, 0, _tree())
    assert extra == {"v": 1}
    np.testing.assert_array_equal(np.asarray(tree["a"]), _tree()["a"])


def test_all_steps_ignores_stray_dirs_and_drops_spent_old(tmp_path):
    """Strict step parsing: `step_*.tmp` (mid-save crash), `step_abc`
    (foreign junk), and incomplete `step_*` dirs neither crash the int()
    parse nor show up as restorable steps; a `.old` left by a swap that
    died pre-delete (complete final present) is garbage-collected."""
    import os, shutil
    d = str(tmp_path)
    ckpt.save_tree(d, 3, _tree())
    os.makedirs(os.path.join(d, "step_00000007.tmp"))
    os.makedirs(os.path.join(d, "step_abc"))
    os.makedirs(os.path.join(d, "step_00000009"))   # no meta.json: torn
    with open(os.path.join(d, "notes.txt"), "w") as f:
        f.write("not a checkpoint")
    final = os.path.join(d, "step_00000003")
    shutil.copytree(final, final + ".old")          # swap died pre-delete
    assert ckpt.all_steps(d) == [3]
    assert ckpt.latest_step(d) == 3
    assert not os.path.exists(final + ".old")       # spent .old swept
    assert os.path.isdir(os.path.join(d, "step_00000007.tmp"))  # untouched


# ---------------------------------------------------------------------------
# Format drift: actionable errors, not KeyError
# ---------------------------------------------------------------------------


def test_read_meta_on_garbled_json_is_actionable(tmp_path):
    import os
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree())
    path = os.path.join(d, "step_00000000", "meta.json")
    with open(path, "w") as f:
        f.write('{"step": 0, "manifes')          # torn mid-write
    with pytest.raises(CheckpointCorruptError, match="unreadable"):
        ckpt.read_meta(d, 0)
    with open(path, "w") as f:
        f.write('[1, 2, 3]')                     # foreign file
    with pytest.raises(CheckpointCorruptError, match="not a JSON object"):
        ckpt.read_meta(d, 0)
    with pytest.raises(FileNotFoundError):
        ckpt.read_meta(d, 99)


def test_manifest_entry_missing_fields_is_actionable(tmp_path):
    """A manifest written by a drifted/corrupted writer (entry lacking
    crc32/nbytes) must raise CheckpointCorruptError naming the entry and
    the missing fields — not KeyError deep in verification."""
    import json, os
    d = str(tmp_path)
    ckpt.save_tree(d, 0, _tree())
    path = os.path.join(d, "step_00000000", "meta.json")
    with open(path) as f:
        meta = json.load(f)
    del meta["manifest"]["a"]["crc32"]
    with open(path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointCorruptError,
                       match="'a'.*missing required fields"):
        ckpt.restore_tree(d, 0, _tree())


# ---------------------------------------------------------------------------
# Restore safety
# ---------------------------------------------------------------------------


def test_restore_is_mesh_elastic_and_pins_round_strategy(tmp_path):
    """The engine no longer refuses a mesh whose device count differs from
    the writer's: restore is elastic (cross-count subprocess sweeps live
    in test_mesh_failover.py).  Single-chip round-trip here checks the
    sidecar carries the pinned (variant, k_steps) and that restore seeds
    it, plus that `mesh_devices: null` checkpoints restore anywhere."""
    d = str(tmp_path)
    eng = ForecastEngine(slots=1, ckpt_dir=d)
    s = _state(98)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=3))
    eng.pump()
    step = eng.checkpoint()
    meta = ckpt.read_meta(d, step)
    assert meta["extra"]["mesh_devices"] is None
    pin = meta["extra"]["lanes"][0]["plan"]
    assert pin is not None and {"variant", "k_steps"} <= set(pin)
    eng2 = ForecastEngine.restore(d, step)
    assert eng2._pinned[next(iter(eng2._lanes))] == pin
    r = eng2.drain()[rid]
    assert r.status == "ok"
    _assert_bits(r, s)


def test_restore_latest_falls_back_past_corrupt_newest(tmp_path):
    """restore(step=None) must not die because the NEWEST checkpoint is
    rotten: it falls back to the next-older valid one, and raises one
    aggregated CheckpointCorruptError only when every step is bad."""
    d = str(tmp_path)
    eng = ForecastEngine(slots=1, ckpt_dir=d)
    s = _state(101)
    rid = eng.submit(ForecastRequest(program=PROG, state=s, steps=4))
    eng.pump()
    step_a = eng.checkpoint()
    eng.pump()
    step_b = eng.checkpoint()
    assert step_b > step_a
    faults.corrupt_checkpoint(d, step_b, "bitflip", seed=5)
    eng2 = ForecastEngine.restore(d)          # silently skips step_b
    r = eng2.drain()[rid]
    assert r.status == "ok"
    _assert_bits(r, s)
    faults.corrupt_checkpoint(d, step_a, "truncate")
    with pytest.raises(CheckpointCorruptError, match="every checkpoint"):
        ForecastEngine.restore(d)


def test_restore_incompatible_engine_sidecar_is_actionable(tmp_path):
    """A meta.json whose engine sidecar is missing fields (incompatible
    writer / truncated extra) raises CheckpointCorruptError naming the
    problem — which also lets restore-from-latest fall back past it."""
    import json, os
    d = str(tmp_path)
    eng = ForecastEngine(slots=1, ckpt_dir=d)
    eng.submit(ForecastRequest(program=PROG, state=_state(102), steps=2))
    eng.pump()
    step = eng.checkpoint()
    meta_path = os.path.join(d, f"step_{step:08d}", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["extra"]["slots"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointCorruptError, match="sidecar"):
        ForecastEngine.restore(d, step)


def test_restore_preserves_supervision_config(tmp_path):
    d = str(tmp_path)
    eng = ForecastEngine(slots=1, ckpt_dir=d, max_queue=7, guard_limit=123.0,
                         ckpt_every_rounds=5, max_round_retries=4,
                         retry_backoff_s=0.01)
    eng.submit(ForecastRequest(program=PROG, state=_state(99), steps=2))
    eng.pump()
    step = eng.checkpoint()
    eng2 = ForecastEngine.restore(d, step)
    assert eng2.max_queue == 7 and eng2.guard_limit == 123.0
    assert eng2.ckpt_every_rounds == 5 and eng2.max_round_retries == 4
    assert eng2.retry_backoff_s == 0.01 and eng2.guard
    res = eng2.drain()
    assert all(r.status == "ok" for r in res.values())
