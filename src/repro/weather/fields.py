"""COSMO-like weather state: prognostic fields on a (nz, ny, nx) grid.

Fields follow the paper's vocabulary: "fields represent atmospheric
components like wind, pressure, velocity, etc. that are required for weather
calculation".  The state is a flat pytree so it shards/checkpoints like any
model params.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PROGNOSTIC = ("u", "v", "t", "pp")   # wind u/v, temperature, pressure pert.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WeatherState:
    """Prognostic fields + vertical contravariant velocity (wcon, staggered
    in x: (nz, ny, nx+1)) + slow tendencies + the running stage tendencies
    that vadvc updates (utens_stage per field)."""

    fields: Dict[str, jnp.ndarray]          # each (E, nz, ny, nx)
    wcon: jnp.ndarray                       # (E, nz, ny, nx); staggered view
                                            # wcon[..., i..i+1] built on use
                                            # (periodic wrap / halo exchange)
    tens: Dict[str, jnp.ndarray]            # slow tendencies, like fields
    stage_tens: Dict[str, jnp.ndarray]      # vadvc-updated tendencies

    def tree_flatten(self):
        keys = tuple(sorted(self.fields))
        leaves = ([self.fields[k] for k in keys] + [self.wcon]
                  + [self.tens[k] for k in keys]
                  + [self.stage_tens[k] for k in keys])
        return leaves, keys

    @classmethod
    def tree_unflatten(cls, keys, leaves):
        n = len(keys)
        fields = dict(zip(keys, leaves[:n]))
        wcon = leaves[n]
        tens = dict(zip(keys, leaves[n + 1:2 * n + 1]))
        stage = dict(zip(keys, leaves[2 * n + 1:]))
        return cls(fields=fields, wcon=wcon, tens=tens, stage_tens=stage)

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        f = next(iter(self.fields.values()))
        return f.shape[-3:]


def zeros_state(grid_shape: Tuple[int, int, int], ensemble: int = 1,
                dtype=jnp.float32,
                names: Tuple[str, ...] = PROGNOSTIC) -> WeatherState:
    """An all-zero state — the empty batch a serving engine admits
    requests into (zeros are a fixed point of the stencils, so idle
    ensemble slots stay finite) and the restore template for checkpointed
    engine state."""
    shape = (ensemble,) + tuple(grid_shape)
    z = lambda: jnp.zeros(shape, jnp.dtype(dtype))
    return WeatherState(fields={n: z() for n in names}, wcon=z(),
                        tens={n: z() for n in names},
                        stage_tens={n: z() for n in names})


def _smooth_noise(key, shape, dtype) -> jnp.ndarray:
    """Band-limited random field (atmosphere-ish smoothness): random coarse
    grid, trilinear-resized up."""
    coarse = tuple(max(2, s // 8) for s in shape[-3:])
    x = jax.random.normal(key, shape[:-3] + coarse, jnp.float32)
    x = jax.image.resize(x, shape, method="trilinear")
    return x.astype(dtype)


def initial_state(key, grid_shape: Tuple[int, int, int], ensemble: int = 1,
                  dtype=jnp.float32) -> WeatherState:
    nz, ny, nx = grid_shape
    keys = jax.random.split(key, 3 * len(PROGNOSTIC) + 1)
    shape = (ensemble, nz, ny, nx)
    fields = {f: _smooth_noise(keys[i], shape, dtype)
              for i, f in enumerate(PROGNOSTIC)}
    tens = {f: 0.01 * _smooth_noise(keys[len(PROGNOSTIC) + i], shape, dtype)
            for i, f in enumerate(PROGNOSTIC)}
    stage = {f: jnp.zeros(shape, dtype) for f in PROGNOSTIC}
    # wcon: vertical velocity scaled so the implicit solve is well conditioned
    # (physically |wcon·dt/dz| << 1).
    wcon = 0.15 * _smooth_noise(keys[-1], (ensemble, nz, ny, nx), dtype)
    return WeatherState(fields=fields, wcon=wcon, tens=tens, stage_tens=stage)
