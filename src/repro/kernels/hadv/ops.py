"""Jitted public entry points for hadv_upwind (planner-aware dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune, tiling
from repro.kernels.hadv import ref as _ref
from repro.kernels.hadv.hadv import hadv_pallas

HALO = 1   # one-sided (low-side) reach in y and x


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the Pallas kernel, snapped to a divisor."""
    tuned = autotune.tune_named("hadv_upwind", grid_shape, dtype)
    return tiling.snap_to_divisor(tuned.plan.tile[1], grid_shape[1], lo=1)


def resolve_tile(grid_shape, dtype) -> tiling.TilePlan:
    """Planner entry (`weather/program.py::compile`): the auto-tuned,
    snapped y-window as a full `TilePlan` over the hadv tile space."""
    ty = plan_tile(grid_shape, dtype)
    return tiling.TilePlan(op=autotune.get_op("hadv_upwind"),
                           grid_shape=tuple(int(g) for g in grid_shape),
                           tile=(1, ty, int(grid_shape[2])),
                           dtype=str(jnp.dtype(dtype)))


@functools.partial(jax.jit, static_argnames=("cfl", "use_pallas", "ty",
                                             "interpret"))
def hadv_upwind(src: jnp.ndarray, cfl: float = _ref.DEFAULT_CFL,
                use_pallas: bool = False, ty: int = 0,
                interpret: bool = True) -> jnp.ndarray:
    if use_pallas:
        ty = ty or plan_tile(src.shape, src.dtype)
        return hadv_pallas(src, cfl=cfl, ty=ty, interpret=interpret)
    return _ref.hadv_upwind(src, cfl=cfl)
