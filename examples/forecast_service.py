"""Forecast-as-a-service demo: concurrent requests through ForecastEngine.

Submits a mix of forecast requests — different stencil programs, member
initial conditions, step counts, precisions — to one engine.  The engine
compiles each distinct program ONCE (plan cache), folds admitted requests
into the ensemble axis of the shared plan (continuous batching), retires
each request at the round boundary where its step count completes, and
backfills the freed slot from the queue.  Every served result is
bit-identical to a solo `compile(program).run(state, steps)`.

`--chaos` turns on the supervision demo (docs/robustness.md): a NaN
poison and a transient device loss are injected mid-run; the engine
quarantines the poisoned request (with a per-field diagnosis), retries
through the device loss, and serves everyone else bit-identically.

`--kill-device N` runs the mesh-failover drill instead: the engine
serves on a 2x2 mesh, device N dies *persistently* at round 1, and the
engine rebuilds a mesh from the survivors, reshards, and finishes every
in-flight request — printed as a before/after mesh line and a
preserved-request table with a bit-for-bit check against a solo run on
the original mesh.  (Re-execs itself with 4 forced host devices when the
process has fewer.)

Run:  PYTHONPATH=src python examples/forecast_service.py
      PYTHONPATH=src python examples/forecast_service.py \
          --slots 4 --requests 10 --ckpt /tmp/forecast_ckpt
      PYTHONPATH=src python examples/forecast_service.py --chaos
      PYTHONPATH=src python examples/forecast_service.py --kill-device 3
"""

import argparse
import os
import sys

import jax

from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram


def kill_device_demo(args):
    """Mesh-failover drill: persistent device loss mid-flight."""
    import numpy as np

    kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
          if hasattr(jax.sharding, "AxisType") else {})
    mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
    inj = FaultInjector([FaultSpec(kind="device_loss", round=1,
                                   device=args.kill_device, once=False)],
                        seed=0)
    eng = ForecastEngine(slots=args.slots, mesh=mesh, ax_y="data",
                         ax_x="model", fault_injector=inj)
    catalog = (StencilProgram(grid_shape=(4, 16, 16), op="dycore"),
               StencilProgram(grid_shape=(3, 8, 8), op="hdiff"))
    print(f"== mesh-failover drill: device {args.kill_device} dies "
          f"persistently at round 1, {args.requests} requests in flight ==")
    print(f"before: mesh 2x2 on devices "
          f"{[int(d.id) for d in mesh.devices.flat]}")
    inputs = {}
    for i in range(args.requests):
        prog = catalog[i % len(catalog)]
        state = fields.initial_state(jax.random.PRNGKey(i),
                                     prog.grid_shape, ensemble=1)
        rid = eng.submit(ForecastRequest(program=prog, state=state,
                                         steps=3 + 2 * (i % 2)))
        inputs[rid] = (prog, state)

    results = eng.drain()
    s = eng.stats()
    fo = s["failovers"][0] if s["failovers"] else None
    if fo is None:
        print("no failover happened — was the device id on the mesh?")
    else:
        print(f"after:  mesh {fo['to_shape'][0]}x{fo['to_shape'][1]} on "
              f"devices {fo['to_devices']} (lost device "
              f"{fo['lost_device']} at round {fo['round']}, reshard "
              f"{fo['reshard_ms']:.1f} ms)")
    print(f"{'rid':>3} {'op':>6} {'steps':>5} {'rounds':>6} "
          f"{'status':>6} {'bits_vs_original_mesh':>22}")
    for rid in sorted(results):
        r, (prog, state) = results[rid], inputs[rid]
        want = wprog.compile(prog, mesh=mesh, ax_y="data",
                             ax_x="model").run(state, r.steps)
        same = r.ok and all(
            np.array_equal(np.asarray(r.state.fields[n]),
                           np.asarray(want.fields[n]))
            for n in prog.fields)
        print(f"{rid:>3} {prog.op:>6} {r.steps:>5} {r.rounds:>6} "
              f"{r.status:>6} {'identical' if same else 'DIVERGED':>22}")
        assert same, f"rid={rid} not preserved bit-for-bit"
    print(f"stats: mesh_failovers={s['mesh_failovers']} "
          f"recovery_rounds={s['recovery_rounds']} "
          f"requests_preserved={s['requests_preserved']} "
          f"lane_failures={s['lane_failures']}")
    print("mesh-failover drill OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slots", type=int, default=2,
                    help="ensemble slots per cached plan")
    ap.add_argument("--requests", type=int, default=6,
                    help="number of forecast requests to submit")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir: snapshot the warm engine mid-"
                         "drain and finish from the restored engine")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a NaN poison + a transient device loss "
                         "and show quarantine/retry in action")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded queue: submit() raises QueueFullError "
                         "past this (backpressure)")
    ap.add_argument("--kill-device", type=int, default=None, metavar="N",
                    help="mesh-failover drill: serve on a 2x2 mesh, kill "
                         "device N persistently at round 1, show the "
                         "before/after mesh and the preserved requests")
    args = ap.parse_args()

    if args.kill_device is not None:
        if (jax.device_count() < 4
                and "_FORECAST_DEMO_REEXEC" not in os.environ):
            # the drill needs a 2x2 mesh; re-exec with forced host devices
            env = dict(os.environ, JAX_PLATFORMS="cpu",
                       _FORECAST_DEMO_REEXEC="1",
                       XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                                  + " --xla_force_host_platform_device"
                                    "_count=4").strip())
            os.execve(sys.executable, [sys.executable] + sys.argv, env)
        kill_device_demo(args)
        return

    inj = None
    if args.chaos:
        inj = FaultInjector([FaultSpec(kind="poison_nan", round=1),
                             FaultSpec(kind="device_loss", round=2)],
                            seed=0)

    catalog = (
        StencilProgram(grid_shape=(4, 16, 16), op="dycore"),
        StencilProgram(grid_shape=(4, 16, 16), op="dycore",
                       dtype="bfloat16"),
        StencilProgram(grid_shape=(3, 8, 8), op="hdiff"),
    )
    eng = ForecastEngine(slots=args.slots, ckpt_dir=args.ckpt,
                         max_queue=args.max_queue, fault_injector=inj)
    print(f"== forecast service: {args.requests} requests over "
          f"{len(catalog)} programs, {args.slots} slots ==")
    for i in range(args.requests):
        prog = catalog[i % len(catalog)]
        state = fields.initial_state(jax.random.PRNGKey(i),
                                     prog.grid_shape, ensemble=1,
                                     dtype=prog.dtype)
        rid = eng.submit(ForecastRequest(program=prog, state=state,
                                         steps=2 + 3 * (i % 3)))
        print(f"submitted rid={rid} op={prog.op} dtype={prog.dtype} "
              f"steps={2 + 3 * (i % 3)}")

    if args.ckpt:
        # a few scheduler beats, then snapshot + restore the warm engine:
        # in-flight lane batches, queue, and finished results all survive
        eng.pump()
        step = eng.checkpoint()
        print(f"checkpointed warm engine at step {step} -> {args.ckpt}")
        eng = ForecastEngine.restore(args.ckpt)
        print(f"restored: {eng.stats()['active']} active, "
              f"{eng.stats()['queued']} queued")

    results = eng.drain()
    print(f"{'rid':>3} {'op':>6} {'dtype':>8} {'steps':>5} "
          f"{'rounds':>6} {'wait_ms':>8} {'latency_ms':>10} {'status':>8}")
    for rid in sorted(results):
        r = results[rid]
        print(f"{rid:>3} {r.program.op:>6} {r.program.dtype:>8} "
              f"{r.steps:>5} {r.rounds:>6} {r.queue_wait_s * 1e3:>8.1f} "
              f"{r.latency_s * 1e3:>10.1f} {r.status:>8}")
        if r.diagnosis is not None:
            print(f"     diagnosis: {r.diagnosis.get('reason')} "
                  f"{r.diagnosis.get('bad_leaves', '')}")
    s = eng.stats()
    print(f"stats: plans_cached={s['plans_cached']} "
          f"cache_hit_rate={s['plan_cache_hit_rate']:.2f} "
          f"occupancy={s['occupancy']:.2f} rounds={s['rounds']} "
          f"rolled_back={s['rolled_back_slot_rounds']}")
    if args.chaos:
        print(f"chaos: faults_fired={inj.fired()} "
              f"quarantined={s['quarantined']} "
              f"round_retries={s['round_retries']} "
              f"failed={s['failed']}")
    print("forecast service OK")


if __name__ == "__main__":
    main()
