"""Gemma3-27B — dense LM, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt scaled per assignment; unverified]."""

from repro.configs.base import ModelConfig

# 62 layers = 10 x (5 local + 1 global) + 2 local remainder.
CONFIG = ModelConfig(
    name="gemma3-27b",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, rope_theta=1e6, rope_theta_local=1e4,
    qk_norm=True, sandwich_norm=True,
    norm="rms", gated_mlp=True, act="gelu",
    tie_embeddings=True,
)
