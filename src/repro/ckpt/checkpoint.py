"""Checkpointing: atomic, keep-N, async save; elastic restore.

Layout: <dir>/step_<n>/arrays.npz + meta.json, written to a tmp dir and
swapped in by rename (atomic on POSIX; the previous step dir is renamed
aside, never rmtree'd first, so a crash mid-swap always leaves at least
one complete checkpoint — see `_swap`/`_recover`).  Arrays are saved
*unsharded-logical* (gathered),
so a checkpoint written on one mesh restores onto any other mesh — the
elastic-scaling path: restore() applies the *current* mesh's shardings.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (truncated archive,
    bit-flipped array, missing entry).  The message names the offending
    entry so operators know WHAT rotted, not just that np.load choked."""

# numpy-native dtype names; everything else (bfloat16, fp8s) is stored as a
# same-width unsigned-int view + its name in meta.json (np.load would
# otherwise hand back void dtypes like |V2).
_NATIVE = frozenset(
    "bool int8 int16 int32 int64 uint8 uint16 uint32 uint64 "
    "float16 float32 float64 complex64 complex128".split())


def _pack(arrays: dict) -> Tuple[dict, dict]:
    packed, dtypes = {}, {}
    for k, v in arrays.items():
        name = v.dtype.name
        if name in _NATIVE:
            packed[k] = v
        else:
            packed[k] = v.view(np.dtype(f"u{v.dtype.itemsize}"))
            dtypes[k] = name
    return packed, dtypes


def _unpack(arr: np.ndarray, name: Optional[str]) -> np.ndarray:
    if not name:
        return arr
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def _manifest(packed: dict) -> dict:
    """Per-array integrity manifest over the PACKED (on-disk) arrays:
    crc32 + byte count + shape + stored dtype for every entry."""
    return {k: {"crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
                "nbytes": int(v.nbytes), "shape": list(v.shape),
                "dtype": str(v.dtype)} for k, v in packed.items()}


def _load_verified(base: str) -> Tuple[dict, dict]:
    """Load `base/arrays.npz` + meta, verifying every entry against the
    manifest.  Raises `CheckpointCorruptError` naming the bad entry on a
    truncated file, an unreadable member, or a crc32 mismatch; old
    manifest-less checkpoints load unverified (nothing to check against)."""
    meta_path = os.path.join(base, "meta.json")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {base!r}: meta.json is unreadable ({e})") from e
    manifest = meta.get("manifest")
    dtypes = meta.get("dtypes", {})
    flat = {}
    npz = os.path.join(base, "arrays.npz")
    try:
        with np.load(npz) as z:
            names = list(z.files)
            for k in names:
                try:
                    arr = z[k]
                except Exception as e:
                    raise CheckpointCorruptError(
                        f"checkpoint {base!r}: entry {k!r} is unreadable "
                        f"(truncated or bit-flipped archive member: "
                        f"{e})") from e
                if manifest is not None:
                    want = manifest.get(k)
                    if want is None:
                        raise CheckpointCorruptError(
                            f"checkpoint {base!r}: entry {k!r} is not in "
                            f"the manifest (foreign or stale array)")
                    if (not isinstance(want, dict) or "crc32" not in want
                            or "nbytes" not in want):
                        raise CheckpointCorruptError(
                            f"checkpoint {base!r}: manifest entry for {k!r} "
                            f"is missing required fields (need crc32 + "
                            f"nbytes, have "
                            f"{sorted(want) if isinstance(want, dict) else type(want).__name__}) "
                            f"— written by an incompatible or corrupted "
                            f"writer; re-save the checkpoint or restore an "
                            f"older step")
                    got_crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                    if (got_crc != want["crc32"]
                            or int(arr.nbytes) != want["nbytes"]):
                        raise CheckpointCorruptError(
                            f"checkpoint {base!r}: entry {k!r} fails "
                            f"integrity check (crc32 {got_crc} != manifest "
                            f"{want['crc32']}) — the array was corrupted "
                            f"on disk")
                flat[k] = _unpack(arr, dtypes.get(k))
    except CheckpointCorruptError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint {base!r}: arrays.npz is unreadable (truncated or "
            f"corrupt archive: {e})") from e
    if manifest is not None:
        missing = sorted(set(manifest) - set(flat))
        if missing:
            raise CheckpointCorruptError(
                f"checkpoint {base!r}: manifest entries missing from "
                f"arrays.npz: {missing[:5]}")
    return flat, meta


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(template, flat: dict):
    def one(path, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = flat[key]
        return jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype")
                           else None)
    return jax.tree_util.tree_map_with_path(one, template)


def _swap(tmp: str, final: str) -> None:
    """Promote `tmp` to `final` WITHOUT a window where neither exists.

    The naive `rmtree(final); rename(tmp, final)` loses BOTH the previous
    and the new checkpoint if the process dies between the two calls.
    Instead the previous `final` is renamed aside (rename is atomic on
    POSIX, rmtree is not), the tmp dir takes its place, and only then is
    the old data deleted — a crash at any point leaves at least one
    complete checkpoint on disk (`final`, `final + ".old"`, or both), and
    `_recover` reinstates an orphaned `.old` the next time the directory
    is listed."""
    old = final + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)
    shutil.rmtree(old, ignore_errors=True)


def _recover(ckpt_dir: str) -> None:
    """Sweep crash leftovers: a `step_*.old` whose `step_*` is missing or
    incomplete is a swap that died mid-rename — reinstate it; one whose
    final is complete is a swap that died pre-delete — drop it.  Stray
    `.tmp` dirs are never touched (they may belong to an in-flight
    writer and are ignored by `all_steps` anyway)."""
    for name in os.listdir(ckpt_dir):
        if not name.endswith(".old") or not _STEP_RE.match(name[:-4]):
            continue
        old = os.path.join(ckpt_dir, name)
        final = old[:-4]
        if os.path.exists(os.path.join(final, "meta.json")):
            shutil.rmtree(old, ignore_errors=True)
        elif os.path.exists(os.path.join(old, "meta.json")):
            if os.path.exists(final):      # incomplete final: lose it
                shutil.rmtree(final, ignore_errors=True)
            os.rename(old, final)


def save(ckpt_dir: str, step: int, params, opt_state, keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    packed, dtypes = _pack(arrays)
    np.savez(os.path.join(tmp, "arrays.npz"), **packed)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_arrays": len(arrays),
                   "dtypes": dtypes, "manifest": _manifest(packed)}, f)
    _swap(tmp, final)
    _gc(ckpt_dir, keep)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


_STEP_RE = re.compile(r"^step_(\d+)$")


def all_steps(ckpt_dir: str):
    """Steps with a COMPLETE checkpoint dir.  Strict `step_<digits>`
    matching: stray `step_*.tmp` dirs from a mid-save crash, `.old` dirs
    from a mid-swap crash, and foreign `step_*` junk are all ignored
    rather than crashing the int() parse (orphaned `.old` dirs are first
    reinstated by the crash-recovery sweep)."""
    if not os.path.isdir(ckpt_dir):
        return []
    _recover(ckpt_dir)
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m is not None:
            meta = os.path.join(ckpt_dir, name, "meta.json")
            if os.path.exists(meta):       # complete checkpoints only
                out.append(int(m.group(1)))
    return sorted(out)                     # os.listdir order is fs-dependent


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, mesh, p_shard, o_shard
            ) -> Tuple[Any, Any, int]:
    """Elastic restore: shardings come from the *current* mesh."""
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, _ = _load_verified(base)
    p_flat = {k[len("params/"):]: v for k, v in flat.items()
              if k.startswith("params/")}
    o_flat = {k[len("opt/"):]: v for k, v in flat.items()
              if k.startswith("opt/")}
    params = _unflatten_from_shard_tree(p_shard, p_flat)
    opt = _unflatten_from_shard_tree(o_shard, o_flat)
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt = jax.tree.map(jax.device_put, opt, o_shard)
    return params, opt, step


def _unflatten_from_shard_tree(shard_tree, flat: dict):
    def one(path, _):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return jnp.asarray(flat[key])
    return jax.tree_util.tree_map_with_path(one, shard_tree)


def save_tree(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
              keep: int = 3):
    """Atomic keep-N checkpoint of an arbitrary pytree + JSON metadata.

    Same on-disk contract as `save` (step_<n>/arrays.npz + meta.json,
    tmp-dir + rename), but generic: `tree` is any pytree of arrays and
    `extra` is a JSON-serializable sidecar (e.g. a serving engine's queue/
    slot bookkeeping — the arrays land in the npz, the structure travels
    in meta.json).  Restore with `restore_tree` against a same-structure
    template."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    packed, dtypes = _pack(arrays)
    np.savez(os.path.join(tmp, "arrays.npz"), **packed)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_arrays": len(arrays), "dtypes": dtypes,
                   "manifest": _manifest(packed), "extra": extra}, f)
    _swap(tmp, final)
    _gc(ckpt_dir, keep)


def restore_tree(ckpt_dir: str, step: int, template
                 ) -> Tuple[Any, Optional[dict]]:
    """Load a `save_tree` checkpoint: returns `(tree, extra)`.

    `template` supplies the pytree structure and leaf dtypes (e.g. a
    zeros-built state of the right shape); arrays are cast onto it the
    same way elastic `restore` does.  Every array is verified against the
    per-entry crc32 manifest written by `save_tree`; a truncated or
    bit-flipped checkpoint raises `CheckpointCorruptError` naming the bad
    entry instead of silently loading garbage."""
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, meta = _load_verified(base)
    return _unflatten(template, flat), meta.get("extra")


def read_meta(ckpt_dir: str, step: int) -> dict:
    """The meta.json of one checkpoint (a `save_tree` restore needs the
    `extra` sidecar BEFORE it can build the template).  A missing step
    dir raises FileNotFoundError; a present-but-rotten meta.json (torn
    write, truncation, non-dict content) raises `CheckpointCorruptError`
    naming the file, so callers can fall back to an older step instead of
    dying on a raw json/KeyError."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    try:
        with open(path) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint meta {path!r} is unreadable ({e}) — the "
            f"checkpoint was torn mid-write or corrupted on disk; restore "
            f"an older step") from e
    if not isinstance(meta, dict):
        raise CheckpointCorruptError(
            f"checkpoint meta {path!r} is not a JSON object "
            f"(got {type(meta).__name__}) — foreign or corrupt file")
    return meta


class AsyncSaver:
    """Overlap checkpoint writes with the next training steps."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, params, opt_state):
        self.wait()
        # device_get on the main thread (jax is not thread-safe for transfers
        # racing with compute), file I/O on the worker thread.
        p = _flatten(params)
        o = _flatten(opt_state)

        def work():
            final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp, exist_ok=True)
            arrays = {f"params/{k}": v for k, v in p.items()}
            arrays.update({f"opt/{k}": v for k, v in o.items()})
            packed, dtypes = _pack(arrays)
            np.savez(os.path.join(tmp, "arrays.npz"), **packed)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "n_arrays": len(arrays),
                           "dtypes": dtypes,
                           "manifest": _manifest(packed)}, f)
            _swap(tmp, final)
            _gc(self.ckpt_dir, self.keep)

        os.makedirs(self.ckpt_dir, exist_ok=True)
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
