"""NERO kernel package: vadvc."""
