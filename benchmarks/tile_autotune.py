"""Paper Fig. 6 + Table 2 — tile auto-tuning and resource utilization.

Reproduces the paper's two findings: (1) hand-picked homogeneous tiles are
sub-optimal vs the multi-objective Pareto search; (2) the Pareto-optimal
tile *changes with precision*.  Resource axis = VMEM bytes (the FPGA
BRAM/URAM analogue; Table 2's utilization column).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import hierarchy as hw
from repro.core import perfmodel, tiling
from repro.core.autotune import get_op, tune

GRID = (64, 256, 256)


def run():
    hier = hw.tpu_v5e()
    for op in (get_op("vadvc"), get_op("hdiff"), get_op("dycore_fused")):
        for dtype in ("float32", "bfloat16"):
            tuned = tune(op, GRID, dtype)
            plan, est = tuned.plan, tuned.est
            vmem_pct = 100.0 * plan.vmem_bytes / hier.vmem.capacity_bytes
            emit(f"fig6/{op.name}_{dtype}_auto", est.time_s * 1e6,
                 f"tile={plan.tile} vmem={vmem_pct:.0f}% "
                 f"gflops={est.gflops:.0f} pareto_pts={len(tuned.pareto)}")
            # hand-tuned homogeneous tile (the paper's baseline practice);
            # sequential axes must stay whole or the plan is infeasible.
            hand_tile = tuple(GRID[a] if a in op.seq_axes else min(8, GRID[a])
                              for a in range(3))
            hand = tiling.TilePlan(op, GRID, hand_tile, dtype)
            if hand.fits(hier):
                est_h = perfmodel.estimate(hand)
                emit(f"fig6/{op.name}_{dtype}_hand", est_h.time_s * 1e6,
                     f"tile={hand.tile} "
                     f"vmem={100.0 * hand.vmem_bytes / hier.vmem.capacity_bytes:.0f}% "
                     f"gflops={est_h.gflops:.0f} "
                     f"slowdown={est_h.time_s / est.time_s:.2f}x")
        # precision dependence of the optimum (paper's key Fig. 6 insight).
        # At v5e's 128 MiB VMEM the paper's 256x256x64 domain doesn't bind
        # the resource axis (both precisions pick the same max tile) — the
        # effect the paper measured appears when near-memory is scarce, so
        # we also tune under an FPGA-BRAM-scale budget (1 MiB — the
        # per-PE BRAM share of the paper's XCVU37P), where bf16 affords a
        # larger window than fp32, exactly as in Fig. 6.
        p32 = tune(op, GRID, "float32").plan.tile
        p16 = tune(op, GRID, "bfloat16").plan.tile
        emit(f"fig6/{op.name}_precision_shift_v5e", 0.0,
             f"fp32_tile={p32} bf16_tile={p16} differs={p32 != p16} "
             f"(VMEM unconstrained at this domain)")
        small = hw.Hierarchy(
            hbm=hier.hbm,
            vmem=hw.MemoryLevel("vmem", 2**20,
                                hier.vmem.bandwidth_bytes_per_s,
                                hier.vmem.energy_pj_per_byte),
            vreg=hier.vreg)
        try:
            c32 = tune(op, GRID, "float32", small).plan
            c16 = tune(op, GRID, "bfloat16", small).plan
        except ValueError:
            # dycore_fused keeps whole z-columns AND whole x-rows per window;
            # its minimum footprint exceeds an FPGA-BRAM-scale budget — the
            # fused op only exists because VMEM is 128x larger per core.
            emit(f"fig6/{op.name}_precision_shift_1MiB", 0.0,
                 "no legal window under 1 MiB (whole-z/whole-x op)")
            continue
        emit(f"fig6/{op.name}_precision_shift_1MiB", 0.0,
             f"fp32_tile={c32.tile} bf16_tile={c16.tile} "
             f"differs={c32.tile != c16.tile} "
             f"bf16_window_pts={c16.tile_points} "
             f"fp32_window_pts={c32.tile_points}")


if __name__ == "__main__":
    run()
