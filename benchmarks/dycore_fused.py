"""Fused vs unfused dycore step — the NERO fusion claim, measured + modeled.

Paper §3 (arxiv 2107.08716): the CPU/GPU baseline round-trips every
intermediate through main memory; the in-fabric pipeline streams each field
once.  This benchmark reports that claim three ways for one full dycore step
(4 prognostic fields):

  * measured wall-clock of `dycore_step` on its three paths — unfused
    oracle, per-field fused (4 Pallas launches), whole-state fused (ONE
    launch, shared staggered-velocity slab).  (CPU note: without a TPU the
    fused kernels run in the Pallas *interpreter*, so their wall-clock here
    validates the pipelines, it does not demonstrate the speedup — the
    modeled rows do);
  * modeled HBM traffic per step from core/memmodel.dycore_step_traffic
    (array-level reads/writes each pipeline materializes), with the fused
    y-window halo re-read overhead from the auto-tuned TilePlan;
  * modeled TPU time/energy for the fused plan from core/perfmodel, and the
    k-step communication-avoiding exchange model
    (core/memmodel.kstep_exchange_model).

Emitted metric names (docs/benchmarks.md):
  dycore_fused/walltime_{unfused,fused,whole_state}  us per step (measured)
  dycore_fused/traffic_{unfused,fused,whole_state}_* modeled MB per step
  dycore_fused/model_{fused}                         modeled TPU time
  dycore_fused/kstep_k<k>                            k-step exchange model

Also writes BENCH_dycore.json (walltime, modeled HBM bytes, steps/s) for
cross-PR perf tracking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, smoke_mode, time_fn, write_json
from repro.core import hierarchy as hw
from repro.core import memmodel, perfmodel, tiling
from repro.kernels.dycore_fused import ops as fused_ops
from repro.weather import dycore, fields

# Measured grid: deliberately small.  The Pallas interpreter's grid loop
# carries the full output state per iteration (O(grid_steps x state) copy
# overhead that real hardware does not have), which at large grids swamps —
# and inverts — the launch-amortization effect the whole-state step
# targets.  At this size the per-`pallas_call` dispatch cost is the visible
# term, which is exactly the 4-launches-vs-1 comparison; HBM-traffic
# effects are covered by the modeled rows at the paper's domain.
GRID = (4, 16, 16)
ENSEMBLE = 1
MODEL_GRID = (64, 256, 256)  # the paper's domain, for the modeled rows
SMOKE_GRID = (4, 16, 16)     # CI smoke job (tiny, interpret mode)


def run():
    smoke = smoke_mode()
    grid = SMOKE_GRID if smoke else GRID
    iters, warmup = (1, 1) if smoke else (7, 2)
    st = fields.initial_state(jax.random.PRNGKey(0), grid,
                              ensemble=ENSEMBLE)
    n_fields = len(fields.PROGNOSTIC)
    backend = jax.default_backend()
    interp_note = ("" if backend == "tpu"
                   else " (Pallas interpreter — validates, not representative)")

    walltime = {}
    t_unfused = time_fn(lambda s: dycore.dycore_step(s, fused=False), st,
                        iters=iters, warmup=warmup)
    walltime["unfused"] = t_unfused
    emit("dycore_fused/walltime_unfused", t_unfused,
         f"grid={grid} ensemble={ENSEMBLE}")
    t_fused = time_fn(
        lambda s: dycore.dycore_step(s, fused=True, whole_state=False), st,
        iters=iters, warmup=warmup)
    walltime["fused_per_field"] = t_fused
    emit("dycore_fused/walltime_fused", t_fused,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 4 launches{interp_note}")
    t_whole = time_fn(
        lambda s: dycore.dycore_step(s, fused=True, whole_state=True), st,
        iters=iters, warmup=warmup)
    walltime["fused_whole_state"] = t_whole
    emit("dycore_fused/walltime_whole_state", t_whole,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 1 launch, shared w{interp_note} "
         f"vs_per_field={t_fused / max(t_whole, 1e-9):.2f}x")

    # Modeled HBM traffic at the paper's domain, auto-tuned fused window.
    model_grid = grid if smoke else MODEL_GRID
    traffic = {}
    for dtype in ("float32", "bfloat16"):
        ty = fused_ops.plan_tile(model_grid, jnp.dtype(dtype))
        t = memmodel.dycore_step_traffic(model_grid, dtype,
                                         n_fields=n_fields, ty=ty)
        traffic[dtype] = {
            "unfused": t["unfused"]["total"],
            "fused_per_field": t["fused"]["total"],
            "fused_whole_state": t["fused_whole"]["total"],
            "reduction_x_whole": t["reduction_x_whole"],
        }
        mb = 1.0 / 2**20
        emit(f"dycore_fused/traffic_unfused_{dtype}", 0.0,
             f"MB={t['unfused']['total'] * mb:.0f} "
             f"vadvc={t['unfused']['vadvc'] * mb:.0f} "
             f"pointwise={t['unfused']['pointwise'] * mb:.0f} "
             f"hdiff={(t['unfused']['hdiff'] + t['unfused']['hdiff_pad']) * mb:.0f}")
        emit(f"dycore_fused/traffic_fused_{dtype}", 0.0,
             f"MB={t['fused']['total'] * mb:.0f} ty={ty} "
             f"halo_overhead={t['halo_overhead'] * 100:.1f}% "
             f"reduction={t['reduction_x']:.2f}x "
             f"(aliased-window pessimistic bound: "
             f"MB={t['fused']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_window_reads']:.2f}x)")
        emit(f"dycore_fused/traffic_whole_state_{dtype}", 0.0,
             f"MB={t['fused_whole']['total'] * mb:.0f} ty={ty} "
             f"reduction={t['reduction_x_whole']:.2f}x "
             f"vs_per_field="
             f"{t['fused']['total'] / max(t['fused_whole']['total'], 1):.3f}x "
             f"(pessimistic bound: "
             f"MB={t['fused_whole']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_whole_window_reads']:.2f}x)")

        # Modeled TPU time for the fused plan (per field pipeline pass).
        plan = tiling.TilePlan(op=tiling.DYCORE_FUSED, grid_shape=model_grid,
                               tile=(model_grid[0], ty, model_grid[2]),
                               dtype=dtype)
        est = perfmodel.estimate(plan)
        emit(f"dycore_fused/model_fused_{dtype}",
             est.time_s * n_fields * 1e6,
             f"bottleneck={est.bottleneck} gflops={est.gflops:.0f} "
             f"vmem={100.0 * plan.vmem_bytes / hw.tpu_v5e().vmem.capacity_bytes:.0f}%")

    # Communication-avoiding k-step exchange model (weather/domain.py).
    kstep = {}
    for k in (1, 2, 4):
        try:
            m = memmodel.kstep_exchange_model(model_grid, "float32",
                                              n_fields=n_fields, k=k)
        except ValueError:
            continue
        kstep[str(k)] = m
        emit(f"dycore_fused/kstep_k{k}", 0.0,
             f"rounds={m['rounds_kstep']}v{m['rounds_sequential']} "
             f"bytes_ratio={m['bytes_ratio']:.2f} "
             f"redundant_flops={m['redundant_flops_frac'] * 100:.0f}%")

    write_json("BENCH_dycore.json", {
        "grid": list(grid),
        "model_grid": list(model_grid),
        "ensemble": ENSEMBLE,
        "n_fields": n_fields,
        "walltime_us": walltime,
        "steps_per_s": {k: 1e6 / max(v, 1e-9) for k, v in walltime.items()},
        "modeled_hbm_bytes": traffic,
        "kstep_exchange": kstep,
    })


if __name__ == "__main__":
    run()
