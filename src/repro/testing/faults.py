"""Deterministic fault injection for the supervised forecasting stack.

An always-on forecast service is only trustworthy unattended if every
failure mode it claims to survive is *rehearsed*, deterministically, in
CI.  This module is that rehearsal harness: a seedable `FaultInjector`
the `ForecastEngine` consults at its supervision points, plus file-level
corruption helpers for the checkpoint integrity tests.

Faults are *declared* as `FaultSpec`s — what kind, at which engine round,
into which slot — so a test (or the CI chaos job) can pin a failure to an
exact scheduling point and assert the recovery bit-for-bit:

* ``poison_nan`` / ``poison_inf``: overwrite elements of one ensemble
  slot's state with NaN/Inf at a chosen round boundary (a blown-up
  forecast / corrupt request).  Positions are drawn from the injector's
  seeded rng, so the same seed poisons the same elements.
* ``compile_fail``: raise `InjectedCompileError` from a chosen attempt of
  the engine's compile fallback chain (``native`` → ``interpret`` →
  ``reference``), forcing the chain to degrade.
* ``device_loss``: raise `InjectedDeviceLoss` when a chosen round starts
  — a transient backend/runtime failure the engine must retry with
  backoff.  With `device=<id>` the loss is PERSISTENT per-device: it
  fires on every round from `round` on **while that device is part of
  the mesh the engine reports via `device_ids`** — the model of a chip
  falling out of the fabric.  The raised exception carries
  ``.lost_device`` so the engine's failover can tell which survivor set
  to rebuild from.
* ``wire_corrupt``: overwrite a few elements of one slot inside ONE
  shard's slab with finite, in-bounds garbage at a round boundary — a
  corrupted halo wire buffer.  It passes the NaN/Inf/magnitude validity
  guard by construction; only the per-slot fingerprint reduction
  (`program.slot_guard`) catches it, and only on slots that did not
  legitimately advance that round (rolled-back or idle slots — the
  engine's non-participant invariant).
* ``straggler``: sleep `delay_s` seconds as the round starts — a hung
  collective / slow device.  Nothing is raised; the engine's per-round
  deadline watchdog (`round_deadline_s`) must notice the overrun and
  treat the attempt as failed.

Every fired fault is appended to ``injector.log`` (kind, round, slot) so
tests and the robustness benchmark can assert what actually happened.

Checkpoint corruption is file-level, not hook-level: `truncate_file`,
`bitflip_file`, and `corrupt_checkpoint` damage a written checkpoint in
place so `ckpt.restore_tree`'s manifest verification can be tested
against real on-disk rot.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "InjectedFault",
           "InjectedCompileError", "InjectedDeviceLoss", "truncate_file",
           "bitflip_file", "corrupt_checkpoint"]

KINDS = ("poison_nan", "poison_inf", "compile_fail", "device_loss",
         "wire_corrupt", "straggler")


class InjectedFault(RuntimeError):
    """Base class of all injected failures (never raised by real code)."""


class InjectedCompileError(InjectedFault):
    """Simulated backend lowering/compile failure."""


class InjectedDeviceLoss(InjectedFault):
    """Simulated device loss / transient runtime failure mid-round.
    `lost_device` is the failed device's id for a per-device persistent
    loss (None for the transient, device-less flavor)."""

    def __init__(self, msg: str, lost_device: Optional[int] = None):
        super().__init__(msg)
        self.lost_device = lost_device


@dataclasses.dataclass
class FaultSpec:
    """One declared fault.

    `round` indexes the engine's global round counter (poison and
    device-loss faults fire when that round runs).  `slot` picks the lane
    slot to poison; None (or an inactive slot) falls back to a seeded
    choice among the slots actually busy that round.  `op` restricts the
    fault to lanes/compiles of one stencil op (None = any).  `attempt`
    names which stage of the compile fallback chain a ``compile_fail``
    kills (``"native"``, ``"interpret"``, ``"reference"``, or ``"all"``).
    `once` (default) retires the spec after it fires — the transient-fault
    model; set False for a persistent fault.

    `device` (``device_loss`` only) makes the loss per-device and
    persistent-while-present: it fires on every round >= `round` as long
    as that device id is in the `device_ids` the engine passes to
    `on_round` — so a failover onto surviving devices genuinely clears
    it.  `delay_s` is the ``straggler`` sleep.  `shard` picks which
    shard's slab a ``wire_corrupt`` lands in (the y-decomposed slab
    index)."""

    kind: str
    round: int = 0
    slot: Optional[int] = None
    field: Optional[str] = None                 # poison: field name, None=all
    op: Optional[str] = None
    attempt: str = "native"
    once: bool = True
    device: Optional[int] = None                # device_loss: device id
    delay_s: float = 0.0                        # straggler: sleep seconds
    shard: int = 0                              # wire_corrupt: slab index

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind={self.kind!r} not one of {KINDS}")
        if self.device is not None and self.kind != "device_loss":
            raise ValueError(f"device= only applies to device_loss specs, "
                             f"not {self.kind!r}")


class FaultInjector:
    """Seeded, deterministic fault source.  The engine calls the hooks;
    specs decide whether they fire.  Thread-hostile by design (the engine
    is single-threaded); same (specs, seed) => same faults."""

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.log: List[Dict[str, Any]] = []
        self._spent: List[FaultSpec] = []

    # -- bookkeeping --------------------------------------------------------
    def _fire(self, spec: FaultSpec, **event) -> None:
        self.log.append({"kind": spec.kind, **event})
        if spec.once:
            self.specs.remove(spec)
            self._spent.append(spec)

    def fired(self, kind: Optional[str] = None) -> int:
        return sum(1 for e in self.log if kind is None or e["kind"] == kind)

    # -- engine hooks -------------------------------------------------------
    def on_compile(self, program, attempt: str) -> None:
        """Called before each stage of the compile fallback chain; raises
        `InjectedCompileError` when a ``compile_fail`` spec matches."""
        for spec in list(self.specs):
            if spec.kind != "compile_fail":
                continue
            if spec.op is not None and spec.op != program.op:
                continue
            if spec.attempt not in ("all", attempt):
                continue
            self._fire(spec, op=program.op, attempt=attempt)
            raise InjectedCompileError(
                f"injected lowering failure: op={program.op!r} "
                f"attempt={attempt!r}")

    def on_round(self, op: str, round_index: int,
                 device_ids: Optional[Sequence[int]] = None) -> None:
        """Called as a lane round starts.  Raises `InjectedDeviceLoss`
        when a ``device_loss`` spec matches this round (or, for a
        per-device spec, while its device is in `device_ids` — the ids of
        the mesh the engine is about to step on); sleeps for a matching
        ``straggler`` spec."""
        for spec in list(self.specs):
            if spec.kind == "straggler":
                if spec.round != round_index:
                    continue
                if spec.op is not None and spec.op != op:
                    continue
                self._fire(spec, op=op, round=round_index,
                           delay_s=spec.delay_s)
                time.sleep(spec.delay_s)
                continue
            if spec.kind != "device_loss":
                continue
            if spec.device is not None:
                # Per-device persistent loss: the chip is gone from
                # `round` on; it only stops failing rounds once the
                # engine stops scheduling onto it.
                if round_index < spec.round:
                    continue
                if device_ids is None or spec.device not in device_ids:
                    continue
            elif spec.round != round_index:
                continue
            if spec.op is not None and spec.op != op:
                continue
            self._fire(spec, op=op, round=round_index, device=spec.device)
            raise InjectedDeviceLoss(
                f"injected device loss: op={op!r} round={round_index}"
                + (f" device={spec.device}" if spec.device is not None
                   else ""),
                lost_device=spec.device)

    def poison(self, batch, op: str, round_index: int,
               active_slots: Sequence[int],
               nonparticipants: Sequence[int] = (),
               shards: Sequence[int] = (1, 1)):
        """Called at the round boundary (post-step, pre-guard); returns
        `batch` with matching poison specs applied to ONE active slot each
        — only that slot's leaves are written, so healthy slots keep their
        exact bits.

        ``wire_corrupt`` specs also land here (the round boundary IS the
        moment a bad wire buffer would have materialized as bad slab
        rows): they prefer a slot from `nonparticipants` (rolled-back or
        idle slots, whose bits the engine can PROVE must not change) and
        damage only shard `spec.shard`'s rows of the y-decomposed slab
        (`shards` = the plan's (py, px))."""
        for spec in list(self.specs):
            if spec.kind == "wire_corrupt":
                if spec.round != round_index:
                    continue
                if spec.op is not None and spec.op != op:
                    continue
                pool = list(nonparticipants) or list(active_slots)
                if spec.slot is not None:
                    slot = spec.slot
                elif pool:
                    slot = int(self.rng.choice(pool))
                else:
                    continue
                batch = self._corrupt_shard(batch, slot, spec.field,
                                            spec.shard, shards)
                self._fire(spec, op=op, round=round_index, slot=slot,
                           shard=spec.shard)
                continue
            if spec.kind not in ("poison_nan", "poison_inf"):
                continue
            if spec.round != round_index:
                continue
            if spec.op is not None and spec.op != op:
                continue
            if not active_slots:
                continue                     # nothing to poison this round
            slot = (spec.slot if spec.slot in active_slots
                    else int(self.rng.choice(list(active_slots))))
            val = np.nan if spec.kind == "poison_nan" else np.inf
            batch = self._poison_slot(batch, slot, spec.field, val)
            self._fire(spec, op=op, round=round_index, slot=slot)
        return batch

    def _corrupt_shard(self, batch, slot: int, field: Optional[str],
                       shard: int, shards: Sequence[int]):
        """Finite, in-bounds damage to one slot's rows inside ONE shard's
        slab: a seeded handful of elements of the slab's first rows gets
        +1.0 — invisible to the NaN/Inf/magnitude validity guard, visible
        to the fingerprint."""
        py = max(1, int(shards[0]))
        name = field if field is not None else sorted(batch.fields)[0]
        leaf = batch.fields[name]
        ny = int(leaf.shape[2])
        ly = max(1, ny // py)
        lo = min(int(shard), py - 1) * ly
        rows = slice(lo, lo + max(1, min(2, ly)))
        e = leaf[slot]                       # (nz, ny, nx)
        band = e[:, rows, :]
        n = max(1, int(band.size) // 16)
        idx = self.rng.choice(band.size, size=n, replace=False)
        flat = jnp.ravel(band).at[jnp.asarray(idx)].add(
            jnp.asarray(1.0, leaf.dtype))
        e = e.at[:, rows, :].set(jnp.reshape(flat, band.shape))
        out = jax.tree_util.tree_map(lambda a: a, batch)
        out.fields = dict(out.fields)
        out.fields[name] = leaf.at[slot].set(e)
        return out

    def _poison_slot(self, batch, slot: int, field: Optional[str],
                     val: float):
        """Overwrite a seeded handful of elements of `slot` with `val`."""
        def bad(leaf):
            e = leaf[slot]
            n = max(1, int(e.size) // 8)
            idx = self.rng.choice(e.size, size=n, replace=False)
            flat = jnp.ravel(e).at[jnp.asarray(idx)].set(
                jnp.asarray(val, leaf.dtype))
            return leaf.at[slot].set(jnp.reshape(flat, e.shape))

        if field is None:
            return jax.tree_util.tree_map(bad, batch)
        out = jax.tree_util.tree_map(lambda a: a, batch)
        out.fields = dict(out.fields)
        out.fields[field] = bad(out.fields[field])
        return out


# ---------------------------------------------------------------------------
# Checkpoint file corruption (drives ckpt's manifest verification tests)
# ---------------------------------------------------------------------------


def truncate_file(path: str, frac: float = 0.5) -> int:
    """Truncate `path` to `frac` of its size (a torn write / full disk);
    returns the new size."""
    size = os.path.getsize(path)
    new = max(1, int(size * frac))
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


def bitflip_file(path: str, seed: int = 0, nbits: int = 1) -> List[int]:
    """Flip `nbits` seeded-random bits of `path` in place (silent media
    corruption); returns the byte offsets touched.  Offsets avoid the
    head/tail of the file so an npz flip lands in archive member data
    (detected by the manifest crc), not in the zip trailer."""
    rng = np.random.default_rng(seed)
    size = os.path.getsize(path)
    lo = min(512, size // 4)
    hi = max(lo + 1, size - min(1024, size // 4))
    offsets = sorted(int(o) for o in
                     rng.choice(np.arange(lo, hi),
                                size=min(nbits, hi - lo), replace=False))
    with open(path, "r+b") as f:
        for off in offsets:
            f.seek(off)
            byte = f.read(1)[0]
            f.seek(off)
            f.write(bytes([byte ^ (1 << int(rng.integers(8)))]))
    return offsets


def corrupt_checkpoint(ckpt_dir: str, step: int, mode: str = "truncate",
                       seed: int = 0) -> str:
    """Damage one written checkpoint's arrays.npz in place.  `mode` is
    ``"truncate"`` or ``"bitflip"``; returns the corrupted path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    if mode == "truncate":
        truncate_file(path)
    elif mode == "bitflip":
        bitflip_file(path, seed=seed, nbits=8)
    else:
        raise ValueError(f"mode={mode!r} must be 'truncate' or 'bitflip'")
    return path
