"""Logical-axis sharding rules (MaxText-style) for every framework pytree.

One table maps parameter *paths* to PartitionSpecs per run kind:

  * train:   FSDP over "data" on the embed/contraction dim + TP/EP over
             "model" on heads/ffn/experts/vocab; batch over ("pod","data").
  * serve (prefill/decode): weights TP over "model" only (no per-step
             all-gathers); KV caches batch->"data", seq->"model"
             (long-context, batch=1: seq->("data","model")).

Stacked layer params (leading scan dim under superblocks/enc_blocks/
dec_blocks) automatically get a leading None.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ModelConfig, ShapeConfig

STACK_KEYS = ("superblocks", "enc_blocks", "dec_blocks")


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(names: Tuple[str, ...], ndim: int, kind: str,
               expert_div: bool = True) -> P:
    """Full rule table.  kind: 'train' (FSDP+TP) or 'serve' (TP only).

    expert_div: n_experts divides the model axis -> expert-parallel MoE
    weights; otherwise fall back to tensor-parallel over d_ff (granite's 40
    experts don't divide a 16-wide model axis)."""
    fsdp = "data" if kind == "train" else None
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    stacked = any(s in names for s in STACK_KEYS)
    base_ndim = ndim - 1 if stacked else ndim

    def done(spec: P) -> P:
        assert len(spec) <= base_ndim, (names, ndim, spec)
        spec = P(*(tuple(spec) + (None,) * (base_ndim - len(spec))))
        if stacked:
            spec = P(*((None,) + tuple(spec)))
        return spec

    m = "model"
    d = fsdp

    if leaf == "embed":
        return done(P(m, d))
    if leaf == "head":
        return done(P(d, m))
    if parent in ("attn", "xattn"):
        if leaf in ("wq", "wk", "wv"):
            return done(P(d, m))
        if leaf == "wo":
            return done(P(m, d))
        return done(P())                        # qk-norm scales
    if parent == "ffn":
        if leaf == "router":
            return done(P())
        if base_ndim == 3:                      # MoE experts (E, d, f)
            if leaf in ("wi", "wg"):
                return done(P(m, d, None) if expert_div
                            else P(None, d, m))
            if leaf == "wo":
                return done(P(m, None, d) if expert_div
                            else P(None, m, d))
        if leaf in ("wi", "wg"):
            return done(P(d, m))
        if leaf == "wo":
            return done(P(m, d))
    if parent == "rec":
        if leaf in ("w_branch_x", "w_branch_g"):
            return done(P(d, m))
        if leaf == "conv":
            return done(P(None, m))
        if leaf in ("w_rec_gate", "w_in_gate"):
            return done(P(None, m))
        if leaf == "lam":
            return done(P(m))
        if leaf == "w_out":
            return done(P(m, d))
    if parent == "ssd":
        if leaf == "in_proj":
            return done(P(d, m))
        if leaf == "conv":
            return done(P(None, m))
        if leaf == "norm_scale":
            return done(P(m))
        if leaf == "out_proj":
            return done(P(m, d))
        return done(P())                        # A_log, D, dt_bias
    return done(P())                            # norms & everything scalar


def params_sharding(params_or_shapes, mesh: Mesh, kind: str):
    """Pytree of NamedShardings matching the params pytree."""
    model_par = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = _path_names(path)
        expert_div = True
        if len(leaf.shape) >= 3 and "ffn" in names:
            stacked = any(s in names for s in STACK_KEYS)
            n_experts = leaf.shape[1] if stacked else leaf.shape[0]
            expert_div = (n_experts % model_par == 0)
        spec = param_spec(names, len(leaf.shape), kind,
                          expert_div=expert_div)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, batch_size: int):
    """Batch dim spec: over ("pod","data") when they divide the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n = 1
    chosen = []
    for a in axes:
        if batch_size % (n * mesh.shape[a]) == 0:
            chosen.append(a)
            n *= mesh.shape[a]
    return tuple(chosen) if chosen else None


def data_spec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    b = batch_sharding(mesh, batch_size)
    return P(*((b,) + (None,) * (ndim - 1)))


def cache_spec(names: Tuple[str, ...], ndim: int, mesh: Mesh,
               batch_size: int) -> P:
    """KV / state cache rules.  Stacked leading scan dim -> None.

    attn k/v (R, B, S, K, hd): B->data axes, S->"model"
      (batch==1 long-context: S->("data","model")).
    rec/ssd states: B->data, width/heads dim -> "model".
    """
    leaf = names[-1]
    b_axes = batch_sharding(mesh, batch_size)
    stacked = (any(s in names for s in STACK_KEYS)
               or (leaf in ("k", "v") and ndim == 5)
               or (leaf in ("k_scale", "v_scale") and ndim == 4)
               or (names and names[0] == "dec"))
    base = ndim - 1 if stacked else ndim

    if leaf in ("k", "v", "k_scale", "v_scale") and base in (3, 4):
        seq_ax = ("model" if b_axes
                  else tuple(a for a in ("data", "model")
                             if a in mesh.axis_names))
        spec = (P(b_axes, seq_ax, None, None) if base == 4
                else P(b_axes, seq_ax, None))   # int8 KV scales (B, S, K)
    elif leaf == "h" and base == 2:           # rglru state (B, W)
        spec = P(b_axes, "model")
    elif leaf == "h" and base == 4:           # ssd state (B, nh, p, n)
        spec = P(b_axes, "model", None, None)
    elif leaf == "conv" and base == 3:        # conv state (B, cw-1, W)
        spec = P(b_axes, None, "model")
    elif leaf == "enc" and base == 3:         # whisper encoder states
        spec = P(b_axes, None, None)
    else:
        spec = P(*([b_axes] + [None] * (base - 1))) if base else P()
    spec = P(*(tuple(spec) + (None,) * (base - len(spec))))
    if stacked:
        spec = P(*((None,) + tuple(spec)))
    return spec


def cache_sharding(cache_shapes, mesh: Mesh, batch_size: int):
    def one(path, leaf):
        names = _path_names(path)
        spec = cache_spec(names, len(leaf.shape), mesh, batch_size)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)
