"""Fused compound dycore step: vadvc -> point-wise update -> hdiff in one
Pallas dataflow pipeline (NERO's in-fabric fusion, arxiv 2107.08716 §3)."""

from repro.kernels.dycore_fused.fused import fused_dycore_pallas
from repro.kernels.dycore_fused.ops import fused_step, plan_tile, snap_ty
from repro.kernels.dycore_fused.ref import fused_step_ref

__all__ = ["fused_dycore_pallas", "fused_step", "fused_step_ref",
           "plan_tile", "snap_ty"]
