"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_mha_pallas, ref
from repro.kernels.flash_attention.ops import auto_blocks


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _run(b, t, s, h, kh, hd, dtype, causal, window, softcap, bq=64, bk=64):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, t, h, hd), dtype)
    k = _rand(ks[1], (b, s, kh, hd), dtype)
    v = _rand(ks[2], (b, s, kh, hd), dtype)
    out = flash_mha_pallas(q, k, v, causal=causal, window=window,
                           softcap=softcap, block_q=bq, block_k=bk,
                           interpret=True)
    want = ref.mha(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_basic_shapes(dtype, causal):
    _run(2, 128, 128, 4, 4, 32, dtype, causal, 0, 0.0)


@pytest.mark.parametrize("g", [2, 4])
def test_gqa_group_sizes(g):
    _run(1, 128, 128, 4 * g // g * g, 4, 32, jnp.float32, True, 0, 0.0)
    _run(1, 128, 128, g * 2, 2, 32, jnp.float32, True, 0, 0.0)


def test_sliding_window():
    _run(1, 256, 256, 2, 2, 32, jnp.float32, True, 64, 0.0)


def test_softcap():
    _run(1, 128, 128, 2, 1, 32, jnp.float32, True, 0, 30.0)


def test_cross_attention_rectangular():
    # prefill-style T != S, non-causal (whisper cross-attn shape)
    _run(2, 64, 192, 4, 2, 32, jnp.float32, False, 0, 0.0)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([64, 128, 256]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]),
       st.sampled_from([32, 64]),
       st.booleans())
def test_property_sweep(t, s, heads, hd, causal):
    h, kh = heads
    _run(1, t, s, h, kh, hd, jnp.float32, causal, 0, 0.0)


def test_auto_blocks_fit_and_align():
    bq, bk = auto_blocks(4096, 32768, 128)
    assert 4096 % bq == 0 and 32768 % bk == 0
    assert bq % 128 == 0 and bk % 128 == 0
