"""Sharding rules, vocab padding, and launcher knobs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import api, lm
from repro.parallel import sharding as shd
from repro.launch.mesh import make_mesh


def test_param_spec_train_vs_serve():
    names = ("superblocks", "b0", "attn", "wq")
    assert shd.param_spec(names, 3, "train") == P(None, "data", "model")
    assert shd.param_spec(names, 3, "serve") == P(None, None, "model")
    names = ("rem0", "ffn", "wo")
    assert shd.param_spec(names, 2, "train") == P("model", "data")
    assert shd.param_spec(names, 2, "serve") == P("model", None)


def test_moe_expert_div_fallback():
    """40 experts on a 16-wide model axis -> TP over d_ff, E unsharded."""
    names = ("superblocks", "b0", "ffn", "wi")
    assert shd.param_spec(names, 4, "train", expert_div=True) \
        == P(None, "model", "data", None)
    assert shd.param_spec(names, 4, "train", expert_div=False) \
        == P(None, None, "data", "model")


def test_params_sharding_detects_nondivisible_experts():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = registry.get_config("granite-moe-3b-a800m")
    model = api.build(cfg)
    shapes = model.param_shapes()
    tree = shd.params_sharding(shapes, mesh, "train")
    leaf = tree["superblocks"]["b0"]["ffn"]["wi"]
    # model axis width 1 divides everything -> expert-parallel layout
    assert leaf.spec == P(None, "model", "data", None)


def test_padded_vocab_is_128_multiple_and_masked():
    cfg = registry.get_config("granite-moe-3b-a800m")
    assert cfg.padded_vocab % 128 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    logits = jnp.ones((2, 3, cfg.padded_vocab))
    masked = lm.mask_padded_vocab(logits, cfg.vocab_size)
    assert float(masked[..., cfg.vocab_size:].max()) < -1e29
    assert float(masked[..., :cfg.vocab_size].min()) == 1.0


def test_padding_columns_do_not_change_loss():
    """Garbage in the physical padding rows must not affect the NLL."""
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"),
                                  layers=2)
    cfg = dataclasses.replace(cfg, vocab_size=250)   # padded_vocab = 256
    assert cfg.padded_vocab == 256
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    base = float(model.loss(params, batch, remat="none"))
    poisoned = jax.tree.map(lambda x: x, params)
    poisoned["head"] = params["head"].at[:, cfg.vocab_size:].set(1e4)
    poisoned["embed"] = params["embed"].at[cfg.vocab_size:].set(-1e4)
    pois = float(model.loss(poisoned, batch, remat="none"))
    np.testing.assert_allclose(base, pois, rtol=1e-5)


def test_choose_microbatches_fits_and_caps():
    from repro.launch import dryrun
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = registry.reduced_config(registry.get_config("olmo-1b"))
    mb = dryrun.choose_microbatches(cfg, SHAPES["train_4k"], mesh)
    assert mb >= 1 and (mb & (mb - 1)) == 0 or mb == SHAPES[
        "train_4k"].global_batch
    assert dryrun.choose_microbatches(cfg, SHAPES["decode_32k"], mesh) == 1


def test_grad_accum_bf16_close_to_f32():
    """bf16 gradient accumulation (wire compression) stays numerically
    close to f32 accumulation for one step."""
    from repro.data import synthetic
    from repro.train import loop, optim
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"),
                                  layers=2)
    model = api.build(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                              clip_norm=1e9)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    batch = jax.tree.map(jnp.asarray, synthetic.lm_batch(cfg, 0, 0, 8, 32))
    s32, _, _ = loop.make_train_step(model, mesh, opt_cfg, microbatches=4,
                                     remat="none")
    s16, _, _ = loop.make_train_step(model, mesh, opt_cfg, microbatches=4,
                                     remat="none", grad_dtype="bfloat16")
    p32, _, _ = s32(params, opt_state, batch)
    p16, _, _ = s16(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p16)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)
