"""Mamba2-1.3B — attention-free SSM with SSD [arXiv:2405.21060; unverified]."""

from repro.configs.base import ModelConfig, SSDConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50280,
    pattern=("ssd",), rope_theta=0.0,
    norm="rms", gated_mlp=False, act="silu",
    tie_embeddings=True,
    ssd=SSDConfig(d_state=128, head_dim=64, expand=2, chunk=256,
                  conv_width=4, n_groups=1),
)
