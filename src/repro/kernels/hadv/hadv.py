"""Pallas TPU kernel for first-order upwind horizontal advection.

Same shape as the hdiff kernel, with a 1-row low-side halo instead of a
symmetric 2-row one: grid = (nz, ny/ty), the y-halo realized with an
aliased prev-window ref (clamped at the global low edge — those rows are
passthrough anyway), x whole per window on the lane dimension.  Compute
is fp32 internally; bf16 in/out supported.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

from repro.kernels.hadv.ref import DEFAULT_CFL


def _hadv_kernel(prev_ref, cur_ref, out_ref, *, cfl: float,
                 ny: int, ty: int):
    j = pl.program_id(1)
    nx = cur_ref.shape[2]

    prev = prev_ref[0].astype(jnp.float32)     # (ty, nx)
    cur = cur_ref[0].astype(jnp.float32)
    # Working window with a 1-row halo on the low side only.
    work = jnp.concatenate([prev[-1:], cur], axis=0)   # (ty+1, nx)

    c = work[1: 1 + ty, 1:]         # (ty, nx-1)
    ym = work[0: ty, 1:]
    xm = work[1: 1 + ty, : nx - 1]
    interior = c - cfl * ((c - ym) + (c - xm))

    # Global row 0 passes through (low-side ring); column 0 is never
    # written.  Clamped prev at j == 0 only feeds that invalid row.
    row_ids = j * ty + jax.lax.broadcasted_iota(jnp.int32, (ty, 1), 0)
    valid = row_ids >= 1
    center = work[1: 1 + ty, :]
    res = center.at[:, 1:].set(jnp.where(valid, interior, center[:, 1:]))
    out_ref[0] = res.astype(out_ref.dtype)


def hadv_pallas(src: jnp.ndarray, cfl: float = DEFAULT_CFL,
                ty: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Tiled upwind advection.  src: (nz, ny, nx), ny % ty == 0, ty >= 1."""
    nz, ny, nx = src.shape
    if ny % ty or ty < 1:
        raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 1")
    nyb = ny // ty

    spec = functools.partial(pl.BlockSpec, (1, ty, nx))
    in_specs = [
        spec(lambda k, j: (k, jnp.maximum(j - 1, 0), 0)),   # prev
        spec(lambda k, j: (k, j, 0)),                       # cur
    ]
    out_spec = spec(lambda k, j: (k, j, 0))

    kernel = functools.partial(_hadv_kernel, cfl=cfl, ny=ny, ty=ty)
    fn = pl.pallas_call(
        kernel,
        grid=(nz, nyb),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_hadv_upwind",
    )
    return fn(src, src)
