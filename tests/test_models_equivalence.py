"""Cross-implementation equivalences: flash==dense attention, SSD chunked ==
step-by-step recurrence, RG-LRU scan == sequential, prefill+decode == full
forward, M-RoPE text == standard RoPE, MoE conservation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import MoEConfig, SSDConfig
from repro.models import api, attention, lm
from repro.models.common import rope_apply


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,s,h,kv,window", [
    (32, 32, 4, 4, 0), (64, 64, 8, 2, 0), (32, 32, 4, 1, 0),
    (64, 64, 4, 2, 16), (128, 128, 2, 2, 32),
])
def test_flash_matches_dense(t, s, h, kv, window, rng):
    b, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    want = np.asarray(attention.dense_attention(q, k, v, causal=True,
                                                window=window))
    got = np.asarray(attention.flash_attention(q, k, v, causal=True,
                                               window=window, q_chunk=16,
                                               kv_chunk=16))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_matches_dense_last_row(rng):
    b, s, h, kv, hd = 2, 24, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    got = np.asarray(attention.decode_attention(q, k, v, pos=s - 1))
    want = np.asarray(attention.dense_attention(q, k, v, causal=True,
                                                q_offset=s - 1))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------

def _ssd_sequential(x, dt, A, B, C):
    """Step-by-step recurrence oracle: h = exp(dt A) h + dt B x."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    g = B.shape[2]
    rep = h // g
    Bh = np.repeat(B, rep, axis=2)
    Ch = np.repeat(C, rep, axis=2)
    hstate = np.zeros((b, h, p, n))
    ys = np.zeros_like(x)
    for i in range(t):
        da = np.exp(dt[:, i] * A)                      # (b,h)
        hstate = (hstate * da[..., None, None]
                  + (dt[:, i, :, None, None]
                     * Bh[:, i, :, None, :] * x[:, i, :, :, None]))
        ys[:, i] = np.einsum("bhn,bhpn->bhp", Ch[:, i], hstate)
    return ys, hstate


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 8, 16]),
       st.sampled_from([4, 8]))
def test_ssd_chunked_matches_sequential(seed, t, chunk):
    from repro.models.ssd import _ssd_chunked
    rng = np.random.default_rng(seed)
    b, h, p, g, n = 2, 4, 8, 2, 8
    x = rng.normal(size=(b, t, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, size=(b, t, h)).astype(np.float32)
    A = -rng.uniform(0.1, 2.0, size=(h,)).astype(np.float32)
    B = rng.normal(size=(b, t, g, n)).astype(np.float32)
    C = rng.normal(size=(b, t, g, n)).astype(np.float32)
    want_y, want_h = _ssd_sequential(x, dt, A, B, C)
    got_y, got_h = _ssd_chunked(*map(jnp.asarray, (x, dt, A, B, C)),
                                chunk=min(chunk, t))
    np.testing.assert_allclose(np.asarray(got_y), want_y, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_h), want_h, rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def test_lru_scan_matches_sequential(rng):
    from repro.models.rglru import lru_scan
    b, t, w = 2, 33, 8
    a = jnp.asarray(rng.uniform(0.5, 0.99, size=(b, t, w)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, t, w)).astype(np.float32))
    got = np.asarray(lru_scan(a, x))
    h = np.zeros((b, w), np.float32)
    for i in range(t):
        h = np.asarray(a)[:, i] * h + np.asarray(x)[:, i]
        np.testing.assert_allclose(got[:, i], h, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# prefill/decode equivalence for every arch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_prefill_decode_matches_full(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    if cfg.moe:    # no-drop capacity so routing matches across paths
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            n_experts=4, top_k=2, capacity_factor=4.0, router_chunk=64))
    model = api.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    T = 24
    toks = jax.random.randint(key, (2, T + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (2, cfg.encdec.encoder_len, cfg.d_model), jnp.float32)
        from repro.models import encdec
        enc = encdec.encode(cfg, params, batch["frames"])
        full, _ = encdec.decode(cfg, params, toks, enc, mode="train")
    else:
        full, _, _ = lm.apply(cfg, params, toks, mode="train")
    full = np.asarray(full, np.float32)

    pb = dict(batch)
    pb["tokens"] = toks[:, :T]
    logits_p, cache = model.prefill(params, pb, max_len=T + 8)
    dec, _ = model.decode_step(params, cache, toks[:, T:T + 1], T)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32)[:, -1],
                               full[:, T - 1], rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(dec, np.float32)[:, 0],
                               full[:, T], rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# M-RoPE
# ---------------------------------------------------------------------------

def test_mrope_text_equals_rope(rng):
    b, t, h, hd = 2, 8, 2, 16
    x = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    std = rope_apply(x, pos, 1e4)
    pos3 = jnp.broadcast_to(pos[..., None], (b, t, 3))
    mr = rope_apply(x, pos3, 1e4, mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(np.asarray(mr), np.asarray(std), rtol=1e-6,
                               atol=1e-6)


def test_rope_relative_invariance(rng):
    """q·k after rope depends only on relative distance."""
    b, h, hd = 1, 1, 32
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, 1, h, hd)).astype(np.float32))

    def dot_at(pq, pk):
        qq = rope_apply(q, jnp.full((b, 1), pq), 1e4)
        kk = rope_apply(k, jnp.full((b, 1), pk), 1e4)
        return float(jnp.sum(qq * kk))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_moe_no_drop_equals_dense_topk(seed):
    """With generous capacity, chunked GShard == explicit per-token top-k."""
    from repro.models.moe import moe_apply, moe_init
    cfg = registry.reduced_config(registry.get_config(
        "granite-moe-3b-a800m"))
    cfg = dataclasses.replace(cfg, moe=MoEConfig(
        n_experts=4, top_k=2, capacity_factor=4.0, router_chunk=32))
    key = jax.random.PRNGKey(seed)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    got, aux = moe_apply(cfg, params, x)
    assert bool(jnp.isfinite(aux))

    # dense reference: route each token independently
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, 2)
    vals = vals / vals.sum(-1, keepdims=True)
    outs = []
    for i in range(xt.shape[0]):
        acc = 0
        for j in range(2):
            e = int(idx[i, j])
            h = xt[i] @ params["wi"][e]
            h = jax.nn.silu(xt[i] @ params["wg"][e]) * h
            acc = acc + vals[i, j] * (h @ params["wo"][e])
        outs.append(acc)
    want = jnp.stack(outs).reshape(2, 16, cfg.d_model)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_gather_matches_onehot_dispatch():
    """The §Perf gather/scatter dispatch must be numerically identical to
    the GShard one-hot baseline (same routing, same capacity drops)."""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.models import moe as moe_lib

    cfg = registry.reduced_config(
        registry.get_config("granite-moe-3b-a800m"), layers=2)
    cfg_g = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="gather"))
    key = jax.random.PRNGKey(3)
    params = moe_lib.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 96, cfg.d_model),
                          jnp.float32)
    y1, a1 = moe_lib.moe_apply(cfg, params, x)
    y2, a2 = moe_lib.moe_apply(cfg_g, params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_int8_kv_cache_close_to_exact():
    """int8 KV cache (per-(pos,head) absmax scales): decode logits stay
    close to the bf16-cache path and greedy tokens agree on a short roll."""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import registry
    from repro.models import api

    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"),
                                  layers=2)
    cfg_q = dataclasses.replace(cfg, kv_dtype="int8")
    model, model_q = api.build(cfg), api.build(cfg_q)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    lg, cache = model.prefill(params, {"tokens": toks}, max_len=24)
    lgq, cache_q = model_q.prefill(params, {"tokens": toks}, max_len=24)
    assert cache_q["superblocks"]["b0"]["k"].dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(lg[:, -1], np.float32),
                               np.asarray(lgq[:, -1], np.float32),
                               atol=0.15, rtol=0.15)
    pos, tok = 12, jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    tok_q = jnp.argmax(lgq[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        lg, cache = model.decode_step(params, cache, tok, jnp.int32(pos))
        lgq, cache_q = model_q.decode_step(params, cache_q, tok_q,
                                           jnp.int32(pos))
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        tok_q = jnp.argmax(lgq[:, -1], -1)[:, None].astype(jnp.int32)
        pos += 1
    assert (np.asarray(tok) == np.asarray(tok_q)).mean() >= 0.5
