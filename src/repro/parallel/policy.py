"""Activation-sharding policy: with_sharding_constraint at block boundaries.

GSPMD propagates shardings from weights/inputs, but with FSDP-sharded
contraction dims it can choose activation-replicated layouts whose partial
sums all-reduce (B, T, ff)-sized tensors — catastrophic.  Pinning the batch
axis on activations at a few seams (embedding output, super-block carry,
xent chunks, logits) forces the weight-gathered FSDP schedule.

Rules are process-global and set by the launcher/dry-run around tracing;
when unset (unit tests, single device) every constrain() is a no-op.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[dict] = None


@contextlib.contextmanager
def activation_rules(batch_axes, model_axis: str = "model",
                     fsdp_gather: bool = False, seq_shard: bool = False,
                     model_par: int = 0):
    """batch_axes: axis name / tuple for the batch dim (None -> unsharded).

    fsdp_gather=True pins every block weight to its gathered (TP-only) form
    at use: GSPMD then all-gathers the FSDP-sharded weight (bytes =
    params/layer) instead of partial-sum all-reducing (B, T, out)
    activations over the data axis — the §Perf fix for collective-bound
    train cells.

    seq_shard=True shards the (B, T, D) inter-block activations on T over
    the model axis (Megatron sequence parallelism): row-parallel output
    all-reduces become reduce-scatter + all-gather pairs and the remat
    carries shrink by the model-axis width.  Ignored for T == 1 (decode).
    """
    global _RULES
    old = _RULES
    _RULES = {"batch": batch_axes, "model": model_axis,
              "fsdp_gather": fsdp_gather, "seq_shard": seq_shard,
              "model_par": model_par}
    try:
        yield
    finally:
        _RULES = old


def _wsc(x, spec: P):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def batch_only(x):
    """(B, ...) -> batch over dp axes, rest unsharded."""
    if _RULES is None:
        return x
    return _wsc(x, P(*((_RULES["batch"],) + (None,) * (x.ndim - 1))))


def batch_model_last(x):
    """(B, ..., V_or_heads) -> batch over dp, last dim over model (logits,
    qkv projections)."""
    if _RULES is None:
        return x
    spec = (_RULES["batch"],) + (None,) * (x.ndim - 2) + (_RULES["model"],)
    return _wsc(x, P(*spec))


def batch_model_at(x, axis: int):
    """batch over dp on dim 0, `axis` over model, rest unsharded (attention
    tensors with a heads dim).  A partial shard (yi's 8 kv heads on the
    16-wide axis) is deliberate: measured, it beats both batch-only pinning
    (+3.1 s collective on yi prefill from replicated-accumulator
    all-gathers) — GSPMD keeps the 8-way shard and replicates 2-way."""
    if _RULES is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _RULES["batch"]
    spec[axis] = _RULES["model"]
    return _wsc(x, P(*spec))


def carry(x):
    """Inter-block (B, T, D) activation pin: batch over dp; with seq_shard,
    T additionally over the model axis (sequence parallelism)."""
    if _RULES is None:
        return x
    if _RULES.get("seq_shard") and x.ndim >= 3 and x.shape[1] > 1:
        spec = ((_RULES["batch"], _RULES["model"])
                + (None,) * (x.ndim - 2))
        return _wsc(x, P(*spec))
    return batch_only(x)


def gather_block_weights(params):
    """Pin every ndim>=2 block weight to its gathered (TP-only) layout at
    point of use (no-op unless fsdp_gather is set).  Path-based rules come
    from parallel/sharding.py with kind="serve" (= the FSDP axis removed),
    so the pin is exactly "this weight, all-gathered over data"."""
    if not (_RULES and _RULES.get("fsdp_gather")):
        return params
    import jax
    from repro.parallel import sharding as shd

    model_par = _RULES.get("model_par") or 0

    def one(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        names = shd._path_names(path)
        expert_div = True
        if leaf.ndim >= 3 and "ffn" in names and model_par:
            expert_div = (leaf.shape[0] % model_par == 0)
        spec = shd.param_spec(names, leaf.ndim, "serve",
                              expert_div=expert_div)
        return _wsc(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, params)
