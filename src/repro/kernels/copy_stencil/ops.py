"""Jitted public entry point for the copy stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.copy_stencil import ref as _ref
from repro.kernels.copy_stencil.copy_stencil import copy_pallas


@functools.partial(jax.jit, static_argnames=("use_pallas", "tr", "interpret"))
def copy_stencil(src, use_pallas: bool = False, tr: int = 256,
                 interpret: bool = True):
    if use_pallas:
        return copy_pallas(src, tr=tr, interpret=interpret)
    return _ref.copy_stencil(src)
