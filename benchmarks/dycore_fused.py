"""Fused vs unfused dycore step — the NERO fusion claim, measured + modeled.

Paper §3 (arxiv 2107.08716): the CPU/GPU baseline round-trips every
intermediate through main memory; the in-fabric pipeline streams each field
once.  This benchmark reports that claim three ways for one full dycore step
(4 prognostic fields):

  * measured wall-clock of `dycore_step(fused=True)` vs `fused=False`
    (CPU note: without a TPU the fused kernel runs in the Pallas
    *interpreter*, so its wall-clock here validates the pipeline, it does
    not demonstrate the speedup — the modeled rows do);
  * modeled HBM traffic per step from core/memmodel.dycore_step_traffic
    (array-level reads/writes each pipeline materializes), with the fused
    y-window halo re-read overhead from the auto-tuned TilePlan;
  * modeled TPU time/energy for the fused plan from core/perfmodel.

Emitted metric names (docs/benchmarks.md):
  dycore_fused/walltime_{fused,unfused}   us per step (measured)
  dycore_fused/traffic_{fused,unfused}    modeled MB per step + reduction
  dycore_fused/model_{fused}              modeled TPU time + bottleneck
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hierarchy as hw
from repro.core import memmodel, perfmodel, tiling
from repro.kernels.dycore_fused import ops as fused_ops
from repro.weather import dycore, fields

GRID = (8, 32, 64)          # small enough for the CPU interpreter
ENSEMBLE = 1
MODEL_GRID = (64, 256, 256)  # the paper's domain, for the modeled rows


def run():
    st = fields.initial_state(jax.random.PRNGKey(0), GRID,
                              ensemble=ENSEMBLE)
    n_fields = len(fields.PROGNOSTIC)

    t_unfused = time_fn(
        lambda s: dycore.dycore_step(s, fused=False), st, iters=3, warmup=1)
    emit("dycore_fused/walltime_unfused", t_unfused,
         f"grid={GRID} ensemble={ENSEMBLE}")
    t_fused = time_fn(
        lambda s: dycore.dycore_step(s, fused=True), st, iters=3, warmup=1)
    backend = jax.default_backend()
    emit("dycore_fused/walltime_fused", t_fused,
         f"grid={GRID} ensemble={ENSEMBLE} backend={backend}"
         + (" (Pallas interpreter — validates, not representative)"
            if backend != "tpu" else ""))

    # Modeled HBM traffic at the paper's domain, auto-tuned fused window.
    for dtype in ("float32", "bfloat16"):
        ty = fused_ops.plan_tile(MODEL_GRID, jnp.dtype(dtype))
        t = memmodel.dycore_step_traffic(MODEL_GRID, dtype,
                                         n_fields=n_fields, ty=ty)
        mb = 1.0 / 2**20
        emit(f"dycore_fused/traffic_unfused_{dtype}", 0.0,
             f"MB={t['unfused']['total'] * mb:.0f} "
             f"vadvc={t['unfused']['vadvc'] * mb:.0f} "
             f"pointwise={t['unfused']['pointwise'] * mb:.0f} "
             f"hdiff={(t['unfused']['hdiff'] + t['unfused']['hdiff_pad']) * mb:.0f}")
        emit(f"dycore_fused/traffic_fused_{dtype}", 0.0,
             f"MB={t['fused']['total'] * mb:.0f} ty={ty} "
             f"halo_overhead={t['halo_overhead'] * 100:.1f}% "
             f"reduction={t['reduction_x']:.2f}x "
             f"(aliased-window pessimistic bound: "
             f"MB={t['fused']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_window_reads']:.2f}x)")

        # Modeled TPU time for the fused plan (per field pipeline pass).
        plan = tiling.TilePlan(op=tiling.DYCORE_FUSED, grid_shape=MODEL_GRID,
                               tile=(MODEL_GRID[0], ty, MODEL_GRID[2]),
                               dtype=dtype)
        est = perfmodel.estimate(plan)
        emit(f"dycore_fused/model_fused_{dtype}",
             est.time_s * n_fields * 1e6,
             f"bottleneck={est.bottleneck} gflops={est.gflops:.0f} "
             f"vmem={100.0 * plan.vmem_bytes / hw.tpu_v5e().vmem.capacity_bytes:.0f}%")


if __name__ == "__main__":
    run()
