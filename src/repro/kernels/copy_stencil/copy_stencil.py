"""Pallas copy stencil: one VMEM-blocked stream per grid step ("PE")."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _copy_kernel(in_ref, out_ref):
    out_ref[...] = in_ref[...]


def copy_pallas(src: jnp.ndarray, tr: int = 256,
                interpret: bool = False) -> jnp.ndarray:
    """src: (rows, cols); rows % tr == 0.  Each grid step streams one
    (tr, cols) window HBM->VMEM->HBM, double-buffered by the pipeline."""
    rows, cols = src.shape
    if rows % tr:
        raise ValueError(f"rows={rows} % tr={tr} != 0")
    spec = pl.BlockSpec((tr, cols), lambda r: (r, 0))
    fn = pl.pallas_call(
        _copy_kernel,
        grid=(rows // tr,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="nero_copy",
    )
    return fn(src)
