"""Fused dycore Pallas kernel vs the unfused oracle composition.

The fused pipeline (vadvc Thomas solve -> point-wise update -> compound
hdiff, all in VMEM) must match the unfused reference that materializes every
intermediate — over shape sweeps, tile sizes (including non-divisible
requests that snap), bf16 I/O, batching, periodicity, and the halo-mode
(pad/crop) trick the distributed domain uses.

Comparison policy: the stage tendency (no limiter upstream) must match to
1e-5 everywhere.  The diffused field must match to 1e-5 at every point whose
flux-limiter branch decision is numerically stable; at the measure-zero set
of fragile points (limiter product within fp32 noise of zero —
`ref.limiter_fragile_mask`) two evaluation orders of the same scheme may
legitimately take different branches, so only a loose physical bound
(coeff-scaled flux magnitude) applies there.
"""

import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune, trace_stats
from repro.kernels.dycore_fused import ops, ref
from repro.kernels.dycore_fused.fused import fused_dycore_pallas
from repro.weather import fields
from repro.weather.program import DycoreProgram, compile_dycore


def _plan(grid, ensemble=1, variant="auto", k_steps=1, **kw):
    return compile_dycore(DycoreProgram(grid_shape=grid, ensemble=ensemble,
                                        variant=variant, k_steps=k_steps),
                          **kw)

SHAPES = [(4, 8, 16), (6, 12, 8), (5, 16, 32), (3, 10, 14), (2, 6, 6)]
DT = ref.DEFAULT_DT
LOOSE = 0.05   # |coeff * flux| scale at a flipped limiter branch


def _inputs(rng, shape, dtype=np.float32):
    mk = lambda s: jnp.asarray((s * rng.normal(size=shape)).astype(dtype))
    return mk(1.0), mk(0.15), mk(0.01), mk(0.01)   # f, wcon, utens, ustage


def _assert_field_close(got, want, f2, atol=1e-5, msg=""):
    """Field comparison aware of limiter-fragile points (module docstring)."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    err = np.abs(got - want)
    fragile = np.asarray(ref.limiter_fragile_mask(f2))
    stable = err[~fragile]
    assert stable.size == 0 or stable.max() <= atol, \
        f"{msg}: stable-point err {stable.max()}"
    assert err.max() <= LOOSE, f"{msg}: fragile-point err {err.max()}"


def _ref_with_f2(f, wcon, ut, us):
    """Unfused reference plus the updated field the limiter consumes."""
    want_f, want_s = ref.fused_step_ref_batched(f, wcon, ut, us)
    return want_f, want_s, f + DT * want_s


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_matches_unfused_ref(shape, rng):
    f, wcon, ut, us = _inputs(rng, shape)
    want_f, want_s, f2 = _ref_with_f2(f, wcon, ut, us)
    ny = shape[1]
    for ty in {2, 3, 5, ny // 2 or 2, ny}:
        ty = ops.snap_ty(ty, ny)
        got_f, got_s = ops.fused_step(f, wcon, ut, us, ty=ty,
                                      interpret=True)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   atol=1e-5, err_msg=f"ty={ty} s {shape}")
        _assert_field_close(got_f, want_f, f2, msg=f"ty={ty} f {shape}")


def test_nondivisible_tile_request_snaps(rng):
    """A requested y-window that does not divide ny must snap to a legal
    divisor instead of erroring (ISSUE: non-divisible tile sizes)."""
    assert ops.snap_ty(5, 16) == 4
    assert ops.snap_ty(7, 12) == 6
    assert ops.snap_ty(6, 7) == 7      # prime ny -> whole-y window
    f, wcon, ut, us = _inputs(rng, (3, 14, 8))
    want_f, want_s, f2 = _ref_with_f2(f, wcon, ut, us)
    got_f, got_s = ops.fused_step(f, wcon, ut, us, ty=5, interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5)
    _assert_field_close(got_f, want_f, f2)


def test_bf16_io(rng):
    """bf16 in/out (the paper's half-precision mode): fp32 internal compute
    keeps the error at bf16 quantization level, not accumulation level."""
    shape = (4, 8, 16)
    f, wcon, ut, us = _inputs(rng, shape)
    want_f, want_s = ref.fused_step_ref(f, wcon, ut, us)
    b = lambda a: a.astype(jnp.bfloat16)
    got_f, got_s = ops.fused_step(b(f), b(wcon), b(ut), b(us), ty=4,
                                  interpret=True)
    assert got_f.dtype == jnp.bfloat16 and got_s.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got_f, np.float32),
                               np.asarray(want_f), atol=0.25)
    np.testing.assert_allclose(np.asarray(got_s, np.float32),
                               np.asarray(want_s), atol=0.25)


def test_batched_matches_per_member(rng):
    shape = (2, 3, 4, 8, 16)   # two leading batch dims
    f, wcon, ut, us = _inputs(rng, shape)
    got_f, got_s = ops.fused_step(f, wcon, ut, us, ty=4, interpret=True)
    assert got_f.shape == shape and got_s.shape == shape
    want_f, want_s, f2 = _ref_with_f2(f, wcon, ut, us)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5)
    _assert_field_close(got_f, want_f, f2)


def test_periodicity(rng):
    """Doubly-periodic domain: shifting every input cyclically shifts the
    output by the same amount (no hidden boundary treatment)."""
    shape = (3, 8, 12)
    f, wcon, ut, us = _inputs(rng, shape)
    out_f, out_s = ops.fused_step(f, wcon, ut, us, ty=4, interpret=True)
    _, ref_s, f2 = _ref_with_f2(f, wcon, ut, us)
    for sy, sx in [(3, 0), (0, 5), (2, 7)]:
        r = lambda a: jnp.roll(jnp.roll(a, sy, axis=-2), sx, axis=-1)
        rf, rs = ops.fused_step(r(f), r(wcon), r(ut), r(us), ty=4,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(r(out_s)),
                                   atol=1e-5, err_msg=f"shift=({sy},{sx})")
        _assert_field_close(rf, r(out_f), r(f2), msg=f"shift=({sy},{sx})")


def test_halo_mode_pad_crop(rng):
    """The distributed domain runs the periodic kernel on a halo-exchanged
    slab and crops the interior; wrap-around garbage must stay inside the
    cropped 2-ring (weather/domain.py `local_step_fused`)."""
    shape = (4, 8, 12)
    H = ref.HALO
    ny, nx = shape[-2:]
    f, wcon, ut, us = _inputs(rng, shape)
    want_f, want_s, f2 = _ref_with_f2(f, wcon, ut, us)
    w = wcon + jnp.roll(wcon, -1, axis=-1)
    pad = ref.pad_periodic
    got_f, got_s = fused_dycore_pallas(pad(f), pad(w), pad(ut), pad(us),
                                       ty=4, interpret=True)
    crop = lambda a: a[..., H:H + ny, H:H + nx]
    np.testing.assert_allclose(np.asarray(crop(got_s)), np.asarray(want_s),
                               atol=1e-5)
    _assert_field_close(crop(got_f), want_f, f2)


def test_dycore_step_fused_matches_unfused():
    """End-to-end: the fused dycore plan vs the unfused-oracle plan, all
    four prognostic fields + stage tendencies."""
    st = fields.initial_state(jax.random.PRNGKey(3), (6, 12, 16), ensemble=2)
    out_f = _plan((6, 12, 16), ensemble=2).step(st)
    out_u = _plan((6, 12, 16), ensemble=2, variant="unfused").step(st)
    for name in fields.PROGNOSTIC:
        np.testing.assert_allclose(
            np.asarray(out_f.stage_tens[name]),
            np.asarray(out_u.stage_tens[name]), atol=1e-5, err_msg=name)
        f2 = st.fields[name] + 0.1 * out_u.stage_tens[name]
        _assert_field_close(out_f.fields[name], out_u.fields[name], f2,
                            msg=name)


def test_autotuned_plan_is_legal():
    for grid in [(8, 16, 32), (64, 256, 256), (4, 10, 14)]:
        ty = ops.plan_tile(grid, jnp.float32)
        assert grid[1] % ty == 0 and 2 <= ty <= grid[1], (grid, ty)


# ---- whole-state fused step (one pallas_call for every field) -------------


def _whole_inputs(rng, shape, dtype=np.float32):
    """shape = (..., nf, nz, ny, nx); wcon drops the field axis."""
    mk = lambda s, sh: jnp.asarray((s * rng.normal(size=sh)).astype(dtype))
    wshape = shape[:-4] + shape[-3:]
    return (mk(1.0, shape), mk(0.15, wshape), mk(0.01, shape),
            mk(0.01, shape))


def _whole_ref(fs, wcon, ut, us):
    wb = jnp.broadcast_to(jnp.expand_dims(wcon, -4), fs.shape)
    want_f, want_s = ref.fused_step_ref_batched(fs, wb, ut, us)
    return want_f, want_s, fs + DT * want_s


@pytest.mark.parametrize("shape", [(4, 5, 12, 16), (2, 3, 8, 8),
                                   (3, 4, 10, 14)])   # incl. non-div. ny
def test_whole_state_matches_oracle(shape, rng):
    """Whole-state fused == per-field fused == unfused oracle, including a
    prime-factor ny that forces the y-window to snap."""
    fs, wcon, ut, us = _whole_inputs(rng, shape)
    want_f, want_s, f2 = _whole_ref(fs, wcon, ut, us)
    got_f, got_s = ops.fused_step_whole_state(fs, wcon, ut, us, ty=5,
                                              interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5, err_msg=f"{shape}")
    _assert_field_close(got_f, want_f, f2, msg=f"{shape}")
    # cross-check against the per-field fused kernel, field by field
    for i in range(shape[0]):
        pf_f, pf_s = ops.fused_step(fs[i], wcon, ut[i], us[i], ty=5,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(got_s[i]), np.asarray(pf_s),
                                   atol=1e-5, err_msg=f"field {i}")
        _assert_field_close(got_f[i], pf_f, f2[i], msg=f"field {i}")


def test_whole_state_batched_and_bf16(rng):
    shape = (2, 4, 4, 8, 16)   # (E, nf, nz, ny, nx)
    fs, wcon, ut, us = _whole_inputs(rng, shape)
    want_f, want_s, f2 = _whole_ref(fs, wcon, ut, us)
    got_f, got_s = ops.fused_step_whole_state(fs, wcon, ut, us, ty=4,
                                              interpret=True)
    assert got_f.shape == shape and got_s.shape == shape
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5)
    _assert_field_close(got_f, want_f, f2)
    b = lambda a: a.astype(jnp.bfloat16)
    bf, bs = ops.fused_step_whole_state(b(fs), b(wcon), b(ut), b(us), ty=4,
                                        interpret=True)
    assert bf.dtype == jnp.bfloat16 and bs.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(bf, np.float32),
                               np.asarray(want_f), atol=0.25)
    np.testing.assert_allclose(np.asarray(bs, np.float32),
                               np.asarray(want_s), atol=0.25)


def test_whole_state_use_pallas_false_oracle(rng):
    fs, wcon, ut, us = _whole_inputs(rng, (4, 3, 8, 8))
    want_f, want_s, _ = _whole_ref(fs, wcon, ut, us)
    got_f, got_s = ops.fused_step_whole_state(fs, wcon, ut, us,
                                              use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-6)


def test_dycore_step_single_pallas_call():
    """The whole-state step must launch exactly ONE Pallas kernel for all
    prognostic fields; the per-field path launches one per field (the
    launch-granularity oracle this PR's tentpole collapses)."""
    st = fields.initial_state(jax.random.PRNGKey(0), (3, 8, 8))
    j = jax.make_jaxpr(_plan((3, 8, 8), interpret=True).step)(st)
    assert trace_stats.count_primitive(j, "pallas_call") == 1
    j = jax.make_jaxpr(_plan((3, 8, 8), variant="per_field",
                             interpret=True).step)(st)
    assert trace_stats.count_primitive(j, "pallas_call") == \
        len(fields.PROGNOSTIC)


def test_dycore_step_whole_state_matches_per_field():
    st = fields.initial_state(jax.random.PRNGKey(4), (5, 12, 16), ensemble=2)
    out_w = _plan((5, 12, 16), ensemble=2, variant="whole_state").step(st)
    out_p = _plan((5, 12, 16), ensemble=2, variant="per_field").step(st)
    out_u = _plan((5, 12, 16), ensemble=2, variant="unfused").step(st)
    for name in fields.PROGNOSTIC:
        np.testing.assert_allclose(
            np.asarray(out_w.stage_tens[name]),
            np.asarray(out_u.stage_tens[name]), atol=1e-5, err_msg=name)
        f2 = st.fields[name] + 0.1 * out_u.stage_tens[name]
        _assert_field_close(out_w.fields[name], out_u.fields[name], f2,
                            msg=name)
        _assert_field_close(out_w.fields[name], out_p.fields[name], f2,
                            msg=name)


def test_interpret_defaults_to_auto():
    """ISSUE 2 satellite: `fused_step`'s interpret default was a hard-coded
    True (TPU callers silently got the interpreter); both entry points must
    now default to None -> `_auto_interpret()`."""
    for fn in (ops.fused_step, ops.fused_step_whole_state):
        assert inspect.signature(fn).parameters["interpret"].default is None
    assert ops._auto_interpret() == (jax.default_backend() != "tpu")


# ---- k-step kernel (the whole round in one pallas_call) -------------------


def _seq_whole_state(fs, wcon, ut, us, k, ty):
    """Oracle: k sequential whole-state launches (the PR 2 scan path)."""
    f, s = fs, us
    for _ in range(k):
        f, s = ops.fused_step_whole_state(f, wcon, ut, s, ty=ty,
                                          interpret=True)
    return f, s


@pytest.mark.parametrize("k", [2, 3])
def test_kstep_matches_sequential_steps(k, rng):
    """ONE k-step launch == k sequential whole-state launches to fp32
    rounding (the k local steps run in-kernel on VMEM state; only
    limiter-fragile points may flip branches across the k-step chain)."""
    shape = (3, 4, 12, 16)   # (nf, nz, ny, nx)
    fs, wcon, ut, us = _whole_inputs(rng, shape)
    ty = 2 * k               # ty >= k*HALO
    want_f, want_s = _seq_whole_state(fs, wcon, ut, us, k, ty)
    got_f, got_s = ops.fused_step_kstep(fs, wcon, ut, us, k_steps=k, ty=ty,
                                        interpret=True)
    for got, want, name in ((got_f, want_f, "f"), (got_s, want_s, "s")):
        err = np.abs(np.asarray(got) - np.asarray(want))
        bad = int((err > 1e-5).sum())
        assert bad <= 2 and err.max() < LOOSE, (name, k, bad, err.max())


def test_kstep_prefetch_matches_windows_path(rng):
    """The double-buffered make_async_copy w prefetch and the aliased-
    BlockSpec fallback are the same arithmetic — bit-identical outputs."""
    shape = (2, 3, 4, 16, 16)   # batched (E, nf, nz, ny, nx)
    fs, wcon, ut, us = _whole_inputs(rng, shape)
    out_pf = ops.fused_step_kstep(fs, wcon, ut, us, k_steps=2, ty=4,
                                  interpret=True, prefetch_w=True)
    out_win = ops.fused_step_kstep(fs, wcon, ut, us, k_steps=2, ty=4,
                                   interpret=True, prefetch_w=False)
    for a, b in zip(out_pf, out_win):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kstep_k1_matches_whole_state(rng):
    """k_steps=1 degenerates to one whole-state step (same round)."""
    fs, wcon, ut, us = _whole_inputs(rng, (4, 3, 8, 16))
    want_f, want_s, f2 = _whole_ref(fs, wcon, ut, us)
    got_f, got_s = ops.fused_step_kstep(fs, wcon, ut, us, k_steps=1, ty=4,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-5)
    _assert_field_close(got_f, want_f, f2)


def test_kstep_bf16_io(rng):
    shape = (3, 4, 8, 16)
    fs, wcon, ut, us = _whole_inputs(rng, shape)
    want_f, want_s = _seq_whole_state(fs, wcon, ut, us, 2, 4)
    b = lambda a: a.astype(jnp.bfloat16)
    got_f, got_s = ops.fused_step_kstep(b(fs), b(wcon), b(ut), b(us),
                                        k_steps=2, ty=4, interpret=True)
    assert got_f.dtype == jnp.bfloat16 and got_s.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got_f, np.float32),
                               np.asarray(want_f, np.float32), atol=0.5)


def test_kstep_single_launch_trace():
    """The whole k-step round must trace to exactly ONE pallas_call — the
    structural claim the PR's tentpole makes (no launch per local step)."""
    st = fields.initial_state(jax.random.PRNGKey(0), (3, 8, 8))
    kplan = _plan((3, 8, 8), variant="kstep", k_steps=2, interpret=True)
    j = jax.make_jaxpr(lambda s: kplan.run(s, 2))(st)
    assert trace_stats.count_primitive(j, "pallas_call") == 1
    # and the non-kstep trajectory of the same length also launches once
    # per step (scan body), so the k-step mode strictly halves launches
    # per simulated step at k=2.
    plan1 = _plan((3, 8, 8), interpret=True)
    j1 = jax.make_jaxpr(lambda s: plan1.run(s, 2))(st)
    assert trace_stats.count_primitive(j1, "pallas_call") == 1  # scan body


def test_kstep_ty_snapping_and_validity_bound():
    """snap_ty_kstep: a divisor of ny respecting ty >= k*HALO; too-small
    requests snap UP (the validity front needs the room), impossible grids
    refuse loudly."""
    assert ops.snap_ty_kstep(8, 16, 2) == 8
    assert ops.snap_ty_kstep(5, 16, 2) == 4      # largest divisor <= 5, >= 4
    assert ops.snap_ty_kstep(2, 16, 3) == 8      # snaps UP past k*HALO=6
    assert ops.snap_ty_kstep(2, 14, 3) == 7      # prime-ish ny
    with pytest.raises(ValueError):
        ops.snap_ty_kstep(4, 4, 3)               # ny < k*HALO: no window
    with pytest.raises(ValueError):
        # kernel-level guard: ty below the validity bound
        from repro.kernels.dycore_fused.fused import fused_dycore_kstep_pallas
        fused_dycore_kstep_pallas(jnp.zeros((2, 3, 8, 8)),
                                  jnp.zeros((3, 8, 8)),
                                  jnp.zeros((2, 3, 8, 8)),
                                  jnp.zeros((2, 3, 8, 8)),
                                  k_steps=3, ty=4, interpret=True)


def test_kstep_vmem_budget_rejection():
    """Tile plans that cannot hold the 3-window scratch + double-buffered w
    prefetch must be rejected loudly, not silently spilled: a huge-x grid
    with a deep k forces ty up to the validity bound and past the VMEM
    budget."""
    with pytest.raises(ValueError, match="VMEM|vmem|fit|legal"):
        ops.plan_tile_kstep((128, 8, 1024), jnp.float32, 4, 4)
    # the same grid at k=1 window granularity is plannable
    assert ops.plan_tile((128, 8, 1024), jnp.float32) >= 2


def test_kstep_tile_space_registered():
    """The k-step tile space lives in the autotune registry; its VMEM
    accounting covers the double buffer (extra_vmem_buffers) so the legal
    window set is tighter than the whole-state space's."""
    spec = autotune.get_op("dycore_kstep")
    assert spec.scratch_fields == 8 and spec.scratch_padded
    assert spec.extra_vmem_buffers == 2.0
    ty = ops.plan_tile_kstep((8, 16, 32), jnp.float32, 4, 2)
    assert 16 % ty == 0 and ty >= 4


def test_whole_state_tile_space_registered():
    """The whole-state tile space is registered with the autotuner and its
    VMEM accounting depends on the field count (shared-w residency)."""
    ty = ops.plan_tile_whole_state((8, 16, 32), jnp.float32, 4)
    assert 16 % ty == 0 and 2 <= ty <= 16
    spec = autotune.get_op("dycore_whole_state")
    assert spec.scratch_fields == 7          # 6 temporaries + resident w
    assert abs(spec.fields_in - (3 + 1 / 4)) < 1e-9
    # planning for another field count tunes its own space without
    # clobbering the registered default
    ty8 = ops.plan_tile_whole_state((8, 16, 32), jnp.float32, 8)
    assert 16 % ty8 == 0 and 2 <= ty8 <= 16
    assert autotune.get_op("dycore_whole_state") == spec
