"""Analytic per-plan performance/energy model (shared by autotuner & roofline).

Mirrors the role of the paper's performance estimates during OpenTuner search:
for a TilePlan we derive the three roofline terms (compute / memory /
collective), predicted time = max of the overlappable terms (dataflow
pipelining overlaps load & compute, the paper's §3 design), and energy from
per-level pJ/byte coefficients.

The machine is an input: every entry point takes a `spec=` — a
`hwspec.HardwareSpec` — and derives peaks, bandwidths, energy coefficients,
and the per-kernel-class sustained utilizations from it.  The default spec is
the TPU v5e the kernels are written for (numerically identical to the
pre-spec literals); passing `power9` or `nero_ad9h7` models the paper's two
machines.  When a spec's kernel class declares a MEASURED wall power (the
paper power-metered each kernel), energy is that power times modeled time
instead of the bottom-up traffic sum.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.core import hierarchy as hw
from repro.core import hwspec
from repro.core.tiling import TilePlan


@dataclasses.dataclass(frozen=True)
class PerfEstimate:
    plan: TilePlan
    compute_s: float
    memory_s: float
    collective_s: float
    vmem_s: float
    time_s: float            # pipelined: max(terms) + fill latency
    gflops: float            # useful GFLOP/s at predicted time
    energy_j: float
    bottleneck: str
    hardware: Optional[str] = None      # spec name the model targeted
    kernel_class: Optional[str] = None  # "streaming" | "solver"

    @property
    def gflops_per_watt(self) -> float:
        if self.time_s == 0:
            return 0.0
        watts = self.energy_j / self.time_s
        return self.gflops / max(watts, 1e-9)


def gflops_per_watt(est: PerfEstimate) -> float:
    """Module-level spelling of `PerfEstimate.gflops_per_watt` (0.0 for a
    zero-time estimate rather than a division error)."""
    return est.gflops_per_watt


def estimate(plan: TilePlan,
             hier: Optional[hw.Hierarchy] = None,
             chips: int = 1,
             collective_bytes: float = 0.0,
             utilization: Optional[float] = None,
             spec: Optional[hwspec.HardwareSpec] = None) -> PerfEstimate:
    """Roofline-style time: terms overlap under the dataflow pipeline, so the
    pipeline throughput is set by the slowest stage.  Peaks are derated by the
    spec's per-kernel-class sustained utilizations (HBM controllers, pipeline
    bubbles, the solver class's sequential-axis stalls); an explicit
    `utilization` overrides both."""
    spec = spec or hwspec.default_spec()
    hier = hier or spec.hierarchy()
    cls_name = hwspec.kernel_class_name(plan.op)
    cls = spec.kernel_classes[cls_name]
    bw_util = utilization if utilization is not None else cls.bw_utilization
    fl_util = utilization if utilization is not None else cls.compute_utilization
    b = hw.dtype_bytes(plan.dtype)
    peak = hier.peak_flops_bf16 if b <= 2 else hier.peak_flops_fp32

    flops = plan.flops_total
    hbm_bytes = plan.hbm_bytes_total
    vmem_bytes = hbm_bytes * 2.0   # staged in + consumed out of VMEM

    compute_s = flops / (chips * peak * fl_util)
    memory_s = hbm_bytes / (chips * hier.hbm.bandwidth_bytes_per_s * bw_util)
    vmem_s = vmem_bytes / (chips * hier.vmem.bandwidth_bytes_per_s)
    coll_s = collective_bytes / (chips * hier.ici_bw) if collective_bytes else 0.0

    # Pipeline fill: one tile's worth of latency before steady state.
    fill_s = (plan.hbm_bytes_per_tile /
              (hier.hbm.bandwidth_bytes_per_s * bw_util))
    time_s = max(compute_s, memory_s, vmem_s, coll_s) + fill_s

    terms = {"compute": compute_s, "memory": memory_s,
             "vmem": vmem_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    if cls.watts is not None:
        # The spec recorded this class's measured sustained wall power
        # (paper Table 3 / Fig. 8); trust it over the traffic model.
        energy = cls.watts * time_s * chips
    else:
        energy = (hbm_bytes * hier.hbm.energy_pj_per_byte
                  + vmem_bytes * hier.vmem.energy_pj_per_byte
                  + collective_bytes * spec.collective.energy_pj_per_byte
                  + flops * spec.energy_pj_per_flop) * 1e-12
        energy += spec.idle_watts * time_s * chips   # static power floor

    gflops = flops / time_s / 1e9 if time_s > 0 else 0.0
    return PerfEstimate(plan=plan, compute_s=compute_s, memory_s=memory_s,
                        collective_s=coll_s, vmem_s=vmem_s, time_s=time_s,
                        gflops=gflops, energy_j=energy, bottleneck=bottleneck,
                        hardware=spec.name, kernel_class=cls_name)


def roofline_fraction(est: PerfEstimate,
                      hier: Optional[hw.Hierarchy] = None,
                      chips: int = 1,
                      spec: Optional[hwspec.HardwareSpec] = None) -> float:
    """Achieved fraction of the roofline bound for this op's arithmetic
    intensity (1.0 = sitting on the roof)."""
    if hier is None:
        hier = (spec or (hwspec.load_spec(est.hardware) if est.hardware
                         else hwspec.default_spec())).hierarchy()
    b = hw.dtype_bytes(est.plan.dtype)
    peak = hier.peak_flops_bf16 if b <= 2 else hier.peak_flops_fp32
    ai = est.plan.op.arithmetic_intensity(est.plan.dtype)
    roof = min(peak, ai * hier.hbm.bandwidth_bytes_per_s) * chips
    if est.plan.op.flops_per_point == 0.0:
        # bandwidth kernels (copy): fraction of peak HBM bandwidth instead.
        if est.time_s == 0:
            return 0.0
        achieved_bw = est.plan.hbm_bytes_total / est.time_s
        return achieved_bw / (hier.hbm.bandwidth_bytes_per_s * chips)
    if est.time_s == 0:
        return 0.0
    achieved = est.plan.flops_total / est.time_s
    return achieved / roof


def estimate_by_hardware(op, grid_shape: Sequence[int], dtype,
                         specs: Optional[Sequence[str]] = None,
                         chips: int = 1,
                         collective_bytes: float = 0.0
                         ) -> Dict[str, PerfEstimate]:
    """The paper's cross-machine table, one op at a time: re-tune the tile
    plan FOR each spec's hierarchy (each machine gets its own best window,
    as NERO and POWER9 do in the paper) and model it under that spec.
    Returns `{spec_name: PerfEstimate}` for every shipped spec by default."""
    from repro.core import autotune   # local import: autotune imports us

    out: Dict[str, PerfEstimate] = {}
    for name in (specs or hwspec.available_specs()):
        spec = hwspec.load_spec(name)
        tuned = autotune.tune(op, grid_shape, dtype, spec=spec, chips=chips)
        out[name] = estimate(tuned.plan, chips=chips,
                             collective_bytes=collective_bytes, spec=spec)
    return out
