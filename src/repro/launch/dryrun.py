import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real train/prefill/decode step with production
shardings, lowers it against ShapeDtypeStruct inputs (no allocation),
compiles the SPMD executable, and records:
  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — per-device FLOPs / bytes for the roofline,
  * collective op bytes parsed from the compiled HLO,
  * the derived roofline terms (core/roofline.py).

Results are cached as JSON under benchmarks/results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.core import hlo_cost, memmodel
from repro.core import roofline as rl
from repro.models import api
from repro.parallel import policy
from repro.parallel import sharding as shd
from repro.train import loop as train_loop
from repro.train import optim as opt_lib
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")
RESULTS_DIR = os.path.abspath(RESULTS_DIR)


def _spec_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(model: api.Model, shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = model.cfg
    if shape.kind == "train":
        return model.batch_spec(shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return model.batch_spec(shape.global_batch, shape.seq_len)
    # decode
    spec = {"token": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                          jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encdec.encoder_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return spec


def build_cell(arch: str, shape_name: str, mesh, *, remat: str = "full",
               microbatches: int = 1, serve_param_kind: str = "serve",
               scan_unroll: bool = False, moe_impl: str = "",
               moe_chunk: int = 0, grad_dtype: str = "float32",
               kv_dtype: str = ""):
    """Returns (fn, example_args, in_shardings, out_shardings, meta).

    scan_unroll=False: cells compile in scan form (layer scan body appears
    once — mandatory for 60-80-layer archs on one build host) and the
    roofline pass recovers exact totals with core/hlo_cost.py, which
    multiplies each while body by the trip count XLA records in
    backend_config known_trip_count."""
    cfg = registry.get_config(arch)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    if cfg.moe and (moe_impl or moe_chunk):
        kw = {}
        if moe_impl:
            kw["impl"] = moe_impl
        if moe_chunk:
            kw["router_chunk"] = moe_chunk
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **kw))
    shape = SHAPES[shape_name]
    model = api.build(cfg)
    chips = mesh.devices.size

    p_shapes = model.param_shapes()

    if shape.kind == "train":
        opt_cfg = opt_lib.OptConfig()
        step_fn, _, (p_shard, o_shard) = train_loop.make_train_step(
            model, mesh, opt_cfg, microbatches=microbatches, remat=remat,
            scan_unroll=scan_unroll, grad_dtype=grad_dtype)
        o_shapes = jax.eval_shape(opt_lib.init_opt_state, p_shapes)
        batch = model.batch_spec(shape.global_batch, shape.seq_len)
        b_shard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, shd.data_spec(mesh, s.shape[0], len(s.shape))), batch)
        rep = NamedSharding(mesh, P())
        m_shard = {"grad_norm": rep, "lr": rep, "loss": rep}
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, m_shard)
        args = (p_shapes, o_shapes, batch)
        tokens = shape.global_batch * shape.seq_len
        return step_fn, args, in_sh, out_sh, dict(
            model=model, tokens=tokens, kind="train", chips=chips,
            p_shapes=p_shapes, p_shard=p_shard)

    p_shard = shd.params_sharding(p_shapes, mesh, serve_param_kind)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            logits, cache = model.prefill(params, batch,
                                          max_len=shape.seq_len,
                                          scan_unroll=scan_unroll)
            return logits[:, -1:], cache

        batch = model.batch_spec(shape.global_batch, shape.seq_len)
        b_shard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, shd.data_spec(mesh, s.shape[0], len(s.shape))), batch)
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_shard = shd.cache_sharding(cache_shapes, mesh, shape.global_batch)
        in_sh = (p_shard, b_shard)
        out_sh = (None, c_shard)
        args = (p_shapes, batch)
        tokens = shape.global_batch * shape.seq_len
        return prefill_fn, args, in_sh, out_sh, dict(
            model=model, tokens=tokens, kind="prefill", chips=chips,
            p_shapes=p_shapes, p_shard=p_shard, cache_shapes=cache_shapes,
            cache_shard=c_shard)

    # decode: one new token against a seq_len-deep cache
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_shard = shd.cache_sharding(cache_shapes, mesh, shape.global_batch)

    def decode_fn(params, cache, token, pos):
        logits, new_cache = model.decode_step(params, cache, token, pos,
                                              scan_unroll=scan_unroll)
        return logits, new_cache

    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_shard = NamedSharding(mesh, shd.data_spec(mesh, shape.global_batch,
                                                  2))
    rep = NamedSharding(mesh, P())
    in_sh = (p_shard, c_shard, tok_shard, rep)
    out_sh = (None, c_shard)
    args = (p_shapes, cache_shapes, tok, pos)
    tokens = shape.global_batch * 1
    return decode_fn, args, in_sh, out_sh, dict(
        model=model, tokens=tokens, kind="decode", chips=chips,
        p_shapes=p_shapes, p_shard=p_shard, cache_shapes=cache_shapes,
        cache_shard=c_shard)


def choose_microbatches(cfg, shape, mesh) -> int:
    """Smallest gradient-accumulation depth whose analytic per-device
    estimate fits HBM (the production launcher's knob; recorded in the
    dry-run JSON).  Non-train shapes always use 1."""
    if shape.kind != "train":
        return 1
    model = api.build(cfg)
    p_shapes = model.param_shapes()
    p_shard = shd.params_sharding(p_shapes, mesh, "train")
    b_axes = shd.batch_sharding(mesh, shape.global_batch)
    dp = 1
    if b_axes:
        axes = b_axes if isinstance(b_axes, tuple) else (b_axes,)
        dp = math.prod(mesh.shape[a] for a in axes)
    cap = max(shape.global_batch // dp, 1)
    mb = 1
    while mb < cap:
        est = memmodel.estimate(cfg, shape, mesh, p_shapes, p_shard,
                                microbatches=mb)
        if est["fits_16g"]:
            break
        mb *= 2
    return min(mb, cap)


def attn_kernel_addback(cfg, shape, mesh) -> float:
    """Analytic per-device HBM bytes of the Pallas flash kernel (KV blocks
    re-streamed once per q block; q/o boundary traffic is already charged
    at the out-of-scope projection dots).  The kernelized-variant roofline
    = HLO bytes with the flash_mha scope zeroed + this add-back."""
    from repro.kernels.flash_attention.ops import auto_blocks
    if shape.kind == "decode":
        return 0.0                       # decode path is not the flash scope
    b_axes = shd.batch_sharding(mesh, shape.global_batch)
    dp = 1
    if b_axes:
        axes = b_axes if isinstance(b_axes, tuple) else (b_axes,)
        dp = math.prod(mesh.shape[a] for a in axes)
    b_loc = max(shape.global_batch // dp, 1)
    dtype_b = 2
    passes = 3.0 if shape.kind == "train" else 1.0   # fwd + remat + bwd

    def one(n_calls: int, t: int, s: int) -> float:
        """KV re-stream bytes for n_calls attentions of query len t over
        kv len s: (nq - 1) extra passes over the K+V tensors."""
        if n_calls == 0 or t <= 0 or s <= 0:
            return 0.0
        bq, _ = auto_blocks(t, s, cfg.hd, dtype_b)
        nq = max(t // bq, 1)
        kv = 2 * b_loc * s * cfg.n_kv_heads * cfg.hd * dtype_b
        return float(n_calls * (nq - 1) * kv * passes)

    t = shape.seq_len
    if cfg.encdec:
        # enc self-attn over encoder_len; dec self-attn over t; cross-attn
        # streams the 1500-frame encoder KV, NOT the decoder sequence.
        f = cfg.encdec.encoder_len
        return (one(cfg.encdec.encoder_layers, f, f)
                + one(cfg.n_layers, t, t)
                + one(cfg.n_layers, t, f))
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.pattern[i % len(cfg.pattern)] in
                 ("attn", "local", "global"))
    return one(n_attn, t, t)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             remat: str = "full", microbatches: int = 0,
             variant: str = "baseline", force: bool = False,
             donate: bool = True, attn_kernel: bool = False,
             moe_impl: str = "", moe_chunk: int = 0,
             fsdp_gather: bool = False, seq_shard: bool = False,
             grad_dtype: str = "float32", kv_dtype: str = "") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}__{variant}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = registry.get_config(arch)
    why_skip = registry.skips(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "remat": remat,
              "microbatches": microbatches}
    if why_skip:
        result.update(status="skipped", reason=why_skip)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    shape = SHAPES[shape_name]
    if not microbatches:
        microbatches = choose_microbatches(cfg, shape, mesh)
        result["microbatches"] = microbatches
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh, meta = build_cell(
            arch, shape_name, mesh, remat=remat, microbatches=microbatches,
            moe_impl=moe_impl, moe_chunk=moe_chunk, grad_dtype=grad_dtype,
            kv_dtype=kv_dtype)
        donate_argnums = ()
        if donate and meta["kind"] == "train":
            donate_argnums = (0, 1)
        elif donate and meta["kind"] == "decode":
            donate_argnums = (1,)
        batch_axes = shd.batch_sharding(mesh, shape.global_batch)
        with mesh, policy.activation_rules(
                batch_axes, fsdp_gather=fsdp_gather, seq_shard=seq_shard,
                model_par=mesh.shape.get("model", 1)):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate_argnums)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        mem = {}
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                mem[k] = int(getattr(ma, k, 0))
            live = (mem["argument_size_in_bytes"]
                    + mem["temp_size_in_bytes"]
                    + mem["output_size_in_bytes"]
                    - mem["alias_size_in_bytes"])
            # XLA:CPU fuses far less than TPU -> temp_size overestimates
            # TPU liveness; recorded as a labeled proxy.  The analytic model
            # below is the fit criterion (see EXPERIMENTS.md §Dry-run).
            mem["xla_cpu_live_bytes_per_device"] = int(live)
        analytic = memmodel.estimate(
            cfg, shape, mesh, meta["p_shapes"], meta["p_shard"],
            meta.get("cache_shapes"), meta.get("cache_shard"),
            microbatches=microbatches)
        mem["analytic"] = {k: int(v) if not isinstance(v, bool) else v
                           for k, v in analytic.items()}
        mem["fits_16g"] = analytic["fits_16g"]

        xla_cost = compiled.cost_analysis() or {}
        xla_small = {k: float(v) for k, v in xla_cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")}
        # Loop-aware totals from the compiled HLO (core/hlo_cost.py):
        # while bodies x known_trip_count — exact where XLA's own
        # cost_analysis counts loop bodies once.
        scopes = ("flash_mha",) if attn_kernel else ()
        lc = hlo_cost.analyze_text(compiled.as_text(),
                                   zero_byte_scopes=scopes)
        addback = (attn_kernel_addback(cfg, shape, mesh)
                   if attn_kernel else 0.0)
        cost_small = {
            "flops": lc.flops,
            "bytes accessed": lc.bytes_accessed + addback,
            "bytes fused": lc.bytes_fused + addback,
            "transcendentals": lc.transcendentals,
            "xla_flops_loops_once": xla_small.get("flops", 0.0),
            "xla_bytes_loops_once": xla_small.get("bytes accessed", 0.0),
        }
        if attn_kernel:
            cost_small["attn_kernel_addback_bytes"] = addback
        coll = {k: int(v) for k, v in lc.collective_bytes.items()}
        mf = rl.model_flops(cfg.param_count(), cfg.active_param_count(),
                            meta["tokens"], meta["kind"])
        terms = rl.analyze(cost_small, coll, chips, mf)
        result.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=mem, cost=cost_small,
            collectives=coll,
            tokens=meta["tokens"],
            model_flops=mf,
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
            roofline=dict(
                compute_s=terms.compute_s, memory_s=terms.memory_s,
                collective_s=terms.collective_s, dominant=terms.dominant,
                step_time_bound_s=terms.step_time_s,
                useful_flops_ratio=terms.useful_flops_ratio,
                roofline_fraction=terms.roofline_fraction),
        )
    except Exception as e:      # noqa: BLE001 — record the failure
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=("full", "dots", "none"))
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (smallest depth that fits HBM)")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--attn-kernel", action="store_true",
                    help="kernelized-attention roofline: zero-byte the "
                         "flash_mha scope + analytic kernel traffic")
    ap.add_argument("--moe-impl", default="",
                    choices=("", "onehot", "gather"),
                    help="override MoE dispatch implementation")
    ap.add_argument("--moe-chunk", type=int, default=0,
                    help="override MoE router chunk (tokens)")
    ap.add_argument("--fsdp-gather", action="store_true",
                    help="pin block weights to gathered layout at use")
    ap.add_argument("--grad-bf16", action="store_true",
                    help="bf16 microbatch grad accumulation/reduction")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache with per-(pos,head) scales")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel inter-block activations")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in registry.ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    for mesh_kind in meshes:
        for arch, shape in cells:
            r = run_cell(arch, shape, mesh_kind, remat=args.remat,
                         microbatches=args.microbatches,
                         variant=args.variant, force=args.force,
                         attn_kernel=args.attn_kernel,
                         moe_impl=args.moe_impl, moe_chunk=args.moe_chunk,
                         fsdp_gather=args.fsdp_gather,
                         seq_shard=args.seq_shard,
                         grad_dtype="bfloat16" if args.grad_bf16
                         else "float32",
                         kv_dtype="int8" if args.kv_int8 else "")
            line = {k: r.get(k) for k in ("arch", "shape", "mesh", "status")}
            if r.get("status") == "ok":
                line["dominant"] = r["roofline"]["dominant"]
                line["fit"] = r["memory"].get("fits_16g")
                line["compile_s"] = r.get("compile_s")
                line["GB/dev"] = round(
                    r["memory"].get("analytic", {}).get("total", 0)
                    / 2**30, 2)
                line["GB/dev_xla_cpu"] = round(
                    r["memory"].get("xla_cpu_live_bytes_per_device", 0)
                    / 2**30, 2)
            elif r.get("status") == "error":
                line["error"] = r.get("error", "")[:140]
            else:
                line["reason"] = r.get("reason")
            print(json.dumps(line))


if __name__ == "__main__":
    main()
