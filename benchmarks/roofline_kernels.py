"""Paper Fig. 1 — roofline placement of vadvc / hdiff, per hardware spec.

Computes each kernel's arithmetic intensity and its position under every
shipped spec's roofline (POWER9 — the paper's measured baseline, whose
Fig. 1 points now live in the spec's `reference_points` — NERO, and the
TPU v5e target), from the analytic op specs; the wall-clock column is the
measured jnp reference on this process's backend (labeled 'cpu-jnp').

`roofline_block()` is the embeddable form: `benchmarks/run.py` folds it
into `BENCH_dycore.json` as `roofline_by_hardware`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hwspec, perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href
from repro.kernels.vadvc import ref as vref

GRID = (64, 256, 256)    # the paper's 256x256x64 domain


def roofline_block(grid=GRID, dtype: str = "float32") -> dict:
    """Per-kernel, per-spec roofline points: the roof at the kernel's
    arithmetic intensity, the modeled achieved GFLOPS under the spec's
    sustained-utilization class, the achieved fraction, machine balance,
    and the spec's recorded paper reference — JSON-embeddable."""
    block: dict = {"grid_shape": list(grid), "dtype": dtype, "specs": {},
                   "kernels": {}}
    names = hwspec.available_specs()
    for n in names:
        spec = hwspec.load_spec(n)
        block["specs"][n] = dict(spec.describe(),
                                 machine_balance=spec.hierarchy()
                                 .machine_balance(dtype))
    for op in (tiling.HDIFF, tiling.VADVC):
        ai = op.arithmetic_intensity(dtype)
        ests = perfmodel.estimate_by_hardware(op, grid, dtype, specs=names)
        row: dict = {}
        for n, est in ests.items():
            spec = hwspec.load_spec(n)
            peak = spec.peak_flops_for(dtype)
            roof = min(peak, ai * spec.main.bandwidth_bytes_per_s)
            ref = spec.reference_points.get(op.name, {})
            row[n] = {"arithmetic_intensity": ai,
                      "roof_gflops": roof / 1e9,
                      "model_gflops": est.gflops,
                      "roofline_fraction": est.gflops * 1e9 / roof,
                      "bottleneck": est.bottleneck,
                      "paper_gflops": ref.get("gflops")}
        block["kernels"][op.name] = row
    return block


def run():
    rng = np.random.default_rng(0)
    nz, ny, nx = GRID
    src = jnp.asarray(rng.normal(size=GRID).astype(np.float32))
    us, up, ut, uts = (jnp.asarray(rng.normal(size=GRID).astype(np.float32))
                       for _ in range(4))
    wcon = jnp.asarray(
        rng.uniform(-0.2, 0.2, size=(nz, ny, nx + 1)).astype(np.float32))

    hd_t = time_fn(jax.jit(href.hdiff), src)
    va_t = time_fn(jax.jit(vref.vadvc), us, wcon, up, ut, uts)

    block = roofline_block()
    for name, t_us in (("hdiff", hd_t), ("vadvc", va_t)):
        row = block["kernels"][name]
        parts = []
        for sname, r in row.items():
            parts.append(f"{sname}_roof={r['roof_gflops']:.0f}GF "
                         f"{sname}_model={r['model_gflops']:.0f}GF")
            if r["paper_gflops"] is not None:
                parts.append(f"{sname}_paper={r['paper_gflops']}GF")
        ai = row[next(iter(row))]["arithmetic_intensity"]
        emit(f"fig1/{name}", t_us, f"AI={ai:.2f}flop/B " + " ".join(parts))
    balances = " ".join(
        f"{n}={s['machine_balance']:.1f}flop/B"
        for n, s in block["specs"].items())
    emit("fig1/machine_balance", 0.0, balances)


if __name__ == "__main__":
    run()
