"""NERO kernel package: copy_stencil."""
