"""Loop-aware HLO cost analyzer vs XLA's own cost_analysis.

Validation strategy (the analyzer is what makes scanned dry-run cells give
exact roofline terms):
  1. multipliers forced to 1  -> must match compiled.cost_analysis(),
  2. scanned fn, real multipliers -> must match the fully-unrolled compile,
  3. trip counts parsed from backend_config must equal the scan length,
  4. in-loop collectives are multiplied (the term XLA drops entirely).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost


def _cost(compiled) -> dict:
    """compiled.cost_analysis() returns a dict in jax >= 0.5, a one-element
    list of dicts in 0.4.x."""
    c = compiled.cost_analysis()
    return c[0] if isinstance(c, (list, tuple)) else c


def _body(c, _):
    (x,) = c
    return (jnp.tanh(x @ x),), None


def _scanned(x, n):
    (y,), _ = jax.lax.scan(_body, (x,), None, length=n)
    return y


def _unrolled(x, n):
    for _ in range(n):
        x = jnp.tanh(x @ x)
    return x


@pytest.fixture(scope="module")
def compiled_pair():
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cs = jax.jit(lambda x: _scanned(x, 12)).lower(spec).compile()
    cu = jax.jit(lambda x: _unrolled(x, 12)).lower(spec).compile()
    return cs, cu


def test_multiplier_one_matches_xla(compiled_pair):
    cs, _ = compiled_pair
    xla = _cost(cs)
    mine = hlo_cost.analyze_text(cs.as_text(), loop_multipliers=False)
    assert mine.flops == pytest.approx(xla["flops"], rel=0.02)
    assert mine.bytes_accessed == pytest.approx(xla["bytes accessed"],
                                                rel=0.05)
    assert mine.transcendentals == pytest.approx(
        xla.get("transcendentals", 0.0), rel=0.02)


def test_loop_aware_matches_unrolled(compiled_pair):
    cs, cu = compiled_pair
    xla_unrolled = _cost(cu)
    mine = hlo_cost.analyze_text(cs.as_text())
    assert mine.while_trip_counts == [12]
    assert mine.flops == pytest.approx(xla_unrolled["flops"], rel=0.02)
    assert mine.bytes_accessed == pytest.approx(
        xla_unrolled["bytes accessed"], rel=0.05)


def test_nested_scan_multiplies_both_levels():
    def inner(c, _):
        return jnp.sin(c * 2.0), None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=5)
        return y @ y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=7)
        return y

    spec = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(spec).compile()
    mine = hlo_cost.analyze_text(c.as_text())
    assert sorted(mine.while_trip_counts) == [5, 7]
    # 7 outer iterations x one 32x32x32 matmul each
    assert mine.flops >= 7 * 2 * 32 ** 3
    # 35 sin applications of 1024 elements
    assert mine.transcendentals == pytest.approx(35 * 1024, rel=0.02)


_COLL_SNIPPET = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import hlo_cost

mesh = jax.make_mesh((4,), ("d",))

def body(c, _):
    return jax.lax.psum(c, "d") * 0.5, None

def f(x):
    y, _ = jax.lax.scan(body, x, None, length=9)
    return y

from repro.compat import shard_map
smap = shard_map(f, mesh, in_specs=P("d"), out_specs=P(None))
spec = jax.ShapeDtypeStruct((8, 128), jnp.float32)
c = jax.jit(smap).lower(spec).compile()
mine = hlo_cost.analyze_text(c.as_text())
ar = mine.collective_bytes.get("all-reduce", 0.0)
# 9 iterations x per-device (2,128) f32 shard = 9 x 1024 B
assert ar == 9 * 2 * 128 * 4, mine.collective_bytes
print("COLL_OK")
"""


def test_inloop_collective_bytes_multiplied():
    """In-loop collectives get the trip-count multiplier (XLA's own
    cost_analysis misses them entirely).  Runs with 4 forced host devices."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _COLL_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "COLL_OK" in r.stdout, r.stderr[-2000:]


def test_dot_general_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    sa = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    sb = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    c = jax.jit(f).lower(sa, sb).compile()
    mine = hlo_cost.analyze_text(c.as_text())
    xla = _cost(c)
    assert mine.flops == pytest.approx(xla["flops"], rel=0.02)
    assert mine.flops == pytest.approx(2 * 4 * 16 * 32 * 8, rel=0.02)


def test_zero_byte_scope_credits_bytes_not_flops():
    """Kernel-credit accounting: ops under a named scope (and everything
    they call, incl. scan bodies whose metadata XLA drops) charge zero HBM
    bytes; FLOPs are never zeroed."""
    def body(c, _):
        with jax.named_scope("hot_kernel"):
            c = jnp.tanh(c @ c)
        return c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=6)
        return y * 2.0

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(spec).compile()
    base = hlo_cost.analyze_text(c.as_text())
    cred = hlo_cost.analyze_text(c.as_text(),
                                 zero_byte_scopes=("hot_kernel",))
    assert cred.flops == base.flops
    assert cred.transcendentals == base.transcendentals
    assert cred.bytes_fused < base.bytes_fused * 0.5
    assert cred.bytes_accessed < base.bytes_accessed
