"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
On this 1-core CPU container a 200-step run takes hours; for a quick
functional pass use:
      PYTHONPATH=src python examples/train_lm.py --steps 6 --batch 2 --seq 64
(the same driver runs the full setting on a real pod).
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.models import api
from repro.train import loop, optim
from repro.launch.mesh import make_mesh

# ~100M params: 12 layers, d=768 (tinyllama family); param_count() = 129M
CFG_100M = ModelConfig(
    name="demo-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=3072, vocab_size=16384, pattern=("attn",), rope_theta=1e4,
    norm="rms", gated_mlp=True, act="silu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/nero_train_lm")
    args = ap.parse_args()

    cfg = CFG_100M
    model = api.build(cfg)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} seq {args.seq}")
    mesh = make_mesh((1, 1), ("data", "model"))
    data = synthetic.iterator(cfg, args.batch, args.seq)
    opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps)
    params, _, hist = loop.fit(model, mesh, data, steps=args.steps,
                               opt_cfg=opt_cfg, ckpt_dir=args.ckpt_dir,
                               ckpt_every=100, log_every=20)
    if not hist:
        print(f"checkpoint in {args.ckpt_dir} is already at step "
              f">= {args.steps}; nothing to do (rm -r it to retrain)")
        print("train_lm OK")
        return
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if len(hist) > 20:
        assert hist[-1]["loss"] < hist[0]["loss"]
    print("train_lm OK")


if __name__ == "__main__":
    main()
