"""COSMO-like dynamical core built from the paper's compound kernels.

One timestep applies the three computational patterns the paper names
(§1): horizontal stencils (hdiff), tridiagonal solves in the vertical
(vadvc), and point-wise computation (the explicit update).  It is a
*representative* dycore, faithful to the kernels and their composition, not a
full COSMO port.

The execution strategy — unfused oracle / per-field fused / whole-state
fused / in-kernel k-step, tile choice, interpret mode — is resolved by the
declarative plan API in `weather/program.py`:

    from repro.weather.program import DycoreProgram, compile_dycore
    plan = compile_dycore(DycoreProgram(grid_shape=(16, 64, 64)))
    state = plan.step(state)          # one round
    state = plan.run(state, steps=10)

`dycore_step(...)` and `run(...)` below are the LEGACY flag-soup entry
points, kept as thin deprecated shims (they build a program and call
`compile_dycore` under the hood, emitting `DeprecationWarning`) so the
historical oracle/equivalence tests keep their meaning bit-for-bit.  The
periodic per-kernel helpers (`hdiff_periodic`, `vadvc_field`) and the
state stack/unstack utilities stay first-class — the plan lowering in
`weather/program.py` builds on them.

The domain is doubly periodic in (y, x) — the standard dycore test setup —
so the distributed version (weather/domain.py + program.py) only needs
circular halo exchanges.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.kernels.dycore_fused.ref import pad_periodic
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC, WeatherState

HALO = 2   # hdiff needs 2; vadvc needs 1 (staggered wcon)

_DEPRECATED = (
    "weather.dycore.{name}(fused=..., whole_state=..., ...) is deprecated: "
    "build a DycoreProgram and call repro.weather.program.compile_dycore() "
    "— the returned ExecutionPlan resolves variant/tile/k-step/exchange "
    "once and exposes step()/run()/report().")


def hdiff_periodic(src: jnp.ndarray, coeff: float) -> jnp.ndarray:
    """Periodic compound horizontal diffusion of a (..., nz, ny, nx) field."""
    ny, nx = src.shape[-2:]
    flat = src.reshape((-1,) + src.shape[-3:])

    def one(f):
        padded = pad_periodic(f, HALO)
        out = hdiff_ref.hdiff(padded, coeff=coeff)
        return out[:, HALO:HALO + ny, HALO:HALO + nx]

    return jax.vmap(one)(flat).reshape(src.shape)


def vadvc_field(u_stage, wcon, u_pos, utens, utens_stage):
    """vadvc over a (..., nz, ny, nx) field.  `wcon` is (..., nz, ny, nx)
    and is wrap-padded to the staggered (nx+1) extent (periodic domain)."""
    shape = u_stage.shape
    wcon_s = jnp.concatenate([wcon, wcon[..., :1]], axis=-1)
    flat = lambda a: a.reshape((-1,) + a.shape[-3:])
    out = jax.vmap(vadvc_ref.vadvc)(flat(u_stage), flat(wcon_s), flat(u_pos),
                                    flat(utens), flat(utens_stage))
    return out.reshape(shape)


def stack_state(d: dict, names=PROGNOSTIC) -> jnp.ndarray:
    """Stack the per-field dict onto a new axis -4: (..., nf, nz, ny, nx).
    `names` fixes the field order (a program's field set; default: the
    full prognostic set) — the single home of the layout convention the
    plan lowering (`weather/program.py`) builds on."""
    return jnp.stack([d[name] for name in names], axis=-4)


def unstack_state(a: jnp.ndarray, names=PROGNOSTIC) -> dict:
    """Inverse of `stack_state`."""
    return {name: jnp.take(a, i, axis=-4) for i, name in enumerate(names)}


# ---------------------------------------------------------------------------
# Deprecated flag-soup shims (the pre-plan API, kept for the oracle tests)
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict = {}


def _shim_plan(state: WeatherState, *, variant, k_steps, coeff, dt,
               interpret):
    """Build (and cache) the ExecutionPlan a legacy call maps onto."""
    from repro.weather.program import DycoreProgram, compile_dycore
    ensemble = int(state.wcon.shape[0]) if state.wcon.ndim == 4 else 1
    key = (state.grid_shape, str(state.wcon.dtype), ensemble, variant,
           k_steps, coeff, dt, interpret)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        prog = DycoreProgram(grid_shape=state.grid_shape, ensemble=ensemble,
                             dtype=str(state.wcon.dtype), coeff=coeff,
                             dt=dt, variant=variant, k_steps=k_steps)
        plan = compile_dycore(prog, interpret=interpret)
        _PLAN_CACHE[key] = plan
    return plan


def _variant(fused: bool, whole_state: bool) -> str:
    if not fused:
        return "unfused"
    return "auto" if whole_state else "per_field"


def dycore_step(state: WeatherState, coeff: float = 0.025,
                dt: float = 0.1, fused: bool = True,
                whole_state: bool = True,
                interpret: bool | None = None) -> WeatherState:
    """DEPRECATED shim: one timestep through the flags-era entry point.

    `fused=True, whole_state=True` (default) is the whole-state fused
    variant (ONE Pallas launch), `whole_state=False` the per-field fused
    pipeline, `fused=False` the unfused oracle composition.  The call maps
    onto `compile_dycore` under the hood and returns bit-identical results
    to the equivalent plan's `step`."""
    warnings.warn(_DEPRECATED.format(name="dycore_step"), DeprecationWarning,
                  stacklevel=2)
    plan = _shim_plan(state, variant=_variant(fused, whole_state), k_steps=1,
                      coeff=coeff, dt=dt, interpret=interpret)
    return plan.step(state)


def run(state: WeatherState, steps: int, coeff: float = 0.025,
        dt: float = 0.1, fused: bool = True,
        whole_state: bool = True, k_steps: int = 1,
        interpret: bool | None = None) -> WeatherState:
    """DEPRECATED shim: advance `steps` timesteps through the flags-era
    entry point.  With `k_steps > 1` (fused whole-state path) the
    trajectory runs as k-step rounds — ONE Pallas launch each, the k local
    steps iterated in-kernel on VMEM state — plus, when `steps` is not a
    multiple, one shorter ragged tail round (`ExecutionPlan.run`)."""
    warnings.warn(_DEPRECATED.format(name="run"), DeprecationWarning,
                  stacklevel=2)
    if k_steps != "auto" and (not isinstance(k_steps, int) or k_steps < 1):
        raise ValueError(f"k_steps={k_steps!r} must be >= 1")
    if k_steps != 1 and not (fused and whole_state):
        raise ValueError("k_steps > 1 requires the fused whole-state path")
    plan = _shim_plan(state, variant=_variant(fused, whole_state),
                      k_steps=k_steps, coeff=coeff, dt=dt,
                      interpret=interpret)
    return plan.run(state, steps)
