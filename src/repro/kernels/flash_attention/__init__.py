from repro.kernels.flash_attention.flash import flash_mha_pallas
from repro.kernels.flash_attention.ops import (auto_blocks, flash_mha,
                                               flash_traffic_bytes)
from repro.kernels.flash_attention import ref

__all__ = ["flash_mha_pallas", "flash_mha", "auto_blocks",
           "flash_traffic_bytes", "ref"]
