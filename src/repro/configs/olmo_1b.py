"""OLMo-1B — dense LM with non-parametric LayerNorm [arXiv:2402.00838; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=50304,
    pattern=("attn",), rope_theta=1e4,
    norm="ln_nonparam", gated_mlp=True, act="silu",
    tie_embeddings=True,
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
