"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of each family and run one forward/train step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api


def _batch(cfg, key, b=2, t=16):
    batch = {"tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size)}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encdec.encoder_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    model = api.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    if model.family == "lm":
        from repro.models import lm
        logits, _, _ = lm.apply(cfg, params, batch["tokens"], mode="train")
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_one_grad_step_decreases_loss(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    model = api.build(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(cfg, key)

    def loss_fn(p):
        return model.loss(p, batch, remat="none")

    l0, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    lr = 2e-2 / max(float(gnorm), 1.0)
    p2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    l1 = loss_fn(p2)
    assert float(l1) < float(l0), f"{arch}: {float(l0)} -> {float(l1)}"


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_param_count_matches_materialized(arch):
    """Analytic param_count (used for MODEL_FLOPS) vs the actual tree."""
    cfg = registry.reduced_config(registry.get_config(arch))
    model = api.build(cfg)
    shapes = model.param_shapes()
    n_real = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    n_est = cfg.param_count()
    # norms/gates/biases are excluded from the analytic count; tolerate 8%.
    assert abs(n_real - n_est) / n_real < 0.08, (arch, n_real, n_est)
