"""Paper Fig. 8 — energy efficiency (GFLOPS/Watt) vs PEs.

Model-derived (this container has no power sensors): per-level pJ/byte
coefficients (hierarchy.py) + static chip power, mirroring the paper's
observation that every extra HBM channel costs ~1 W and that peak energy
efficiency occurs below the peak-performance PE count.
Paper reference points: vadvc 1.61 GFLOPS/W, hdiff 21.01 GFLOPS/W.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import hierarchy as hw
from repro.core import perfmodel, tiling
from repro.core.autotune import tune

PAPER = {"vadvc": 1.61, "hdiff": 21.01}
GRID = (64, 256, 256)


def run():
    for op in (tiling.VADVC, tiling.HDIFF):
        best = None
        for chips in (1, 2, 4, 8, 16):
            tuned = tune(op, GRID, "float32", chips=chips)
            est = perfmodel.estimate(tuned.plan, chips=chips)
            gpw = est.plan.flops_total / est.time_s / 1e9 / (
                est.energy_j / est.time_s)
            best = max(best or 0.0, gpw)
            emit(f"fig8/{op.name}_chips{chips}", est.time_s * 1e6,
                 f"gflops_per_watt={gpw:.2f}")
        emit(f"fig8/{op.name}_summary", 0.0,
             f"model_best={best:.2f}GF/W paper_fpga={PAPER[op.name]}GF/W")


if __name__ == "__main__":
    run()
