"""Pure oracle for COSMO vertical advection (Thomas tridiagonal solver).

Faithful to the gridtools `vertical_advection_dycore` benchmark that NERO
implements on the FPGA: an implicit vertical discretization solved with the
Thomas algorithm — forward sweep building/eliminating (ccol, dcol), backward
substitution, and the final tendency update.

Layout: (z, y, x) = (k, j, i).  `wcon` is staggered in i: callers pass
wcon with shape (nz, ny, nx + 1) so both wcon[..., i] and wcon[..., i+1]
exist for every output column i.  In k, the sweep at level k uses wcon[k]
(gav) and wcon[k+1] (gcv), per the staggered vertical grid.

Two oracles are provided:
  * `vadvc_np`   — numpy, python loop over k (the clearest possible spec).
  * `vadvc`      — jnp, lax.scan over k (differentiable/jit path and the
                   reference for the Pallas kernel sweeps).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

DTR_STAGE = 3.0 / 20.0
BETA_V = 0.0
BET_M = 0.5 * (1.0 - BETA_V)
BET_P = 0.5 * (1.0 + BETA_V)


def vadvc_np(u_stage: np.ndarray, wcon: np.ndarray, u_pos: np.ndarray,
             utens: np.ndarray, utens_stage: np.ndarray) -> np.ndarray:
    """Reference in plain numpy.  All fields (nz, ny, nx); wcon (nz, ny, nx+1).
    Returns the updated utens_stage."""
    u_stage = np.asarray(u_stage, np.float64)
    wcon = np.asarray(wcon, np.float64)
    u_pos = np.asarray(u_pos, np.float64)
    utens = np.asarray(utens, np.float64)
    utens_stage_in = np.asarray(utens_stage, np.float64)
    nz, ny, nx = u_stage.shape

    ccol = np.empty_like(u_stage)
    dcol = np.empty_like(u_stage)
    wl = wcon[:, :, :nx]       # wcon(i)
    wr = wcon[:, :, 1:nx + 1]  # wcon(i+1)

    # ---- forward sweep ----------------------------------------------------
    # k = 0 (no sub-diagonal; gcv from level k+1)
    gcv = 0.25 * (wr[1] + wl[1])
    cs = gcv * BET_M
    ccol[0] = gcv * BET_P
    bcol = DTR_STAGE - ccol[0]
    correction = -cs * (u_stage[1] - u_stage[0])
    dcol[0] = (DTR_STAGE * u_pos[0] + utens[0] + utens_stage_in[0]
               + correction)
    divided = 1.0 / bcol
    ccol[0] *= divided
    dcol[0] *= divided

    # 0 < k < nz-1
    for k in range(1, nz - 1):
        gav = -0.25 * (wr[k] + wl[k])
        gcv = 0.25 * (wr[k + 1] + wl[k + 1])
        as_ = gav * BET_M
        cs = gcv * BET_M
        acol = gav * BET_P
        ccol[k] = gcv * BET_P
        bcol = DTR_STAGE - acol - ccol[k]
        correction = (-as_ * (u_stage[k - 1] - u_stage[k])
                      - cs * (u_stage[k + 1] - u_stage[k]))
        dcol[k] = (DTR_STAGE * u_pos[k] + utens[k] + utens_stage_in[k]
                   + correction)
        divided = 1.0 / (bcol - ccol[k - 1] * acol)
        ccol[k] *= divided
        dcol[k] = (dcol[k] - dcol[k - 1] * acol) * divided

    # k = nz-1 (no super-diagonal)
    k = nz - 1
    gav = -0.25 * (wr[k] + wl[k])
    as_ = gav * BET_M
    acol = gav * BET_P
    bcol = DTR_STAGE - acol
    correction = -as_ * (u_stage[k - 1] - u_stage[k])
    dcol[k] = (DTR_STAGE * u_pos[k] + utens[k] + utens_stage_in[k]
               + correction)
    divided = 1.0 / (bcol - ccol[k - 1] * acol)
    dcol[k] = (dcol[k] - dcol[k - 1] * acol) * divided

    # ---- backward sweep ----------------------------------------------------
    out = np.empty_like(u_stage)
    datac = dcol[nz - 1]
    out[nz - 1] = DTR_STAGE * (datac - u_pos[nz - 1])
    for k in range(nz - 2, -1, -1):
        datac = dcol[k] - ccol[k] * datac
        out[k] = DTR_STAGE * (datac - u_pos[k])
    return out


def _system(u_stage, wcon, u_pos, utens, utens_stage, xp):
    """Tridiagonal system (a, b, c, d) shared by the jnp oracle and the
    residual property check.  Row k: a[k] x[k-1] + b[k] x[k] + c[k] x[k+1]
    = d[k], with a[0] = c[-1] = 0."""
    nz, ny, nx = u_stage.shape
    wl = wcon[:, :, :nx]
    wr = wcon[:, :, 1:nx + 1]
    w = wl + wr
    gav = -0.25 * w                                     # level k
    if xp is np:
        gcv = 0.25 * np.concatenate([w[1:], np.zeros_like(w[-1:])], axis=0)
    else:
        gcv = 0.25 * jnp.concatenate([w[1:], jnp.zeros_like(w[-1:])], axis=0)
    a = gav * BET_P
    if xp is np:
        a[0] = 0.0
    else:
        a = a.at[0].set(0.0)
    c = gcv * BET_P                                     # c[-1] == 0 already
    b = DTR_STAGE - a - c

    du = xp.diff(u_stage, axis=0)                       # u[k+1]-u[k]
    d = DTR_STAGE * u_pos + utens + utens_stage
    if xp is np:
        d[1:] += (gav[1:] * BET_M) * du                 # -as*(u[k-1]-u[k])
        d[:-1] += -(gcv[:-1] * BET_M) * du              # -cs*(u[k+1]-u[k])
    else:
        d = d.at[1:].add((gav[1:] * BET_M) * du)
        d = d.at[:-1].add(-(gcv[:-1] * BET_M) * du)
    return a, b, c, d


def vadvc(u_stage: jnp.ndarray, wcon: jnp.ndarray, u_pos: jnp.ndarray,
          utens: jnp.ndarray, utens_stage: jnp.ndarray) -> jnp.ndarray:
    """jnp oracle via lax.scan (differentiable, jittable)."""
    in_dtype = u_stage.dtype
    f32 = jnp.float32
    u_stage, wcon, u_pos, utens, utens_stage = (
        jnp.asarray(x, f32) for x in (u_stage, wcon, u_pos, utens,
                                      utens_stage))
    a, b, c, d = _system(u_stage, wcon, u_pos, utens, utens_stage, jnp)

    # Thomas forward elimination.
    def fwd(carry, xs):
        cprev, dprev = carry
        a_k, b_k, c_k, d_k = xs
        denom = 1.0 / (b_k - cprev * a_k)
        c_new = c_k * denom
        d_new = (d_k - dprev * a_k) * denom
        return (c_new, d_new), (c_new, d_new)

    c0 = c[0] / b[0]
    d0 = d[0] / b[0]
    _, (cs_, ds_) = jax.lax.scan(fwd, (c0, d0), (a[1:], b[1:], c[1:], d[1:]))
    cp = jnp.concatenate([c0[None], cs_], axis=0)
    dp = jnp.concatenate([d0[None], ds_], axis=0)

    # Back substitution.
    def bwd(carry, xs):
        c_k, d_k = xs
        x = d_k - c_k * carry
        return x, x

    xlast = dp[-1]
    _, xs_rev = jax.lax.scan(bwd, xlast, (cp[:-1][::-1], dp[:-1][::-1]))
    x = jnp.concatenate([xs_rev[::-1], xlast[None]], axis=0)
    out = DTR_STAGE * (x - u_pos)
    return out.astype(in_dtype)


def tridiagonal_residual(u_stage, wcon, u_pos, utens, utens_stage, out):
    """Property check: reconstruct x from `out` and verify A x = d.

    Returns max |A x - d| (float64).  Thomas must actually solve the implicit
    system, independent of any oracle implementation."""
    u_stage, wcon, u_pos, utens, utens_stage, out = (
        np.asarray(v, np.float64)
        for v in (u_stage, wcon, u_pos, utens, utens_stage, out))
    a, b, c, d = _system(u_stage, wcon, u_pos, utens, utens_stage, np)
    x = out / DTR_STAGE + u_pos
    ax = b * x
    ax[1:] += a[1:] * x[:-1]
    ax[:-1] += c[:-1] * x[1:]
    return float(np.max(np.abs(ax - d)))
