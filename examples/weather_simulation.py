"""End-to-end weather driver: ensemble dycore simulation with the paper's
compound kernels, optionally domain-decomposed over a device mesh.

By default each field steps through the fused single-pass Pallas pipeline
(kernels/dycore_fused); `--no-fused` selects the unfused oracle composition.
Ensemble members (`--ensemble N`) are data-parallel: on a mesh with a "pod"
axis they shard across it with zero extra halo traffic — the worked example
in docs/architecture.md ("Scale-out: domain decomposition and ensemble
pods") shows the 3-axis ("pod", "data", "model") version of this driver.

Run:  PYTHONPATH=src python examples/weather_simulation.py --steps 10
      XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/weather_simulation.py --mesh 2,2
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.weather import domain, dycore, fields
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="16,64,64")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ensemble", type=int, default=2)
    ap.add_argument("--mesh", default="",
                    help="e.g. 2,2 -> ('data','model') decomposition")
    ap.add_argument("--no-fused", action="store_true",
                    help="unfused oracle composition instead of the fused "
                         "Pallas pipeline (docs/architecture.md)")
    args = ap.parse_args()
    fused = not args.no_fused

    grid = tuple(int(x) for x in args.grid.split(","))
    st = fields.initial_state(jax.random.PRNGKey(0), grid,
                              ensemble=args.ensemble)
    print(f"grid={grid} ensemble={args.ensemble} steps={args.steps}")

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model"))
        step, spec = domain.make_distributed_step(mesh, fused=fused)
        st = domain.shard_state(st, mesh, spec)
        print(f"domain-decomposed over mesh {dict(mesh.shape)} fused={fused}")
    else:
        step = lambda s: dycore.dycore_step(s, fused=fused)
        print(f"single-device fused={fused}")

    t0 = time.perf_counter()
    energy0 = float(sum(jnp.sum(jnp.square(f))
                        for f in st.fields.values()))
    for i in range(args.steps):
        st = step(st)
    jax.block_until_ready(st.fields["t"])
    dt = time.perf_counter() - t0
    energy1 = float(sum(jnp.sum(jnp.square(f)) for f in st.fields.values()))
    pts = args.ensemble * np.prod(grid) * args.steps
    print(f"{args.steps} steps in {dt:.2f}s "
          f"({pts / dt / 1e6:.1f}M point-updates/s)")
    print(f"field energy {energy0:.1f} -> {energy1:.1f} "
          f"(diffusion dissipates: {energy1 < energy0})")
    assert np.isfinite(energy1)
    print("weather simulation OK")


if __name__ == "__main__":
    main()
