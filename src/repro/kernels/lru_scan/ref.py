"""Oracle for the linear-recurrence sweep h_t = a_t h_{t-1} + b_t.

This is the temporal analogue of vadvc's Thomas forward sweep — the kernel
NERO's design maps onto RG-LRU (recurrentgemma) and SSM state updates.
Layout: (T, C) — time major, channels minor (lane dim)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a, b: (T, C) -> h: (T, C), h_0 = b_0 (zero initial state)."""

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=0)
    return h
