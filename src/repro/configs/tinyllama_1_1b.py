"""TinyLlama-1.1B — llama2-arch small dense LM [arXiv:2401.02385; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000,
    pattern=("attn",), rope_theta=1e4,
    norm="rms", gated_mlp=True, act="silu",
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
