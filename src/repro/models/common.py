"""Shared model primitives: norms, RoPE (incl. M-RoPE), init helpers."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int):
    if cfg.norm == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "ln_nonparam":      # OLMo: non-parametric LayerNorm
        return {}
    raise ValueError(cfg.norm)


def norm_apply(cfg: ModelConfig, params, x: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rms":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        x = x * params["scale"]
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
        if cfg.norm == "ln":
            x = x * params["scale"] + params["bias"]
    return x.astype(dt)


def qk_norm_apply(q: jnp.ndarray, scale: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """Per-head RMS norm on q/k (gemma3)."""
    dt = q.dtype
    q = q.astype(jnp.float32)
    q = q * jax.lax.rsqrt(jnp.mean(q * q, axis=-1, keepdims=True) + eps)
    return (q * scale).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None
               ) -> jnp.ndarray:
    """x: (B, T, H, hd); positions: (B, T) int or (B, T, 3) for M-RoPE.

    Half-split (llama-style) rotation.  With `mrope_sections` (a, b, c) —
    a + b + c == hd/2 — frequency i uses position component 0/1/2 by section
    (Qwen2-VL M-RoPE; for text inputs the three components coincide)."""
    b, t, h, hd = x.shape
    half = hd // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 2:
        pos = positions[..., None].astype(jnp.float32)     # (B,T,1)
        angles = pos * inv_freq                             # (B,T,half)
    else:
        assert mrope_sections is not None
        sel = jnp.concatenate([
            jnp.full((s,), i, jnp.int32)
            for i, s in enumerate(mrope_sections)])         # (half,)
        pos = positions.astype(jnp.float32)                 # (B,T,3)
        pos_per_freq = jnp.take(pos, sel, axis=-1)          # (B,T,half)
        angles = pos_per_freq * inv_freq
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
