"""Training launcher: --arch <id> [--smoke] with checkpoint/resume.

On this CPU container use --smoke (reduced config, tiny mesh).  On a real
pod the same entry point builds the production mesh and full config."""

from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.data import synthetic
from repro.models import api
from repro.train import loop, optim
from repro.launch.mesh import make_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the local device(s)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.reduced_config(cfg)
        n = len(jax.devices())
        mesh = make_mesh((1, n), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    model = api.build(cfg)
    opt_cfg = optim.OptConfig(lr=args.lr, warmup_steps=5,
                              total_steps=args.steps)
    data = synthetic.iterator(cfg, args.batch, args.seq)
    params, opt_state, hist = loop.fit(
        model, mesh, data, steps=args.steps, opt_cfg=opt_cfg,
        microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
