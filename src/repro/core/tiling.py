"""3-D window (tile) planner — NERO's "precision-optimized tiling".

The paper streams a 3-D window of the grid per PE through the on-chip
hierarchy.  Here a `TilePlan` describes exactly that: the window (block)
shape per field, its halo, the VMEM footprint including the double-buffered
pipeline stage, and which hierarchy level it lands in.  The autotuner
(`core/autotune.py`) searches over TilePlans; the Pallas kernels consume the
chosen plan as their BlockSpec shapes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Sequence, Tuple

import jax.numpy as jnp

from repro.core import hierarchy as hw


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Abstract description of a memory-bound operator for planning purposes.

    `fields_in` / `fields_out`: number of same-shaped 3-D input/output fields
    the op streams (vadvc: 7 in / 1 out; hdiff: 1 in / 1 out).  May be
    fractional when a stream is shared/amortized across an outer batch axis
    (dycore_whole_state: the `w` slab is read once per field group).
    `halo`: per-axis one-sided halo the stencil needs (hdiff: (0,2,2)).
    `halo_tiles`: additional per-axis one-sided halo measured in multiples
    of the tile extent itself (dycore_kstep: (0,1,0) — the working window
    is the tile plus a whole aliased window per side).
    `seq_axes`: axes that must stay whole inside a tile because the op is
    sequential along them (vadvc: z; lru_scan: t).
    `flops_per_point`: useful FLOPs per output grid point.
    `scratch_fields`: number of tile-shaped temporaries (vadvc: ccol,dcol);
    sized to the padded window when `scratch_padded` (dycore_kstep carries
    the whole working window per temporary).
    `extra_vmem_buffers`: padded-window-sized dtype-width buffers the kernel
    allocates beyond the streamed fields and fp32 scratch (dycore_kstep: 2,
    the double-buffered `w` prefetch slots).
    """

    name: str
    fields_in: float
    fields_out: int
    halo: Tuple[int, int, int]
    seq_axes: Tuple[int, ...]
    flops_per_point: float
    scratch_fields: int = 0
    parallel_axes: Tuple[int, ...] = ()
    halo_tiles: Tuple[int, int, int] = (0, 0, 0)
    scratch_padded: bool = False
    extra_vmem_buffers: float = 0.0

    @property
    def bytes_moved_per_point(self) -> float:
        """Ideal HBM traffic per point per dtype-byte (reads + writes)."""
        return float(self.fields_in + self.fields_out)

    def arithmetic_intensity(self, dtype) -> float:
        return self.flops_per_point / (
            self.bytes_moved_per_point * hw.dtype_bytes(dtype))


# Canonical op specs for the paper's kernels -------------------------------

# hdiff: per output point the compound stencil does ~21 flops (4 laplacians
# reused across neighbors amortize; we count the gridtools fused-op count).
HDIFF = OpSpec(
    name="hdiff", fields_in=1, fields_out=1, halo=(0, 2, 2),
    seq_axes=(), parallel_axes=(0, 1, 2), flops_per_point=21.0)

# vadvc: 7 input fields (ccol,dcol,wcon,ustage,upos,utens,utensstage),
# 1 output; forward+backward sweep ~ 38 flops/point; sequential in z (axis 0
# in our (z, y, x) layout); scratch ccol/dcol tiles.
VADVC = OpSpec(
    name="vadvc", fields_in=7, fields_out=1, halo=(0, 0, 1),
    seq_axes=(0,), parallel_axes=(1, 2), flops_per_point=38.0,
    scratch_fields=3)

COPY = OpSpec(
    name="copy", fields_in=1, fields_out=1, halo=(0, 0, 0),
    seq_axes=(), parallel_axes=(0, 1, 2), flops_per_point=0.0)

# lru_scan (RG-LRU / SSM sweep): layout (channels, time) folded to 3-D as
# (time, batch*channels, 1); sequential in time; 9 flops/point (gates+fma).
LRU_SCAN = OpSpec(
    name="lru_scan", fields_in=3, fields_out=1, halo=(0, 0, 0),
    seq_axes=(0,), parallel_axes=(1,), flops_per_point=9.0,
    scratch_fields=1)

# dycore_fused: the whole-field dycore step fused into one dataflow pipeline
# (kernels/dycore_fused) — vadvc Thomas solve + point-wise update + compound
# hdiff.  4 streamed inputs (f, w, utens, utens_stage), 2 outputs (f_new,
# stage); z stays whole (the solve is sequential) and so does x (the kernel
# realizes the periodic x-halo as a VMEM lane roll, so only y is tiled and
# only the 2-deep y-halo is re-read from HBM); 6 tile-shaped fp32 VMEM
# temporaries (fwork/wwork/rhs/ccol/dcol/stage).
# flops/point = vadvc(38) + update(2) + hdiff(21).
DYCORE_FUSED = OpSpec(
    name="dycore_fused", fields_in=4, fields_out=2, halo=(0, 2, 0),
    seq_axes=(0, 2), parallel_axes=(1,), flops_per_point=61.0,
    scratch_fields=6)


# hadv_upwind: first-order donor-cell horizontal advection.  The stencil
# reaches ONE point backward in y and x only (the rides in the registry are
# asymmetric); the tile model keeps the symmetric one-sided halo convention.
HADV_UPWIND = OpSpec(
    name="hadv_upwind", fields_in=1, fields_out=1, halo=(0, 1, 1),
    seq_axes=(), parallel_axes=(0, 1, 2), flops_per_point=5.0)

# vadvc_update: the paper's ablation composition — the vadvc Thomas solve
# fused with the point-wise leapfrog update (no hdiff).  Same 7 input
# streams and z-sequential geometry as vadvc, but two outputs (new field +
# stage tendency) and the +2 update flops.
VADVC_UPDATE = OpSpec(
    name="vadvc_update", fields_in=7, fields_out=2, halo=(0, 0, 1),
    seq_axes=(0,), parallel_axes=(1, 2), flops_per_point=40.0,
    scratch_fields=3)

# asselin: point-wise leapfrog time filter from stored tendencies —
# f' = f + coeff * (tens - stage_tens).  Three input streams, one output,
# zero halo (the registry's zero-exchange op).
ASSELIN = OpSpec(
    name="asselin", fields_in=3, fields_out=1, halo=(0, 0, 0),
    seq_axes=(), parallel_axes=(0, 1, 2), flops_per_point=3.0)


def pipeline_spec(name: str, stage_specs: Sequence[OpSpec], *,
                  fields_in: float, fields_out: int,
                  halo: Tuple[int, int, int]) -> OpSpec:
    """Synthesize the tile space of a fused stage chain (`weather/
    pipeline.py`): ONE pass streams the union of the stages' operands
    (`fields_in`/`fields_out`, computed by the pipeline planner from its
    operand bindings) while intermediates stay resident, so flops are the
    SUM over stages but the byte streams are not.  Sequential axes union
    (one z-sequential stage pins the whole chain's z), scratch takes the
    max simultaneous working set, and `halo` is the chain's accumulated
    one-sided reach."""
    if not stage_specs:
        raise ValueError("pipeline needs at least one stage spec")
    seq = tuple(sorted({a for s in stage_specs for a in s.seq_axes}))
    par = tuple(sorted(set(range(3)) - set(seq)))
    return OpSpec(
        name=name, fields_in=float(fields_in), fields_out=int(fields_out),
        halo=tuple(int(h) for h in halo), seq_axes=seq, parallel_axes=par,
        flops_per_point=float(sum(s.flops_per_point for s in stage_specs)),
        scratch_fields=max(s.scratch_fields for s in stage_specs))


def snap_to_divisor(t: int, n: int, lo: int = 2) -> int:
    """Largest divisor of `n` that is `<= t` and `>= lo`; falls back to `n`
    itself when no divisor lands in `[lo, t]`.

    The ONE snapping rule every kernel package uses to turn an auto-tuned
    tile extent into a legal one (`kernels/*/ops.py` used to each carry a
    private halving/decrement loop — they drifted; this is the unified
    largest-divisor-below semantics of the fused dycore's `snap_ty`)."""
    t = max(lo, min(int(t), n))
    while n % t and t > lo:
        t -= 1
    return t if n % t == 0 else n


def dycore_whole_state_spec(n_fields: int = 4) -> OpSpec:
    """Tile space of the whole-state fused dycore step (one `pallas_call`
    for all `n_fields` prognostic fields, shared staggered velocity `w`).

    Per-field HBM traffic: 3 private input streams (f, utens, utens_stage)
    plus the shared `w` slab amortized over the field axis — `fields_in =
    3 + 1/n_fields` (the planner's byte accounting tolerates a fractional
    stream).  VMEM is a different story: `w` amortizes in *traffic* but
    stays fully resident next to the per-field windows while the innermost
    field iterations reuse it, so it is counted as a 7th tile-shaped
    scratch buffer (6 pipeline temporaries + the resident shared-`w`
    window).  That is why the whole-state space is registered separately —
    its VMEM pressure, and hence the legal-tile set, depends on the field
    count.
    """
    if n_fields < 1:
        raise ValueError(f"n_fields={n_fields} must be >= 1")
    return OpSpec(
        name="dycore_whole_state", fields_in=3 + 1.0 / n_fields,
        fields_out=2, halo=(0, 2, 0), seq_axes=(0, 2), parallel_axes=(1,),
        flops_per_point=61.0, scratch_fields=7)


DYCORE_WHOLE_STATE = dycore_whole_state_spec()


def dycore_kstep_spec(n_fields: int = 4, k_steps: int = 2) -> OpSpec:
    """Tile space of the k-step fused dycore round (one `pallas_call` runs
    the whole communication-avoiding round: `k_steps` local steps per grid
    cell with the prognostic state held in VMEM between steps).

    Geometry: each grid cell stages a THREE-window working slab (the k-step
    halo is up to a whole `ty` per side — `halo_tiles=(0,1,0)`), and every
    one of the 8 pipeline temporaries (fwork/wwork/twork/swork/rhs/ccol/
    dcol/stage) spans that padded window (`scratch_padded`).  The explicit
    double-buffered `w` prefetch adds 2 padded dtype-width buffers on top
    (`extra_vmem_buffers=2`) — the VMEM budget must clear ALL of that, which
    is why the k-step space is registered separately: its legal-tile set is
    much tighter than the whole-state one.

    HBM traffic per ROUND: the same `3 + 1/n_fields` input streams as the
    whole-state step (state+tendencies once, shared `w` amortized over the
    field axis) and 2 output streams — but the round advances `k_steps`
    timesteps, so `flops_per_point` scales with k while the byte terms do
    not: arithmetic intensity grows ~k-fold (NERO's keep-it-on-fabric
    argument applied across time).
    """
    if n_fields < 1:
        raise ValueError(f"n_fields={n_fields} must be >= 1")
    if k_steps < 1:
        raise ValueError(f"k_steps={k_steps} must be >= 1")
    return OpSpec(
        name="dycore_kstep", fields_in=3 + 1.0 / n_fields, fields_out=2,
        halo=(0, 0, 0), halo_tiles=(0, 1, 0), seq_axes=(0, 2),
        parallel_axes=(1,), flops_per_point=61.0 * k_steps,
        scratch_fields=8, scratch_padded=True, extra_vmem_buffers=2.0)


DYCORE_KSTEP = dycore_kstep_spec()


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A concrete 3-D window choice for an OpSpec on a grid."""

    op: OpSpec
    grid_shape: Tuple[int, int, int]     # full (z, y, x) domain
    tile: Tuple[int, int, int]           # window shape (z, y, x)
    dtype: str
    pipeline_depth: int = 2              # double buffering (dataflow overlap)

    # -- geometry ----------------------------------------------------------
    @property
    def tile_points(self) -> int:
        return int(self.tile[0] * self.tile[1] * self.tile[2])

    @property
    def padded_tile(self) -> Tuple[int, int, int]:
        """Window + halos actually staged into VMEM (tile-multiple halos,
        e.g. the k-step kernel's whole aliased window per side, included)."""
        return tuple(t + 2 * h + 2 * ht * t for t, h, ht in
                     zip(self.tile, self.op.halo, self.op.halo_tiles))

    @property
    def num_tiles(self) -> int:
        return int(math.prod(
            math.ceil(g / t) for g, t in zip(self.grid_shape, self.tile)))

    # -- resources ----------------------------------------------------------
    @property
    def vmem_bytes(self) -> int:
        """NERO's "resource utilization" axis: bytes of near-memory the plan
        claims, with pipeline double-buffering on the streamed fields, the
        op's explicit extra buffers (e.g. the k-step kernel's double-buffered
        `w` prefetch slots), and padded-window scratch where the op keeps
        whole working windows as temporaries."""
        b = hw.dtype_bytes(self.dtype)
        pt = math.prod(self.padded_tile)
        streamed = (self.op.fields_in + self.op.fields_out) * pt * b
        scratch_pts = pt if self.op.scratch_padded else self.tile_points
        scratch = self.op.scratch_fields * scratch_pts * max(b, 4)
        extra = self.op.extra_vmem_buffers * pt * b
        return int(streamed * self.pipeline_depth + scratch + extra)

    def fits(self, hier: hw.Hierarchy) -> bool:
        return self.vmem_bytes <= hier.vmem.capacity_bytes

    # -- alignment ----------------------------------------------------------
    @property
    def lane_aligned(self) -> bool:
        """Minor-most dim multiple of 128 lanes, next of 8 sublanes — the MXU
        /VPU alignment the paper's BRAM-width matching corresponds to."""
        z, y, x = self.padded_tile
        return (x % hw.VPU_LANES[1] == 0) and (y % hw.VPU_LANES[0] == 0)

    # -- traffic ------------------------------------------------------------
    @property
    def hbm_bytes_per_tile(self) -> int:
        b = hw.dtype_bytes(self.dtype)
        pt = math.prod(self.padded_tile)
        return int((self.op.fields_in * pt + self.op.fields_out *
                    self.tile_points) * b)

    @property
    def hbm_bytes_total(self) -> int:
        return self.hbm_bytes_per_tile * self.num_tiles

    @property
    def halo_overhead(self) -> float:
        """Fraction of HBM traffic that is redundant halo re-reads."""
        ideal = (self.op.bytes_moved_per_point *
                 hw.dtype_bytes(self.dtype) * math.prod(self.grid_shape))
        return self.hbm_bytes_total / max(ideal, 1.0) - 1.0

    @property
    def flops_total(self) -> float:
        return self.op.flops_per_point * math.prod(self.grid_shape)

    def describe(self) -> dict:
        """JSON-serializable summary — embedded by
        `weather/program.py::ExecutionPlan.report()` and hence by the
        `BENCH_dycore.json` plan block."""
        return {"op": self.op.name,
                "grid": list(self.grid_shape),
                "tile": list(self.tile),
                "padded_tile": list(self.padded_tile),
                "dtype": self.dtype,
                "vmem_bytes": int(self.vmem_bytes),
                "lane_aligned": bool(self.lane_aligned),
                "hbm_bytes_total": int(self.hbm_bytes_total),
                "halo_overhead": float(self.halo_overhead)}


def candidate_tiles(op: OpSpec,
                    grid_shape: Sequence[int],
                    dtype,
                    hier: hw.Hierarchy | None = None,
                    max_candidates: int = 512) -> List[TilePlan]:
    """Enumerate the legal tile space (the autotuner's search domain).

    Sequential axes are never split (vadvc needs the whole z column in VMEM —
    exactly the paper's design, which tiles x/y only for vadvc).  Other axes
    take power-of-two sizes, lane-aligned on the minor axis where possible.
    """
    hier = hier or hw.tpu_v5e()
    grid_shape = tuple(int(g) for g in grid_shape)

    def axis_options(ax: int) -> List[int]:
        g = grid_shape[ax]
        if ax in op.seq_axes:
            return [g]
        opts = []
        s = 1
        while s <= g:
            opts.append(s)
            s *= 2
        if g not in opts:
            opts.append(g)
        return opts

    plans: List[TilePlan] = []
    for tz in axis_options(0):
        for ty in axis_options(1):
            for tx in axis_options(2):
                plan = TilePlan(op=op, grid_shape=grid_shape,
                                tile=(tz, ty, tx), dtype=str(jnp.dtype(dtype)))
                if plan.fits(hier):
                    plans.append(plan)
    # Prefer bigger, aligned tiles first so truncation keeps the useful region.
    plans.sort(key=lambda p: (-int(p.lane_aligned), -p.tile_points))
    return plans[:max_candidates]
