"""Multi-objective tile auto-tuner — the paper's OpenTuner stage.

NERO formulates window-size selection as multi-objective optimization
(performance vs. FPGA resource use) and shows the Pareto optimum shifts with
datatype precision (paper Fig. 6).  We reproduce that: objectives are
(predicted time, VMEM bytes); the search is exhaustive over the legal tile
space (it is small once VMEM capacity prunes it) with an optional
hill-climbing mode for huge grids.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import hierarchy as hw
from repro.core import hwspec
from repro.core import perfmodel
from repro.core import tiling as _tiling
from repro.core.tiling import OpSpec, TilePlan, candidate_tiles


@dataclasses.dataclass(frozen=True)
class TunedResult:
    plan: TilePlan
    est: perfmodel.PerfEstimate
    pareto: Tuple[Tuple[float, int], ...]   # (time_s, vmem_bytes) frontier


# Registry of tunable op tile spaces, name -> OpSpec.  Kernel packages look
# their search space up here (and benchmarks sweep it) instead of hard-coding
# an OpSpec import per call site.
OP_SPECS = {
    spec.name: spec
    for spec in (_tiling.HDIFF, _tiling.VADVC, _tiling.COPY,
                 _tiling.LRU_SCAN, _tiling.DYCORE_FUSED,
                 _tiling.DYCORE_WHOLE_STATE, _tiling.DYCORE_KSTEP,
                 _tiling.HADV_UPWIND, _tiling.VADVC_UPDATE,
                 _tiling.ASSELIN)
}


def register_op(spec: OpSpec) -> OpSpec:
    """Add (or replace) an op's tile space in the registry."""
    OP_SPECS[spec.name] = spec
    return spec


def get_op(name: str) -> OpSpec:
    try:
        return OP_SPECS[name]
    except KeyError:
        raise KeyError(f"unknown op {name!r}; registered: "
                       f"{sorted(OP_SPECS)}") from None


def tune_named(name: str, grid_shape: Sequence[int], dtype,
               **kwargs) -> "TunedResult":
    """`tune` with the OpSpec looked up by registered name."""
    return tune(get_op(name), grid_shape, dtype, **kwargs)


def pareto_front(points: Sequence[Tuple[float, int, int]]) -> List[int]:
    """Indices of the Pareto-optimal (time, vmem) points (minimize both)."""
    idx = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front, best_mem = [], None
    for i in idx:
        mem = points[i][1]
        if best_mem is None or mem < best_mem:
            front.append(i)
            best_mem = mem
    return front


def tune(op: OpSpec,
         grid_shape: Sequence[int],
         dtype,
         hier: Optional[hw.Hierarchy] = None,
         chips: int = 1,
         measure: Optional[Callable[[TilePlan], float]] = None,
         vmem_weight: float = 0.0,
         spec: Optional[hwspec.HardwareSpec] = None) -> TunedResult:
    """Pick the tile plan.

    `measure`, when provided, is a wall-clock callable (seconds; return
    `math.inf` for candidates the kernel cannot execute) used instead of the
    analytic model — this is the "auto-tuned" mode of paper Fig. 6; the
    analytic default is the "model-guided" mode.  `spec` selects the machine
    being modeled (candidate pruning uses its hierarchy; scoring its
    sustained-utilization classes).  `vmem_weight` lets the caller trade
    resources for speed (0 => pure performance, like the paper's red-circled
    Pareto picks).
    """
    if hier is None:
        hier = spec.hierarchy() if spec is not None else hw.tpu_v5e()
    cands = candidate_tiles(op, grid_shape, dtype, hier)
    if not cands:
        raise ValueError(
            f"no legal tile for op={op.name} grid={grid_shape} dtype={dtype}")

    scored: List[Tuple[float, int, int]] = []
    ests: List[perfmodel.PerfEstimate] = []
    for i, plan in enumerate(cands):
        est = perfmodel.estimate(plan, hier, chips=chips, spec=spec)
        t = measure(plan) if measure is not None else est.time_s
        scored.append((t, plan.vmem_bytes, i))
        ests.append(est)

    front = pareto_front(scored)
    # Weighted pick along the frontier.
    def cost(i: int) -> float:
        t, mem, _ = scored[i]
        return t * (1.0 + vmem_weight * mem / hier.vmem.capacity_bytes)
    best = min(front, key=cost)
    frontier = tuple((scored[i][0], scored[i][1]) for i in front)
    return TunedResult(plan=cands[best], est=ests[best], pareto=frontier)


# ---------------------------------------------------------------------------
# k_steps autotuning — the communication-avoiding knob, picked the same way
# plan_tile picks the y-window (ROADMAP "Autotune k_steps").
# ---------------------------------------------------------------------------

# Fixed per-collective-round cost: dispatch + link latency of a ppermute
# round on the 2-D torus (model constant, same register as hierarchy.py's
# bandwidth/energy numbers).
COLLECTIVE_LATENCY_S = 5e-6

# Fused dycore flops per grid point per field per step (tiling.DYCORE_FUSED).
_DYCORE_FLOPS_PER_POINT = _tiling.DYCORE_FUSED.flops_per_point


def plan_k_steps(grid_shape: Sequence[int], dtype, mesh_shape,
                 *, n_fields: int = 4, halo: int = 2, max_k: int = 8,
                 hier: Optional[hw.Hierarchy] = None,
                 latency_s: Optional[float] = None,
                 utilization: float = 0.85,
                 flops_per_point: Optional[float] = None,
                 exchange_model: Optional[Callable] = None,
                 spec: Optional[hwspec.HardwareSpec] = None) -> int:
    """Pick the communication-avoiding depth k for a distributed stencil op.

    Modeled per-TIMESTEP cost of running the k-step round:

        (rounds(k) * latency + wire_bytes(k) / ici_bw) / k      collectives
      + compute * (1 + redundant_flops_frac(k))                 halo-ring tax

    The wire/redundancy terms come from `exchange_model(k)` — any callable
    returning `memmodel.packed_exchange_model`-shaped numbers for depth k
    (default: the fused dycore's `memmodel.kstep_exchange_model` footprint)
    — and the compute term from the op's declared `flops_per_point` (and
    `halo` reach) at the local slab, which is how the planner
    (`weather/program.py::compile`) threads each registered StencilOp's
    flop count and footprint through the k resolution instead of baking in
    dycore constants.  Large k amortizes collective latency but pays a
    growing redundant-flops tax on the deepened halo ring; the argmin is
    the paper's sweet spot.  Candidates stop where the deep halo outgrows
    the local slab.

    `mesh_shape` is `(py, px)` — spatial shards along y and x.
    """
    from repro.core import memmodel   # local import: memmodel is heavy

    if hier is None:
        hier = spec.hierarchy() if spec is not None else hw.tpu_v5e()
    if latency_s is None:
        latency_s = (spec.collective.latency_s if spec is not None
                     else COLLECTIVE_LATENCY_S)
    nz, ny, nx = (int(g) for g in grid_shape)
    py, px = (int(s) for s in mesh_shape)
    ly, lx = ny // py, nx // px
    b = hw.dtype_bytes(dtype)
    peak = (hier.peak_flops_bf16 if b <= 2 else hier.peak_flops_fp32)
    if flops_per_point is None:
        flops_per_point = _DYCORE_FLOPS_PER_POINT
    if exchange_model is None:
        def exchange_model(k):
            return memmodel.kstep_exchange_model(
                grid_shape, dtype, n_fields=n_fields, k=k,
                shards=(py, px), halo=halo)
    compute_s = (flops_per_point * n_fields * nz * ly * lx
                 / (peak * utilization))

    best_k, best_cost = 1, None
    for k in range(1, max_k + 1):
        try:
            m = exchange_model(k)
        except ValueError:
            break   # deep halo outgrew the local slab
        coll_s = (m["rounds_kstep"] * latency_s
                  + m["bytes_kstep"] / hier.ici_bw) / k
        cost = coll_s + compute_s * (1.0 + m["redundant_flops_frac"])
        if best_cost is None or cost < best_cost:
            best_k, best_cost = k, cost
    return best_k


def resolve_k_steps(grid_shape: Sequence[int], dtype, mesh_shape,
                    *, n_fields: int = 4, halo: int = 2, max_k: int = 8,
                    hier: Optional[hw.Hierarchy] = None,
                    latency_s: Optional[float] = None,
                    utilization: float = 0.85,
                    flops_per_point: Optional[float] = None,
                    exchange_model: Optional[Callable] = None,
                    vmem_check: Optional[Callable] = None,
                    spec: Optional[hwspec.HardwareSpec] = None) -> int:
    """`plan_k_steps` clamped to what the VMEM budget actually fits.

    The exchange model's argmin can ask for a k whose working slab
    overflows VMEM on the padded local grid; this resolver (the planner's
    steps-per-round entry, `weather/program.py::compile(k_steps="auto")`)
    walks k down until `vmem_check(k)` accepts the plan.  The default
    check is the fused dycore's: `plan_tile_kstep` on the padded local
    slab (3-window scratch + double-buffered `w` prefetch); ops whose
    k-step round is a sequence of separate launches (no in-kernel state
    carry, e.g. hdiff) pass `vmem_check=lambda k: None` — each launch
    plans its own window."""
    k = plan_k_steps(grid_shape, dtype, mesh_shape, n_fields=n_fields,
                     halo=halo, max_k=max_k, hier=hier, latency_s=latency_s,
                     utilization=utilization, flops_per_point=flops_per_point,
                     exchange_model=exchange_model, spec=spec)
    if vmem_check is None:
        # Local import: the kernel package imports this module at load time.
        from repro.kernels.dycore_fused import ops as fused_ops

        nz, ny, nx = (int(g) for g in grid_shape)
        py, px = (int(s) for s in mesh_shape)

        def vmem_check(kk):
            fused_ops.plan_tile_kstep(
                (nz, ny // py + 2 * kk * halo, nx // px + 2 * kk * halo),
                dtype, n_fields, kk)
    while k > 1:
        try:
            vmem_check(k)
            break
        except ValueError:
            k -= 1
    return k


# ---------------------------------------------------------------------------
# Measured (wall-clock) tuning support — the paper's "auto-tuned" mode.
#
# The analytic model above is the "model-guided" mode; `tune(measure=...)`
# is the empirical one.  Because a wall-clock measurement is only meaningful
# on the machine it ran on, measured picks are persisted to an on-disk cache
# keyed on (plan cache key, hardware-spec fingerprint, jax backend): a plan
# tuned once is reused by every later process on the same machine, and a
# cache entry can never be replayed against a different spec or backend.
# `weather/program.py::compile(tune="measure")` is the consumer.
# ---------------------------------------------------------------------------

# Process-wide counters, reset-able by tests and reported by bench-smoke to
# prove the persistent cache round-trips across processes.
TUNE_CACHE_STATS: Dict[str, int] = {"hits": 0, "misses": 0, "stores": 0}

_TUNE_CACHE_ENV = "REPRO_TUNE_CACHE"


def measure_walltime(fn: Callable[[], Any], repeats: int = 3) -> float:
    """Median wall-clock seconds of `fn()` after one untimed warm-up call
    (the warm-up absorbs jit compilation).  `fn` must block until the work
    is done (e.g. call `block_until_ready`).  The planner looks this up as
    `autotune.measure_walltime` at call time, so tests can monkeypatch it
    to spy on (or fake) the measurement."""
    fn()   # warm-up / compile
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def tune_cache_dir() -> str:
    """Cache directory: `$REPRO_TUNE_CACHE` or `~/.cache/repro/tune`."""
    env = os.environ.get(_TUNE_CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "tune")


def tune_cache_key(program_key: Any, spec: hwspec.HardwareSpec,
                   backend: str) -> str:
    """Content key for one (program, machine, backend) tuning decision.
    `program_key` is the planner's `plan_cache_key` (a frozen dataclass with
    a deterministic repr); the spec contributes its content fingerprint so
    editing a spec JSON invalidates every measurement made under it."""
    payload = f"{program_key!r}|spec={spec.fingerprint}|backend={backend}"
    return hashlib.sha256(payload.encode()).hexdigest()[:24]


def tune_cache_load(key: str) -> Optional[Dict[str, Any]]:
    """Load a persisted tuning decision; counts a hit or a miss."""
    path = os.path.join(tune_cache_dir(), f"{key}.json")
    try:
        with open(path) as fh:
            entry = json.load(fh)
    except (OSError, json.JSONDecodeError):
        TUNE_CACHE_STATS["misses"] += 1
        return None
    TUNE_CACHE_STATS["hits"] += 1
    return entry


def tune_cache_store(key: str, entry: Dict[str, Any]) -> None:
    """Persist a tuning decision atomically (tmp + rename), so concurrent
    processes racing on the same key both leave a valid file."""
    cache_dir = tune_cache_dir()
    os.makedirs(cache_dir, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(entry, fh, sort_keys=True)
        os.replace(tmp, os.path.join(cache_dir, f"{key}.json"))
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    TUNE_CACHE_STATS["stores"] += 1
