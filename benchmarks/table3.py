"""Paper Table 3 — cross-work hdiff throughput comparison.

Paper entries are hard-coded from Table 3; our row is the model-projected
TPU v5e hdiff throughput (single chip, auto-tuned tiles) plus the measured
CPU reference for scale."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hwspec, perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href

# Other-work rows stay literal (they are other papers' machines); the
# NERO row comes from the nero_ad9h7 spec's recorded reference points.
TABLE3 = [
    ("NARMADA[129]/XCVU3P", 129.9),
    ("StencilFlow[43]/Stratix10", 145.0),
]


def run():
    grid = (64, 256, 256)
    for name in ("tpu_v5e", "nero_ad9h7"):
        spec = hwspec.load_spec(name)
        tuned = tune(tiling.HDIFF, grid, "float32", spec=spec)
        est = perfmodel.estimate(tuned.plan, spec=spec)
        emit(f"table3/model_{name}", est.time_s * 1e6,
             f"gflops={est.gflops:.0f}")
    nero_ref = hwspec.load_spec("nero_ad9h7").reference_points["hdiff"]
    emit("table3/NERO[ours-paper]/XCVU37P", 0.0,
         f"gflops={nero_ref['gflops']}")
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.normal(size=grid).astype(np.float32))
    t = time_fn(jax.jit(href.hdiff), src)
    gf = tiling.HDIFF.flops_per_point * src.size / (t * 1e-6) / 1e9
    emit("table3/this_cpu_jnp", t, f"gflops={gf:.1f}")
    for name, gflops in TABLE3:
        emit(f"table3/{name}", 0.0, f"gflops={gflops}")


if __name__ == "__main__":
    run()
