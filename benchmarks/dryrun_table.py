"""Aggregate the dry-run JSON cache into the roofline table (EXPERIMENTS.md
§Roofline source of truth)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def rows(variant="baseline", mesh="single"):
    out = []
    for path in sorted(glob.glob(os.path.join(
            DIR, f"*__{mesh}__{variant}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run():
    n_ok = n_skip = n_err = 0
    for r in rows():
        tag = f"dryrun/{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "ok":
            n_ok += 1
            rf = r["roofline"]
            emit(tag, rf["step_time_bound_s"] * 1e6,
                 f"dom={rf['dominant']} "
                 f"frac={rf['roofline_fraction']:.3f} "
                 f"useful={rf['useful_flops_ratio']:.2f} "
                 f"fit={r['memory'].get('fits_16g')}")
        elif r["status"] == "skipped":
            n_skip += 1
            emit(tag, 0.0, f"SKIP: {r['reason']}")
        else:
            n_err += 1
            emit(tag, 0.0, f"ERROR: {r.get('error', '')[:80]}")
    for r in rows(mesh="multi"):
        if r["status"] == "ok":
            n_ok += 1
    emit("dryrun/summary", 0.0, f"ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    run()
