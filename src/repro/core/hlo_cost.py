"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body exactly
ONCE, so any cost derived from a scanned model (layer scan, flash-attention
q/kv chunk scans, SSD chunk scan, chunked cross-entropy) under-counts FLOPs,
bytes, and in-loop collectives by the trip count.  Fully unrolling every
loop fixes that but makes 60-80-layer cells uncompilable in reasonable time
on one host.

This module recovers exact loop-aware totals from the *compiled* artifact:
it parses ``compiled.as_text()``, builds the computation call graph, and
multiplies each while body/condition by the trip count XLA records in the
instruction's ``backend_config={"known_trip_count":{"n":...}}`` (with a
compare-against-constant fallback).  Per-op FLOP/byte counting mirrors
xla::HloCostAnalysis:

  * dot: 2 x prod(output dims) x prod(contracting dims)
  * elementwise / select / compare / iota-like: prod(output)
  * transcendentals (exp, tanh, log, ...): counted separately
  * reduce: prod(input)
  * fusion: FLOPs of the fused computation; bytes = operands + outputs of
    the fusion instruction only (internal ops never touch HBM)
  * collectives: result bytes per op type (ring wire factors are applied by
    core/roofline.py), times the loop multiplier

Validated two ways in tests/test_hlo_cost.py:
  1. multipliers forced to 1  -> matches compiled.cost_analysis(),
  2. scanned model, real multipliers -> matches the fully-unrolled compile.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# opcode classes (mirrors xla::HloCostAnalysis op buckets)
_ELEMENTWISE = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "xor", "not", "negate", "abs",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "atan2", "is-finite", "popcnt", "clz",
    "stochastic-convert",
))
_TRANSCENDENTAL = frozenset((
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "power", "sine", "cosine", "tan",
    "erf", "expm1", "log1p",
))
_COLLECTIVES = frozenset((
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
))
_DATA_MOVEMENT = frozenset((
    "copy", "transpose", "reshape", "broadcast", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "gather",
    "scatter", "iota", "convert", "reduce", "reduce-window", "sort", "rng",
    "rng-bit-generator", "cholesky", "triangular-solve", "dot", "fusion",
    "convolution", "bitcast-convert",
)) | _ELEMENTWISE | _TRANSCENDENTAL | _COLLECTIVES

_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[\d,]*)\]")
_NAME_RE = re.compile(r"%?([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->.*{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)="
    r"(?P<refs>\{[^}]*\}|%?[\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_info(type_str: str) -> Tuple[int, int]:
    """(total elements, total bytes) over possibly-tuple HLO type text."""
    elems = byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _balanced(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Optional["Instr"]:
    """One HLO instruction.  Robust to tuple types with /*index=N*/ comments
    (giant while/scan carries), which defeat single-regex parses."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    m = _NAME_RE.match(s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    if rest.startswith("("):               # tuple type
        end = _balanced(rest, 0)
        type_str, rest = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp + 1:].lstrip()
    m = _OP_RE.match(rest)
    if not m:
        return None
    op = m.group(1)
    open_i = m.end() - 1
    end = _balanced(rest, open_i)
    args = rest[open_i + 1:end - 1]
    attrs = rest[end:]
    return Instr(name, op, type_str, args, attrs)


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_str: str
    args: str
    attrs: str

    @property
    def out_elems(self) -> int:
        return _shape_info(self.type_str)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_info(self.type_str)[1]

    def in_scope(self, scopes: Tuple[str, ...]) -> bool:
        """True if the op_name metadata mentions any named scope — the hook
        for crediting Pallas-kernelized regions (their intermediates live in
        VMEM, so the kernelized variant charges them zero HBM bytes)."""
        return any(s in self.attrs for s in scopes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    bytes_fused: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.bytes_accessed += o.bytes_accessed
        self.bytes_fused += o.bytes_fused
        for k, v in o.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        self.while_trip_counts += o.while_trip_counts
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.transcendentals * k,
                    self.bytes_accessed * k, self.bytes_fused * k,
                    {op: v * k for op, v in self.collective_bytes.items()},
                    list(self.while_trip_counts))


class HloModule:
    """Parsed computations of one HLO module (text form)."""

    def __init__(self, text: str, zero_byte_scopes: Tuple[str, ...] = ()):
        self.zero_scopes = tuple(zero_byte_scopes)
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_RE.match(line)
                if m:
                    cur = m.group("name")
                    self.computations[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            instr = _parse_instr(line)
            if instr is not None:
                self.computations[cur].append(instr)
        if self.entry is None and self.computations:   # defensive
            self.entry = next(iter(self.computations))

    # -- helpers ------------------------------------------------------------

    def _called(self, instr: Instr) -> List[str]:
        out = []
        for m in _CALLED_RE.finditer(instr.attrs):
            refs = m.group("refs")
            if refs.startswith("{"):
                out += [r.strip().lstrip("%") for r in
                        refs[1:-1].split(",") if r.strip()]
            else:
                out.append(refs.lstrip("%"))
        return [c for c in out if c in self.computations]

    def _operand_bytes(self, instr: Instr, comp: str) -> int:
        table = {i.name: i for i in self.computations[comp]}
        total = 0
        for name in _OPERAND_RE.findall(instr.args):
            src = table.get(name)
            if src is not None:
                total += src.out_bytes
        return total

    def _trip_count(self, instr: Instr) -> int:
        m = _TRIP_RE.search(instr.attrs)
        if m:
            return int(m.group(1))
        # fallback: largest s32 constant in the condition computation
        for cname in self._called(instr):
            if "cond" in cname or "region_1" in cname:
                best = 0
                for i in self.computations.get(cname, []):
                    if i.op == "constant":
                        cm = re.search(r"constant\((\d+)\)", i.args)
                        if cm:
                            best = max(best, int(cm.group(1)))
                if best:
                    return best
        return 1

    def _fusion_dus_bytes(self, instr: Instr) -> Optional[float]:
        """If `instr` is a fusion whose root is a dynamic-update-slice (or a
        tuple of them — XLA's functional in-place scan stacking), return the
        summed update-slice bytes; else None.  Charging the whole buffer
        would make scan-stacked outputs quadratic in trip count."""
        total = 0.0
        found = False
        for cname in self._called(instr):
            instrs = self.computations.get(cname, [])
            if not instrs:
                continue
            table = {i.name: i for i in instrs}
            root = instrs[-1]
            roots = [root]
            if root.op == "tuple":
                roots = [table[n] for n in _OPERAND_RE.findall(root.args)
                         if n in table]
            for r in roots:
                if r.op != "dynamic-update-slice":
                    continue
                found = True
                names = _OPERAND_RE.findall(r.args)
                if len(names) > 1 and names[1] in table:
                    total += table[names[1]].out_bytes
                else:
                    total += r.out_bytes
        return total if found else None

    def _dot_flops(self, instr: Instr, comp: str) -> float:
        out = instr.out_elems
        # contracting dims from the lhs operand shape
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
        contract = 1
        if m and m.group(1):
            dims = [int(x) for x in m.group(1).split(",")]
            table = {i.name: i for i in self.computations[comp]}
            names = _OPERAND_RE.findall(instr.args)
            if names and names[0] in table:
                sm = _SHAPE_RE.search(table[names[0]].type_str)
                if sm and sm.group("dims"):
                    lhs_dims = [int(x) for x in sm.group("dims").split(",")]
                    for d in dims:
                        if d < len(lhs_dims):
                            contract *= lhs_dims[d]
        return 2.0 * out * contract

    # -- TPU-fusion-emulated byte recount ------------------------------------
    #
    # XLA:CPU materializes far more fusion boundaries than XLA:TPU, so raw
    # operand+output byte counting (bytes_accessed) over-states TPU HBM
    # traffic several-fold.  bytes_fused emulates TPU fusion: FUSIBLE ops
    # (elementwise chains, broadcasts, layout ops, CPU kLoop fusions) are
    # transparent; traffic is charged only at non-fusible boundaries (dot,
    # reduce, DUS/DS, concat, collectives, sort), walking each operand back
    # through transparent ops to its materialized source and charging
    # min(bytes along the path) — a broadcast reads its small source, a
    # reshape is free, a GTE of a loop carry reads only its component.

    _FUSIBLE = (_ELEMENTWISE | _TRANSCENDENTAL | frozenset((
        "fusion", "copy", "convert", "broadcast", "reshape", "transpose",
        "bitcast", "bitcast-convert", "pad", "reverse", "iota",
        "get-tuple-element", "tuple", "rng-bit-generator", "rng",
        "optimization-barrier", "opt-barrier", "domain",
    )))
    _SKIP_TRAFFIC = frozenset((
        "parameter", "constant", "after-all", "token", "partition-id",
        "replica-id", "all-reduce-done", "all-gather-done", "async-done",
        "collective-permute-done", "while", "call", "conditional",
        "async-start", "custom-call",
    ))

    def _sources(self, name: str, table: Dict[str, "Instr"],
                 memo: Dict[str, Dict[str, int]]) -> Dict[str, int]:
        """Terminal materialized sources reachable via fusible ops:
        {terminal instr name: effective bytes (min along path)}."""
        if name in memo:
            return memo[name]
        instr = table.get(name)
        if instr is None:
            memo[name] = {}
            return memo[name]
        if instr.op == "iota":
            memo[name] = {}                       # generated on the fly
            return memo[name]
        if ((instr.op in self._FUSIBLE
             and not (instr.op == "fusion"
                      and self._fusion_dus_bytes(instr) is not None))
                or (self.zero_scopes and instr.in_scope(self.zero_scopes))):
            out: Dict[str, int] = {}
            memo[name] = out                      # cycle guard
            cap = instr.out_bytes
            for op_name in _OPERAND_RE.findall(instr.args):
                for t, b in self._sources(op_name, table, memo).items():
                    eff = min(b, cap) if cap else b
                    out[t] = min(out.get(t, eff), eff)
            return out
        memo[name] = {name: instr.out_bytes}      # materialized terminal
        return memo[name]

    def _fused_traffic(self, comp: str, in_scope: bool = False) -> float:
        """Non-recursive fusion-emulated HBM traffic of one computation
        (sub-computations are handled by the cost() recursion)."""
        if in_scope:
            return 0.0                            # kernelized: VMEM-resident
        instrs = self.computations.get(comp, [])
        if not instrs:
            return 0.0
        table = {i.name: i for i in instrs}
        memo: Dict[str, Dict[str, int]] = {}
        total = 0.0

        def operand_read(instr: Instr, skip: int = -1) -> float:
            seen: Dict[str, int] = {}
            for idx, op_name in enumerate(_OPERAND_RE.findall(instr.args)):
                if idx == skip:
                    continue
                for t, b in self._sources(op_name, table, memo).items():
                    seen[t] = min(seen.get(t, b), b)
            return float(sum(seen.values()))

        for instr in instrs:
            op = instr.op
            base = op.replace("-start", "")
            if self.zero_scopes and instr.in_scope(self.zero_scopes):
                continue                          # kernelized: VMEM-resident
            if op in self._SKIP_TRAFFIC and base not in _COLLECTIVES:
                continue
            if op == "fusion":
                dus = self._fusion_dus_bytes(instr)
                if dus is not None:               # in-place scan stacking
                    total += 2.0 * dus
                continue
            if op in self._FUSIBLE:
                continue
            if op in ("dynamic-update-slice", "scatter"):
                names = _OPERAND_RE.findall(instr.args)
                upd_i = 1 if op == "dynamic-update-slice" else 2
                upd = (table[names[upd_i]].out_bytes
                       if len(names) > upd_i and names[upd_i] in table
                       else instr.out_bytes)
                total += 2.0 * upd
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                total += 2.0 * instr.out_bytes
                continue
            # dot / convolution / reduce / concatenate / sort / collectives
            total += instr.out_bytes + operand_read(instr)
        root = instrs[-1]
        if root.op in self._FUSIBLE:              # body output materializes
            total += root.out_bytes
        return total

    # -- main walk ----------------------------------------------------------

    def cost(self, comp: Optional[str] = None, *,
             loop_multipliers: bool = True,
             _memo: Optional[Dict] = None,
             _in_scope: bool = False) -> Cost:
        """Aggregate cost of `comp` (default entry), loop-aware.

        _in_scope: the caller instruction was inside a zero-byte scope —
        inherited down the call graph because XLA drops op_name metadata on
        some optimized ops (e.g. CSE'd dots), so per-instruction matching
        alone misses exactly the hot ops."""
        comp = comp or self.entry
        _memo = {} if _memo is None else _memo
        key = (comp, _in_scope)
        if key in _memo:
            return _memo[key]
        total = Cost(bytes_fused=self._fused_traffic(comp, _in_scope))
        for instr in self.computations.get(comp, []):
            op = instr.op
            zb = _in_scope or (self.zero_scopes
                               and instr.in_scope(self.zero_scopes))
            if op == "while":
                trip = self._trip_count(instr) if loop_multipliers else 1
                total.while_trip_counts.append(trip)
                for cname in self._called(instr):
                    total += self.cost(cname,
                                       loop_multipliers=loop_multipliers,
                                       _memo=_memo,
                                       _in_scope=bool(zb)).scaled(trip)
                continue
            if op == "fusion":
                sub = Cost()
                for cname in self._called(instr):
                    sub += self.cost(cname,
                                     loop_multipliers=loop_multipliers,
                                     _memo=_memo, _in_scope=bool(zb))
                total.flops += sub.flops
                total.transcendentals += sub.transcendentals
                # in-fusion loops are impossible; bytes = fusion boundary —
                # except in-place DUS-root fusions (scan stacking): charge
                # the updated slice, not the whole buffer.
                dus = self._fusion_dus_bytes(instr)
                if zb:
                    pass                          # kernelized: VMEM-resident
                elif dus is not None:
                    total.bytes_accessed += 2.0 * dus
                else:
                    total.bytes_accessed += (
                        instr.out_bytes + self._operand_bytes(instr, comp))
                for k, v in sub.collective_bytes.items():
                    total.collective_bytes[k] = (
                        total.collective_bytes.get(k, 0.0) + v)
                continue
            if op in ("call", "conditional", "async-start", "custom-call"):
                for cname in self._called(instr):
                    total += self.cost(cname,
                                       loop_multipliers=loop_multipliers,
                                       _memo=_memo, _in_scope=bool(zb))
                if not zb:
                    total.bytes_accessed += (
                        instr.out_bytes + self._operand_bytes(instr, comp))
                continue
            base = op.replace("-start", "") if op.endswith("-start") else op
            if base in _COLLECTIVES:
                total.collective_bytes[base] = (
                    total.collective_bytes.get(base, 0.0) + instr.out_bytes)
                if not zb:
                    total.bytes_accessed += (
                        instr.out_bytes + self._operand_bytes(instr, comp))
                continue
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "token", "partition-id",
                      "replica-id", "all-reduce-done", "all-gather-done",
                      "collective-permute-done", "async-done", "domain",
                      "opt-barrier"):
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: only the touched slice moves (matches
                # xla::HloCostAnalysis; counting the full buffer makes
                # scan-stacked outputs quadratic in trip count)
                if not zb:
                    table = {i.name: i for i in self.computations[comp]}
                    names = _OPERAND_RE.findall(instr.args)
                    upd_i = 1 if op == "dynamic-update-slice" else 2
                    upd = (table[names[upd_i]].out_bytes
                           if len(names) > upd_i and names[upd_i] in table
                           else instr.out_bytes)
                    total.bytes_accessed += 2 * upd
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                if not zb:
                    total.bytes_accessed += 2 * instr.out_bytes
                continue
            if op == "dot":
                total.flops += self._dot_flops(instr, comp)
            elif op == "convolution":
                # approx: 2 x out x (reduction size) — reduction size from
                # flop-heaviest interpretation is unavailable in text; use
                # operand/output ratio heuristic.
                ob = max(instr.out_elems, 1)
                ib = self._operand_bytes(instr, comp)
                total.flops += 2.0 * ob * max(ib // max(ob, 1), 1)
            elif op in _TRANSCENDENTAL:
                total.transcendentals += instr.out_elems
            elif op in _ELEMENTWISE:
                total.flops += instr.out_elems
            elif op in ("reduce", "reduce-window"):
                total.flops += self._operand_bytes(instr, comp) // 4
            if op in _DATA_MOVEMENT and not zb:
                total.bytes_accessed += (instr.out_bytes
                                         + self._operand_bytes(instr, comp))
        _memo[key] = total
        return total


def analyze_text(hlo_text: str, *, loop_multipliers: bool = True,
                 zero_byte_scopes: Tuple[str, ...] = ()) -> Cost:
    """Parse + cost an HLO module's text (per-device, post-SPMD).

    zero_byte_scopes: jax.named_scope names whose ops are charged zero HBM
    bytes — the accounting credit for regions replaced by a Pallas kernel
    (validated separately in kernels/); FLOPs are still counted."""
    return HloModule(hlo_text, zero_byte_scopes).cost(
        loop_multipliers=loop_multipliers)
