"""Model / run configuration schema.

One `ModelConfig` describes any of the assigned architectures; family-specific
sub-configs (MoE / SSM / recurrent / enc-dec) are optional blocks.  Layer
heterogeneity (gemma3 5:1 local:global, recurrentgemma 2:1 rec:attn) is a
`pattern` of block kinds that repeats; models scan over stacked *super-block*
params (one pattern period per scan step) plus an explicit remainder.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_chunk: int = 512          # chunked GShard dispatch (memory-safe)
    aux_loss_weight: float = 0.01
    impl: str = "onehot"             # "onehot" (GShard baseline) | "gather"


@dataclasses.dataclass(frozen=True)
class SSDConfig:               # Mamba2 (state-space duality)
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:         # Griffin / RecurrentGemma RG-LRU block
    rnn_width: int = 0          # 0 -> d_model
    conv_width: int = 4
    c_constant: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncDecConfig:            # Whisper-style
    encoder_layers: int = 24
    encoder_len: int = 1500     # conv-frontend output frames (stubbed input)


# Block kinds usable in `pattern`:
#   "attn"   full causal self-attention + FFN
#   "local"  sliding-window self-attention + FFN
#   "global" full attention (alias of attn, named for 5:1 patterns)
#   "rec"    RG-LRU recurrent block + FFN
#   "ssd"    Mamba2 SSD mixer (no separate FFN)
BLOCK_KINDS = ("attn", "local", "global", "rec", "ssd")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    pattern: Tuple[str, ...] = ("attn",)   # repeats to cover n_layers
    window: int = 1024                     # for "local" blocks
    rope_theta: float = 1e4
    rope_theta_local: float = 0.0          # 0 -> same as rope_theta
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl M-RoPE
    qk_norm: bool = False
    sandwich_norm: bool = False            # gemma3 pre+post block norms
    norm: str = "rms"                      # rms | ln | ln_nonparam
    gated_mlp: bool = True
    act: str = "silu"
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssd: Optional[SSDConfig] = None
    rec: Optional[RecurrentConfig] = None
    encdec: Optional[EncDecConfig] = None
    dtype: str = "bfloat16"                # activation dtype
    param_dtype: str = "bfloat16"
    kv_dtype: str = ""                     # "" -> dtype; "int8" -> quantized
    #   KV cache (per-(pos,head) absmax scales; decode cells are memory-
    #   bound on cache reads, int8 halves that traffic)
    # which shapes this arch skips and why (assignment rules)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    # ---- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Physical vocab rounded to a multiple of 128 so the embedding /
        head tables shard evenly on any model-axis width that divides 128
        (granite 49155, whisper 51865, mamba2 50280 are not 16-divisible).
        Loss and sampling mask columns >= vocab_size."""
        return -(-self.vocab_size // 128) * 128

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(k == "ssd" for k in self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.hd
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        total = self.vocab_size * d                         # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                    # lm head
        per_kind = {}
        attn = d * n_q + 2 * d * n_kv + n_q * d
        ffn_mult = 3 if self.gated_mlp else 2
        if self.moe:
            ffn = (self.moe.n_experts * ffn_mult * d * self.d_ff
                   + d * self.moe.n_experts)                # experts + router
        else:
            ffn = ffn_mult * d * self.d_ff
        per_kind["attn"] = per_kind["local"] = per_kind["global"] = attn + ffn
        if self.rec:
            w = self.rec.rnn_width or d
            rec = (2 * d * w                 # two input branches
                   + self.rec.conv_width * w  # conv
                   + 2 * w                    # gates' diagonal params
                   + 2 * w * w                # gate projections (lru)
                   + w * d)                   # out proj
            per_kind["rec"] = rec + ffn
        if self.ssd:
            di = self.ssd.expand * d
            nh = di // self.ssd.head_dim
            g = self.ssd.n_groups
            ssd = (d * (2 * di + 2 * g * self.ssd.d_state + nh)  # in_proj
                   + self.ssd.conv_width * (di + 2 * g * self.ssd.d_state)
                   + 2 * nh                                       # A_log, D
                   + di * d)                                      # out_proj
            per_kind["ssd"] = ssd
        for i in range(self.n_layers):
            kind = self.pattern[i % len(self.pattern)]
            total += per_kind[kind]
        if self.encdec:
            # encoder self-attn + ffn, decoder adds cross-attn.
            total += self.encdec.encoder_layers * (attn + ffn)
            total += self.n_layers * attn                   # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        ffn_mult = 3 if self.gated_mlp else 2
        dense_ffn = self.moe.n_experts * ffn_mult * d * self.d_ff
        active_ffn = self.moe.top_k * ffn_mult * d * self.d_ff
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.pattern[i % len(self.pattern)] in
            ("attn", "local", "global", "rec"))
        return int(self.param_count() - n_moe_layers * (dense_ffn - active_ffn))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
