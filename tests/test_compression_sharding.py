"""Gradient compression codec properties + sharding rule validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models import api
from repro.parallel import compression, sharding as shd
from repro.launch.mesh import make_mesh


# ---------------------------------------------------------------------------
# int8 rowwise codec
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    q, s = compression.int8_rowwise_encode(jax.random.PRNGKey(seed), x)
    y = compression.int8_rowwise_decode(q, s)
    # error per element bounded by one quantization step (= scale)
    err = np.abs(np.asarray(y) - np.asarray(x))
    bound = np.asarray(s) * 1.0 + 1e-7
    assert (err <= bound + 1e-6).all()


def test_int8_unbiased():
    """Stochastic rounding: E[decode(encode(x))] == x."""
    x = jnp.full((1, 64), 0.3712, jnp.float32) * jnp.linspace(
        -1, 1, 64)[None]
    acc = np.zeros((1, 64), np.float64)
    n = 400
    for i in range(n):
        q, s = compression.int8_rowwise_encode(jax.random.PRNGKey(i), x)
        acc += np.asarray(compression.int8_rowwise_decode(q, s),
                          np.float64)
    mean = acc / n
    np.testing.assert_allclose(mean, np.asarray(x, np.float64), atol=5e-4)


def test_compressed_psum_single_axis():
    """shard_map DP reduction with all 3 codecs on a 1-wide axis."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1,), ("dp",))
    g = {"w": jnp.arange(8.0).reshape(2, 4)}

    for method in ("none", "bf16", "int8"):
        def f(t):
            return compression.compressed_psum(
                t, "dp", method, key=jax.random.PRNGKey(0))

        out = shard_map(f, mesh=mesh, in_specs=({"w": P()},),
                        out_specs={"w": P()})(g)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), rtol=2e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("kind", ["train", "serve"])
def test_param_specs_are_rank_valid(arch, kind):
    cfg = registry.reduced_config(registry.get_config(arch))
    model = api.build(cfg)
    shapes = model.param_shapes()
    mesh = make_mesh((1, 1), ("data", "model"))
    shards = shd.params_sharding(shapes, mesh, kind)
    for leaf, sh in zip(jax.tree.leaves(shapes), jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))):
        assert len(sh.spec) <= len(leaf.shape), (leaf.shape, sh.spec)


@pytest.mark.parametrize("arch", ["yi-34b", "recurrentgemma-9b",
                                  "mamba2-1.3b", "whisper-medium"])
def test_cache_specs_are_rank_valid(arch):
    cfg = registry.reduced_config(registry.get_config(arch))
    model = api.build(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    cache = jax.eval_shape(lambda: model.init_cache(4, 32))
    shards = shd.cache_sharding(cache, mesh, 4)
    for leaf, sh in zip(jax.tree.leaves(cache), jax.tree.leaves(
            shards, is_leaf=lambda x: hasattr(x, "spec"))):
        assert len(sh.spec) <= len(leaf.shape), (leaf.shape, sh.spec)


def test_batch_sharding_divisibility():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert shd.batch_sharding(mesh, 7) in (("data",), None)
    # batch 7 with data=1 divides; with a fake 16-wide axis it must refuse
    # (can't test >1 devices here; rule logic covered by dryrun cells)
    assert shd.data_spec(mesh, 8, 2) is not None
