"""Paper Fig. 1 — roofline placement of vadvc / hdiff / copy.

Computes each kernel's arithmetic intensity and its position under the
POWER9 roofline (the paper's measured baseline points) and the TPU v5e
roofline (our target platform), from the analytic op specs; the wall-clock
column is the measured jnp reference on this CPU (labeled 'cpu-jnp').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hierarchy as hw
from repro.core import perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href
from repro.kernels.vadvc import ref as vref

GRID = (64, 256, 256)    # the paper's 256x256x64 domain

# Paper Fig. 1 measured POWER9 numbers (GFLOP/s, 64 threads)
PAPER_POWER9 = {"vadvc": 29.1, "hdiff": 58.5}


def run():
    rng = np.random.default_rng(0)
    nz, ny, nx = GRID
    src = jnp.asarray(rng.normal(size=GRID).astype(np.float32))
    us, up, ut, uts = (jnp.asarray(rng.normal(size=GRID).astype(np.float32))
                       for _ in range(4))
    wcon = jnp.asarray(
        rng.uniform(-0.2, 0.2, size=(nz, ny, nx + 1)).astype(np.float32))

    hd_t = time_fn(jax.jit(href.hdiff), src)
    va_t = time_fn(jax.jit(vref.vadvc), us, wcon, up, ut, uts)

    for name, op, t_us in (("hdiff", tiling.HDIFF, hd_t),
                           ("vadvc", tiling.VADVC, va_t)):
        ai32 = op.arithmetic_intensity("float32")
        tuned = tune(op, GRID, "float32")
        est = tuned.est
        frac = perfmodel.roofline_fraction(est)
        p9_roof = min(hw.POWER9_PEAK_FLOPS,
                      ai32 * hw.POWER9_DRAM_BW) / 1e9
        v5e_roof = min(hw.PEAK_FP32_FLOPS, ai32 * hw.HBM_BW) / 1e9
        emit(f"fig1/{name}", t_us,
             f"AI={ai32:.2f}flop/B p9_roof={p9_roof:.0f}GF "
             f"paper_p9={PAPER_POWER9[name]}GF v5e_roof={v5e_roof:.0f}GF "
             f"model_v5e={est.gflops:.0f}GF frac={frac:.2f}")
    emit("fig1/machine_balance", 0.0,
         f"v5e_bf16={hw.tpu_v5e().machine_balance(jnp.bfloat16):.0f}flop/B "
         f"p9={hw.POWER9_PEAK_FLOPS / hw.POWER9_DRAM_BW:.1f}flop/B")


if __name__ == "__main__":
    run()
