"""Forecast-as-a-service: a continuous-batching ensemble serving engine.

An operational forecast service runs the SAME compiled stencil programs
for many concurrent consumers — requests differ only in initial state and
step count, over a handful of plans.  This engine is that service layer
over the plan API (`weather/program.py`):

* **Plan cache, compile once / serve forever.**  Every request names a
  `StencilProgram` (ensemble 1 — one forecast).  The engine canonicalizes
  it with `program.plan_cache_key(prog, ensemble=slots)` and compiles at
  most ONE `ExecutionPlan` per distinct program, shared by every request
  that ever arrives for it.

* **Continuous batching into the ensemble axis.**  The `(e, ...)` fold is
  already the batch dimension of every kernel, so admission is a slot
  scatter (`ensemble_slot_assign`) into a zero-initialized batch state,
  and each engine round is ONE `plan.step` launch for up to `slots`
  concurrent forecasts.  Finished slots retire at round boundaries and
  are backfilled from the queue — the batch never drains to serve a
  straggler.

* **Bit-identical to solo runs.**  The correctness contract (verified by
  `tests/test_forecast_engine.py`'s property harness) is that serving a
  request batched is bit-identical to `compile(program).run(state,
  steps)` solo.  Two facts make that hold: ensemble members are computed
  independently (no cross-slot arithmetic, tile resolution per-member
  invariant), and the engine advances every request through EXACTLY the
  round sequence a solo `run()` would — `floor(steps/k)` full rounds plus
  one ragged tail of `steps mod k`, via the plan's own
  `round_plan(k')` tail machinery.  When ragged step counts force a
  shorter round than some co-batched slot's next canonical part, that
  slot runs the round anyway (slots advance together) but is ROLLED BACK
  (`ensemble_slot_select`) and not credited, so its realized sequence
  never deviates.  With `k_steps == 1` (every single-chip auto plan)
  rounds are single steps and no rollback ever happens.

* **Host I/O overlaps device compute.**  `submit` stages request arrays
  onto the device immediately (`jax.device_put` is async), so by the time
  a slot frees the admission wave's data is already resident; the slot
  scatter donates the old batch buffer on backends that support donation.
  Retirement reads back exactly one slot.

* **Warm restarts.**  `checkpoint()` persists the whole engine — batched
  in-flight state, queue, finished results, per-request bookkeeping —
  through `ckpt.save_tree`; `ForecastEngine.restore()` resumes mid-
  forecast in a fresh process: in-flight requests continue from their
  checkpointed step (no respin to step 0), and the plan cache rebuilds
  lazily from the persisted program keys.

See docs/serving.md for the lifecycle diagrams and BENCH_serve.json for
the latency/occupancy numbers under synthetic load.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.weather import domain as _domain
from repro.weather import fields as _fields
from repro.weather import program as _wprog
from repro.weather.fields import WeatherState

__all__ = ["ForecastRequest", "ForecastResult", "ForecastEngine"]


@dataclasses.dataclass
class ForecastRequest:
    """One forecast: a program (the *what*, ensemble 1), its initial
    state ((1, nz, ny, nx) leaves), and how many timesteps to advance."""

    program: _wprog.StencilProgram
    state: WeatherState
    steps: int
    rid: Optional[int] = None                   # assigned by submit()

    def validate(self) -> None:
        if self.program.ensemble != 1:
            raise ValueError(f"a request is ONE forecast: program.ensemble "
                             f"must be 1, got {self.program.ensemble}")
        if not isinstance(self.steps, int) or self.steps < 0:
            raise ValueError(f"steps={self.steps!r} must be a "
                             f"non-negative int")
        if self.state.grid_shape != self.program.grid_shape:
            raise ValueError(f"state grid {self.state.grid_shape} != "
                             f"program grid {self.program.grid_shape}")
        if str(self.state.wcon.dtype) != self.program.dtype:
            raise ValueError(f"state dtype {self.state.wcon.dtype} != "
                             f"program dtype {self.program.dtype}")
        if set(self.state.fields) != set(self.program.fields):
            raise ValueError(f"state fields {sorted(self.state.fields)} != "
                             f"program fields {sorted(self.program.fields)}")
        if int(self.state.wcon.shape[0]) != 1:
            raise ValueError("request state must have a leading ensemble "
                             "dim of 1")


@dataclasses.dataclass
class ForecastResult:
    """A finished forecast: the final state plus honest per-request
    accounting — `latency_s` is THIS request's admit-to-finish wall time
    (not its wave's), `queue_wait_s` the time it sat unadmitted."""

    rid: int
    program: _wprog.StencilProgram
    state: WeatherState                         # (1, ...) leaves, host-side
    steps: int
    latency_s: float
    queue_wait_s: float
    rounds: int


@dataclasses.dataclass
class _Slot:
    rid: int
    remaining: int
    steps: int
    admit_t: float
    queue_wait_s: float
    rounds: int = 0


@dataclasses.dataclass
class _Lane:
    """One plan's batch: all slots share the lane's compiled plan."""

    key: _wprog.StencilProgram                  # canonical, ensemble=slots
    batch: WeatherState                         # (slots, nz, ny, nx) leaves
    slots: List[Optional[_Slot]]


@dataclasses.dataclass
class _Pending:
    request: ForecastRequest
    submit_t: float
    counted: bool = False       # plan-cache hit/miss recorded once only


class ForecastEngine:
    """Continuous-batching forecast service over cached ExecutionPlans.

    `submit()` enqueues (and stages arrays onto the device), `pump()`
    admits + advances every busy lane one round, `drain()` pumps until
    idle and returns `{rid: ForecastResult}`.  `checkpoint()` /
    `ForecastEngine.restore()` persist and resume the warm engine."""

    def __init__(self, slots: int = 4, mesh=None,
                 interpret: Optional[bool] = None, ax_e: str = "pod",
                 ax_y: str = "data", ax_x: str = "model",
                 ckpt_dir: Optional[str] = None, ckpt_keep: int = 3):
        if slots < 1:
            raise ValueError(f"slots={slots} must be >= 1")
        self.slots = slots
        self.mesh = mesh
        self.interpret = interpret
        self.mesh_axes = (ax_e, ax_y, ax_x)
        self.ckpt_dir = ckpt_dir
        self.ckpt_keep = ckpt_keep

        self._queue: collections.deque[_Pending] = collections.deque()
        self._lanes: Dict[_wprog.StencilProgram, _Lane] = {}
        self._plans: Dict[_wprog.StencilProgram, _wprog.ExecutionPlan] = {}
        self._results: Dict[int, ForecastResult] = {}
        self._next_rid = 0
        self._ckpt_step = 0
        self._stats = {"plan_cache_hits": 0, "plan_cache_misses": 0,
                       "rounds": 0, "admitted": 0, "completed": 0,
                       "rolled_back_slot_rounds": 0,
                       "occupancy_sum": 0.0, "occupancy_samples": 0}
        # Donating the pre-admission batch buffer lets XLA reuse it for
        # the scattered batch; CPU has no donation (it would only warn).
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._assign = jax.jit(_wprog.ensemble_slot_assign,
                               donate_argnums=donate)

    # -- public API ---------------------------------------------------------
    def submit(self, request: ForecastRequest) -> int:
        """Enqueue one forecast; returns its rid.  The initial state is
        device_put NOW (async) so admission later is a device-side
        scatter — staging hides behind whatever round is running."""
        request.validate()
        if request.rid is None:
            request.rid = self._next_rid
        self._next_rid = max(self._next_rid, request.rid) + 1
        request.state = jax.device_put(request.state)
        self._queue.append(_Pending(request, time.perf_counter()))
        return request.rid

    def has_work(self) -> bool:
        return bool(self._queue) or any(
            any(s is not None for s in lane.slots)
            for lane in self._lanes.values())

    def pump(self) -> bool:
        """Admit whatever fits, advance every busy lane ONE round, retire
        finished slots.  Returns `has_work()`."""
        self._admit()
        for lane in self._lanes.values():
            if any(s is not None for s in lane.slots):
                self._round(lane)
        return self.has_work()

    def drain(self) -> Dict[int, ForecastResult]:
        """Pump until idle; returns ALL results finished so far."""
        while self.pump():
            pass
        return dict(self._results)

    @property
    def results(self) -> Dict[int, ForecastResult]:
        return dict(self._results)

    def stats(self) -> Dict[str, Any]:
        """Service counters: plan-cache hit rate, mean batch occupancy
        (active slots / slots over lane-rounds), rounds/admissions."""
        s = dict(self._stats)
        lookups = s["plan_cache_hits"] + s["plan_cache_misses"]
        s["plan_cache_hit_rate"] = (
            s["plan_cache_hits"] / lookups if lookups else None)
        s["occupancy"] = (s["occupancy_sum"] / s["occupancy_samples"]
                          if s["occupancy_samples"] else 0.0)
        s["plans_cached"] = len(self._plans)
        s["queued"] = len(self._queue)
        s["active"] = sum(sum(sl is not None for sl in lane.slots)
                          for lane in self._lanes.values())
        return s

    # -- scheduling ---------------------------------------------------------
    def _plan_for(self, key: _wprog.StencilProgram) -> _wprog.ExecutionPlan:
        plan = self._plans.get(key)
        if plan is None:
            ax_e, ax_y, ax_x = self.mesh_axes
            # Call through the module so a test spy on
            # repro.weather.program.compile observes every compilation.
            plan = _wprog.compile(key, mesh=self.mesh, ax_e=ax_e, ax_y=ax_y,
                                  ax_x=ax_x, interpret=self.interpret)
            self._plans[key] = plan
        return plan

    def _lane_for(self, key: _wprog.StencilProgram) -> _Lane:
        lane = self._lanes.get(key)
        if lane is None:
            batch = _fields.zeros_state(key.grid_shape, ensemble=self.slots,
                                        dtype=key.dtype, names=key.fields)
            if self.mesh is not None:
                batch = _domain.shard_state(
                    batch, self.mesh, self._plan_for(key).state_spec)
            lane = _Lane(key=key, batch=batch,
                         slots=[None] * self.slots)
            self._lanes[key] = lane
        return lane

    def _admit(self) -> None:
        """FIFO admission: fill free slots per lane; a lane with no free
        slot does not block requests bound for other lanes.  All slots
        admitted to one lane this wave go in as ONE scatter."""
        now = time.perf_counter()
        waves: Dict[_wprog.StencilProgram,
                    List[Tuple[int, _Pending]]] = {}
        keep: collections.deque[_Pending] = collections.deque()
        free: Dict[_wprog.StencilProgram, List[int]] = {}
        for pend in self._queue:
            req = pend.request
            if req.steps == 0:
                # A 0-step forecast is its own answer (solo run(state, 0)
                # is the identity) — finish without occupying a slot.
                self._finish(req.rid, req.program,
                             jax.tree_util.tree_map(np.asarray, req.state),
                             steps=0, admit_t=now,
                             queue_wait_s=now - pend.submit_t, rounds=0)
                continue
            key = _wprog.plan_cache_key(req.program, ensemble=self.slots)
            # Request-level cache accounting (once per request): hit-rate
            # == the fraction of requests served by an already-compiled
            # plan, so N requests over M programs miss exactly M times.
            if not pend.counted:
                pend.counted = True
                if key in self._plans:
                    self._stats["plan_cache_hits"] += 1
                else:
                    self._stats["plan_cache_misses"] += 1
                    self._plan_for(key)
            lane = self._lane_for(key)
            if key not in free:
                free[key] = [i for i, s in enumerate(lane.slots)
                             if s is None]
            if free[key]:
                waves.setdefault(key, []).append((free[key].pop(0), pend))
            else:
                keep.append(pend)
        self._queue = keep
        for key, wave in waves.items():
            lane = self._lanes[key]
            idx = [i for i, _ in wave]
            sub = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0),
                *[p.request.state for _, p in wave])
            lane.batch = self._assign(lane.batch, jnp.asarray(idx), sub)
            admit_t = time.perf_counter()
            for i, pend in wave:
                req = pend.request
                lane.slots[i] = _Slot(rid=req.rid, remaining=req.steps,
                                      steps=req.steps, admit_t=admit_t,
                                      queue_wait_s=admit_t - pend.submit_t)
                self._stats["admitted"] += 1

    def _round(self, lane: _Lane) -> None:
        """One lane round: the shortest next canonical part among active
        slots picks the round depth; slots whose next part is deeper run
        along but are rolled back (uncredited) so every request's realized
        round sequence equals its solo `run()` sequence."""
        plan = self._plan_for(lane.key)
        k = plan.k_steps
        parts = {i: min(s.remaining, k)
                 for i, s in enumerate(lane.slots) if s is not None}
        kk = min(parts.values())
        participants = [i for i, p in parts.items() if p == kk]
        prev = lane.batch if len(participants) < len(parts) else None
        lane.batch = plan.round_plan(kk).step(lane.batch)
        if prev is not None:
            mask = np.zeros(self.slots, bool)
            mask[participants] = True
            lane.batch = _wprog.ensemble_slot_select(mask, lane.batch, prev)
            self._stats["rolled_back_slot_rounds"] += (
                len(parts) - len(participants))
        self._stats["rounds"] += 1
        self._stats["occupancy_sum"] += len(parts) / self.slots
        self._stats["occupancy_samples"] += 1
        for i in participants:
            slot = lane.slots[i]
            slot.remaining -= kk
            slot.rounds += 1
            if slot.remaining == 0:
                self._retire(lane, i)

    def _retire(self, lane: _Lane, i: int) -> None:
        slot = lane.slots[i]
        lane.slots[i] = None
        # Read back exactly this slot; blocking here IS the finish time.
        state = jax.tree_util.tree_map(
            np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
        prog = dataclasses.replace(lane.key, ensemble=1)
        self._finish(slot.rid, prog, state, steps=slot.steps,
                     admit_t=slot.admit_t, queue_wait_s=slot.queue_wait_s,
                     rounds=slot.rounds)

    def _finish(self, rid: int, prog, state, *, steps: int, admit_t: float,
                queue_wait_s: float, rounds: int) -> None:
        self._results[rid] = ForecastResult(
            rid=rid, program=prog, state=state, steps=steps,
            latency_s=time.perf_counter() - admit_t,
            queue_wait_s=queue_wait_s, rounds=rounds)
        self._stats["completed"] += 1

    # -- warm-state checkpointing ------------------------------------------
    def checkpoint(self, ckpt_dir: Optional[str] = None,
                   step: Optional[int] = None) -> int:
        """Persist the warm engine (in-flight batches, queue, results,
        bookkeeping) atomically via `ckpt.save_tree`.  Returns the
        checkpoint step.  In-flight latency clocks are stored as
        elapsed-so-far and resume ticking on restore."""
        ckpt_dir = ckpt_dir or self.ckpt_dir
        if ckpt_dir is None:
            raise ValueError("no ckpt_dir: pass one here or at __init__")
        if step is None:
            step = self._ckpt_step
        self._ckpt_step = step + 1
        now = time.perf_counter()
        lanes = list(self._lanes.values())
        tree = {
            "lanes": [lane.batch for lane in lanes],
            "queue": [p.request.state for p in self._queue],
            "results": {str(rid): r.state
                        for rid, r in self._results.items()},
        }
        extra = {
            "slots": self.slots,
            "next_rid": self._next_rid,
            "ckpt_step": self._ckpt_step,
            "stats": {k: v for k, v in self._stats.items()},
            "lanes": [{
                "program": lane.key.to_json(),
                "slots": [None if s is None else {
                    "rid": s.rid, "remaining": s.remaining,
                    "steps": s.steps, "rounds": s.rounds,
                    "elapsed_s": now - s.admit_t,
                    "queue_wait_s": s.queue_wait_s,
                } for s in lane.slots],
            } for lane in lanes],
            "queue": [{
                "rid": p.request.rid,
                "steps": p.request.steps,
                "program": p.request.program.to_json(),
                "waited_s": now - p.submit_t,
            } for p in self._queue],
            "results": [{
                "rid": r.rid, "steps": r.steps, "rounds": r.rounds,
                "latency_s": r.latency_s, "queue_wait_s": r.queue_wait_s,
                "program": r.program.to_json(),
            } for r in self._results.values()],
        }
        ckpt.save_tree(ckpt_dir, step, tree, extra=extra,
                       keep=self.ckpt_keep)
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, step: Optional[int] = None, *,
                mesh=None, interpret: Optional[bool] = None,
                ax_e: str = "pod", ax_y: str = "data", ax_x: str = "model",
                ckpt_keep: int = 3) -> "ForecastEngine":
        """Resume a checkpointed engine: in-flight forecasts continue from
        their persisted step (no respin), queued requests stay queued,
        finished results are preserved.  Plans are NOT serialized — the
        cache rebuilds lazily from the persisted program keys on the
        first round each lane runs."""
        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {ckpt_dir!r}")
        extra = ckpt.read_meta(ckpt_dir, step)["extra"]
        slots = extra["slots"]

        def prog_of(d):
            return _wprog.StencilProgram.from_json(d)

        def template(prog, ensemble):
            return _fields.zeros_state(prog.grid_shape, ensemble=ensemble,
                                       dtype=prog.dtype, names=prog.fields)

        tmpl = {
            "lanes": [template(prog_of(ln["program"]), slots)
                      for ln in extra["lanes"]],
            "queue": [template(prog_of(q["program"]), 1)
                      for q in extra["queue"]],
            "results": {str(r["rid"]): template(prog_of(r["program"]), 1)
                        for r in extra["results"]},
        }
        tree, _ = ckpt.restore_tree(ckpt_dir, step, tmpl)

        eng = cls(slots=slots, mesh=mesh, interpret=interpret, ax_e=ax_e,
                  ax_y=ax_y, ax_x=ax_x, ckpt_dir=ckpt_dir,
                  ckpt_keep=ckpt_keep)
        eng._next_rid = extra["next_rid"]
        eng._ckpt_step = extra["ckpt_step"]
        eng._stats.update(extra["stats"])
        now = time.perf_counter()
        for ln, batch in zip(extra["lanes"], tree["lanes"]):
            key = _wprog.plan_cache_key(prog_of(ln["program"]),
                                        ensemble=slots)
            if mesh is not None:
                batch = _domain.shard_state(batch, mesh,
                                            eng._plan_for(key).state_spec)
            else:
                batch = jax.device_put(batch)
            eng._lanes[key] = _Lane(
                key=key, batch=batch,
                slots=[None if s is None else _Slot(
                    rid=s["rid"], remaining=s["remaining"],
                    steps=s["steps"], rounds=s["rounds"],
                    admit_t=now - s["elapsed_s"],
                    queue_wait_s=s["queue_wait_s"])
                    for s in ln["slots"]])
        for q, state in zip(extra["queue"], tree["queue"]):
            req = ForecastRequest(program=prog_of(q["program"]),
                                  state=jax.device_put(state),
                                  steps=q["steps"], rid=q["rid"])
            eng._queue.append(_Pending(req, now - q["waited_s"]))
        for r in extra["results"]:
            eng._results[r["rid"]] = ForecastResult(
                rid=r["rid"], program=prog_of(r["program"]),
                state=jax.tree_util.tree_map(np.asarray,
                                             tree["results"][str(r["rid"])]),
                steps=r["steps"], latency_s=r["latency_s"],
                queue_wait_s=r["queue_wait_s"], rounds=r["rounds"])
        return eng
