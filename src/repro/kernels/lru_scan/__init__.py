"""NERO kernel package: lru_scan."""
