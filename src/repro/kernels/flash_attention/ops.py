"""Jitted wrapper + block-size selection for the flash-attention kernel.

`auto_blocks` applies the paper's precision-aware tiling rule (core/
autotune.py discipline) to attention: pick the largest (block_q, block_k)
whose VMEM working set — q, k, v blocks + fp32 scores + accumulator, double
buffered by the Pallas pipeline — fits the per-core budget, preferring
MXU-aligned multiples of 128.

`flash_traffic_bytes` is the kernel's analytic HBM traffic (what the
roofline pass adds back for a zero-byte-scoped region): q and o stream
once; k and v stream once per q block.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_mha_pallas

VMEM_BUDGET = 96 * 2**20      # bytes usable for kernel working set (v5e)


def auto_blocks(t: int, s: int, hd: int, dtype_bytes: int = 2,
                budget: int = VMEM_BUDGET) -> Tuple[int, int]:
    """Largest MXU-aligned (block_q, block_k) fitting the VMEM budget."""
    def fits(bq, bk):
        work = (bq * hd * dtype_bytes          # q block
                + 2 * bk * hd * dtype_bytes    # k, v blocks
                + bq * bk * 4                  # fp32 scores
                + bq * (hd + 2) * 4)           # fp32 acc + m + l
        return 2 * work <= budget              # double buffering

    for bq in (512, 256, 128):
        for bk in (1024, 512, 256, 128):
            if t % min(bq, t) == 0 and s % min(bk, s) == 0 and fits(bq, bk):
                return min(bq, t), min(bk, s)
    return min(128, t), min(128, s)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret"))
def flash_mha(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, interpret: bool = False):
    """Auto-tiled flash attention.  q: (B,T,H,hd); k, v: (B,S,KH,hd)."""
    bq, bk = auto_blocks(q.shape[1], k.shape[1], q.shape[3],
                         jnp.dtype(q.dtype).itemsize)
    return flash_mha_pallas(q, k, v, causal=causal, window=window,
                            softcap=softcap, block_q=bq, block_k=bk,
                            interpret=interpret)


def flash_traffic_bytes(b: int, t: int, s: int, h: int, kh: int, hd: int,
                        dtype_bytes: int = 2, block_q: int = 0) -> float:
    """Analytic HBM bytes of the kernel: q+o once, k/v re-streamed per
    q-block (the roofline credit for the kernelized scope)."""
    bq = block_q or auto_blocks(t, s, hd, dtype_bytes)[0]
    nq = max(t // bq, 1)
    q_o = 2 * b * t * h * hd * dtype_bytes
    kv = 2 * b * s * kh * hd * dtype_bytes * nq
    return float(q_o + kv)
