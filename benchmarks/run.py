"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (model-derived values labeled in
the derived column; this container is CPU-only so TPU numbers are
dry-run/model projections, wall-clock numbers are real)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (copy_stencil, dryrun_table, dycore_fused, energy,
                            kernel_walltime, pe_scaling, roofline_kernels,
                            table3, tile_autotune)
    print("name,us_per_call,derived")
    for mod in (roofline_kernels, copy_stencil, tile_autotune, pe_scaling,
                energy, table3, kernel_walltime, dycore_fused, dryrun_table):
        try:
            mod.run()
        except Exception as e:     # keep the suite going; record failure
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
