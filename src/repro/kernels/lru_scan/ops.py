"""Jitted entry point for the LRU sweep kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.lru_scan import ref as _ref
from repro.kernels.lru_scan.lru_scan import lru_scan_pallas


@functools.partial(jax.jit, static_argnames=("use_pallas", "tt", "tc",
                                             "interpret"))
def lru_scan(a, b, use_pallas: bool = False, tt: int = 32, tc: int = 128,
             interpret: bool = True):
    if use_pallas:
        return lru_scan_pallas(a, b, tt=tt, tc=tc, interpret=interpret)
    return _ref.lru_scan_ref(a, b)
