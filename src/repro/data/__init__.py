"""repro.data subpackage."""
