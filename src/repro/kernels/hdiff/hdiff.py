"""Pallas TPU kernel for the COSMO horizontal diffusion compound stencil.

NERO's hdiff PE streams a 3-D window from a dedicated HBM channel through
BRAM/URAM line buffers and computes laplace -> limited flux -> output as a
dataflow pipeline.  The TPU formulation:

  * grid = (nz, ny/ty): z is fully parallel (paper: "hdiff can be fully
    parallelized in the vertical dimension"); y is tiled into windows.
  * The y-halo (2 points) is realized with three aliased input refs
    (prev / cur / next window) — the Pallas idiom for overlapping windows;
    HBM->VMEM block transfers are double-buffered by the Pallas pipeline,
    which is exactly the paper's load/compute/store dataflow overlap.
  * x stays whole inside a window (the paper's windows also keep one axis
    whole per PE); lane dimension = x for VPU alignment.

Compute is fp32 internally; bf16 in/out supported (paper's half-precision
mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.kernels.hdiff.ref import DEFAULT_COEFF


def _hdiff_kernel(prev_ref, cur_ref, next_ref, out_ref, *, coeff: float,
                  ny: int, ty: int):
    j = pl.program_id(1)
    nx = cur_ref.shape[2]

    prev = prev_ref[0].astype(jnp.float32)     # (ty, nx)
    cur = cur_ref[0].astype(jnp.float32)
    nxt = next_ref[0].astype(jnp.float32)
    # Assemble the VMEM working window with a 2-row halo on each side.
    work = jnp.concatenate([prev[-2:], cur, nxt[:2]], axis=0)  # (ty+4, nx)

    def s(dj: int, di: int) -> jnp.ndarray:
        """Window shifted by (dj, di), cropped to the x-interior (halo 2)."""
        return work[2 + dj: 2 + dj + ty, 2 + di: nx - 2 + di]

    def lap(dj: int, di: int) -> jnp.ndarray:
        # true-Laplacian sign (see ref.py): Σ neighbors - 4·center
        return ((s(dj, di - 1) + s(dj, di + 1)
                 + s(dj - 1, di) + s(dj + 1, di))
                - 4.0 * s(dj, di))

    lap_c, lap_xp, lap_xm = lap(0, 0), lap(0, 1), lap(0, -1)
    lap_yp, lap_ym = lap(1, 0), lap(-1, 0)

    flx = lap_xp - lap_c
    flx_m = lap_c - lap_xm
    fly = lap_yp - lap_c
    fly_m = lap_c - lap_ym
    # COSMO flux limiter.
    flx = jnp.where(flx * (s(0, 1) - s(0, 0)) > 0.0, 0.0, flx)
    flx_m = jnp.where(flx_m * (s(0, 0) - s(0, -1)) > 0.0, 0.0, flx_m)
    fly = jnp.where(fly * (s(1, 0) - s(0, 0)) > 0.0, 0.0, fly)
    fly_m = jnp.where(fly_m * (s(0, 0) - s(-1, 0)) > 0.0, 0.0, fly_m)

    interior = s(0, 0) - coeff * ((flx - flx_m) + (fly - fly_m))

    # Rows outside [2, ny-2) pass through (global-boundary ring).
    row_ids = j * ty + jax.lax.broadcasted_iota(jnp.int32, (ty, 1), 0)
    valid = (row_ids >= 2) & (row_ids < ny - 2)
    center = work[2: 2 + ty, :]
    res = center.at[:, 2: nx - 2].set(
        jnp.where(valid, interior, center[:, 2: nx - 2]))
    out_ref[0] = res.astype(out_ref.dtype)


def _hdiff_kstep_kernel(prev_ref, cur_ref, next_ref, out_ref, *,
                        coeff: float, ny: int, ty: int, k_steps: int):
    j = pl.program_id(1)
    nx = cur_ref.shape[2]
    out_dtype = out_ref.dtype
    h = 3 * ty   # slab height: prev + cur + next windows

    slab = jnp.concatenate([prev_ref[0], cur_ref[0], next_ref[0]],
                           axis=0).astype(jnp.float32)       # (3*ty, nx)
    # Global row id of every slab row *as if* the neighbor windows were
    # not edge-clamped.  Clamp duplicates then get out-of-range ids, so
    # they are never recomputed, and the global passthrough ring (rows
    # 0,1 and ny-2,ny-1 — also never recomputed) keeps their stale values
    # from ever reaching a valid output row.
    row_ids = ((j - 1) * ty
               + jax.lax.broadcasted_iota(jnp.int32, (h, 1), 0))
    valid = (row_ids >= 2) & (row_ids < ny - 2)

    def step(_, w):
        def s(dj: int, di: int) -> jnp.ndarray:
            return w[2 + dj: h - 2 + dj, 2 + di: nx - 2 + di]

        def lap(dj: int, di: int) -> jnp.ndarray:
            return ((s(dj, di - 1) + s(dj, di + 1)
                     + s(dj - 1, di) + s(dj + 1, di))
                    - 4.0 * s(dj, di))

        lap_c, lap_xp, lap_xm = lap(0, 0), lap(0, 1), lap(0, -1)
        lap_yp, lap_ym = lap(1, 0), lap(-1, 0)
        flx = lap_xp - lap_c
        flx_m = lap_c - lap_xm
        fly = lap_yp - lap_c
        fly_m = lap_c - lap_ym
        flx = jnp.where(flx * (s(0, 1) - s(0, 0)) > 0.0, 0.0, flx)
        flx_m = jnp.where(flx_m * (s(0, 0) - s(0, -1)) > 0.0, 0.0, flx_m)
        fly = jnp.where(fly * (s(1, 0) - s(0, 0)) > 0.0, 0.0, fly)
        fly_m = jnp.where(fly_m * (s(0, 0) - s(-1, 0)) > 0.0, 0.0, fly_m)
        interior = s(0, 0) - coeff * ((flx - flx_m) + (fly - fly_m))

        w = w.at[2: h - 2, 2: nx - 2].set(
            jnp.where(valid[2: h - 2], interior, w[2: h - 2, 2: nx - 2]))
        # Round-trip through the storage dtype so each in-kernel step
        # rounds exactly like a separate launch (bit-equal ragged tails).
        return w.astype(out_dtype).astype(jnp.float32)

    slab = jax.lax.fori_loop(0, k_steps, step, slab)
    out_ref[0] = slab[ty: 2 * ty].astype(out_dtype)


def hdiff_kstep_pallas(src: jnp.ndarray, coeff: float = DEFAULT_COEFF,
                       ty: int = 8, k_steps: int = 1,
                       interpret: bool = False) -> jnp.ndarray:
    """In-kernel k-step hdiff: ONE launch applies `k_steps` rounds.

    src: (nz, ny, nx), ny % ty == 0, ty >= max(2, 2*k_steps) — each step
    shrinks the slab's valid interior by 2 rows per side, so the written
    center window (rows [ty, 2*ty)) stays step-correct through all k.
    """
    nz, ny, nx = src.shape
    k_steps = int(k_steps)
    if k_steps < 1:
        raise ValueError(f"k_steps={k_steps} must be >= 1")
    lo = max(2, 2 * k_steps)
    if ny % ty or ty < lo:
        raise ValueError(
            f"ny={ny} must be divisible by ty={ty} >= max(2, 2*k)={lo}")
    nyb = ny // ty

    spec = functools.partial(pl.BlockSpec, (1, ty, nx))
    in_specs = [
        spec(lambda k, j: (k, jnp.maximum(j - 1, 0), 0)),          # prev
        spec(lambda k, j: (k, j, 0)),                              # cur
        spec(lambda k, j: (k, jnp.minimum(j + 1, nyb - 1), 0)),    # next
    ]
    out_spec = spec(lambda k, j: (k, j, 0))

    kernel = functools.partial(_hdiff_kstep_kernel, coeff=coeff, ny=ny,
                               ty=ty, k_steps=k_steps)
    fn = pl.pallas_call(
        kernel,
        grid=(nz, nyb),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_hdiff_kstep",
    )
    return fn(src, src, src)


def hdiff_pallas(src: jnp.ndarray, coeff: float = DEFAULT_COEFF,
                 ty: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Tiled compound hdiff.  src: (nz, ny, nx), ny % ty == 0, ty >= 2."""
    nz, ny, nx = src.shape
    if ny % ty or ty < 2:
        raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 2")
    nyb = ny // ty

    spec = functools.partial(pl.BlockSpec, (1, ty, nx))
    in_specs = [
        spec(lambda k, j: (k, jnp.maximum(j - 1, 0), 0)),          # prev
        spec(lambda k, j: (k, j, 0)),                              # cur
        spec(lambda k, j: (k, jnp.minimum(j + 1, nyb - 1), 0)),    # next
    ]
    out_spec = spec(lambda k, j: (k, j, 0))

    kernel = functools.partial(_hdiff_kernel, coeff=coeff, ny=ny, ty=ty)
    fn = pl.pallas_call(
        kernel,
        grid=(nz, nyb),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_hdiff",
    )
    return fn(src, src, src)
