"""Pallas TPU kernel for the first-order linear recurrence (RG-LRU sweep).

NERO's vadvc PE design transplanted to the time axis: channels are the
parallel "columns" (each grid column block is a PE with its own HBM
stream), time is the sequential sweep.  The running state h lives in VMEM
scratch and persists across the sequential grid axis — the Pallas idiom for
carry-over-grid (TPU grids execute sequentially over the last dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _lru_kernel(a_ref, b_ref, out_ref, h_ref, *, tt: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)       # (tt, tc)
    b = b_ref[...].astype(jnp.float32)

    def body(i, h):
        h = a[i] * h + b[i]
        out_ref[pl.ds(i, 1), :] = h[None].astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, tt, body, h_ref[0])
    h_ref[...] = h[None]


def lru_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, tt: int = 32,
                    tc: int = 128, interpret: bool = False) -> jnp.ndarray:
    """a, b: (T, C); T % tt == 0, C % tc == 0."""
    t, c = a.shape
    if t % tt or c % tc:
        raise ValueError(f"(T={t}, C={c}) must tile by (tt={tt}, tc={tc})")
    spec = pl.BlockSpec((tt, tc), lambda ci, ti: (ti, ci))
    fn = pl.pallas_call(
        functools.partial(_lru_kernel, tt=tt),
        grid=(c // tc, t // tt),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((1, tc), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_lru_scan",
    )
    return fn(a, b)
