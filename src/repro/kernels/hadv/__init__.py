"""NERO kernel package: hadv_upwind (horizontal advection, upwind flux)."""
