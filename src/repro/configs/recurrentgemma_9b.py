"""RecurrentGemma-9B — Griffin: RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427; unverified]."""

from repro.configs.base import ModelConfig, RecurrentConfig

# 38 layers = 12 x (rec, rec, attn) + 2 rec remainder.
CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    pattern=("rec", "rec", "attn"),
    window=2048, rope_theta=1e4,
    norm="rms", gated_mlp=True, act="gelu",
    tie_embeddings=True,
    rec=RecurrentConfig(rnn_width=4096, conv_width=4),
)
