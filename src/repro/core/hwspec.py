"""Declarative hardware specs: model any machine, tune on the real one.

The paper's headline result is a CROSS-MACHINE comparison — NERO (an
XCVU37P + HBM2 dataflow fabric over OCAPI) against a 16-core POWER9 —
and the whole memmodel/perfmodel/roofline stack used to hard-code one
machine's constants in `core/hierarchy.py`.  This module makes the
machine an input: a frozen `HardwareSpec` loaded from versioned JSON
under `src/repro/specs/` (`tpu_v5e.json`, `power9.json`,
`nero_ad9h7.json`), schema-validated with errors that NAME the bad
field, and content-fingerprinted so every modeled or measured number
can record exactly which machine description produced it.

A spec carries:

* the memory hierarchy (`main` → `near` → `reg` roles; each level's
  capacity, bandwidth, and pJ/byte) — NERO's HBM→URAM/BRAM→FF chain,
  POWER9's DRAM→L3→L1, the TPU's HBM→VMEM→VREG;
* peak FLOP/s by dtype, idle/peak watts, pJ/flop;
* the collective link (latency, bandwidth, links, pJ/byte) — ICI on
  TPU, the OCAPI link on the AD9H7 card;
* per-KERNEL-CLASS sustained models (`kernel_classes`): the fraction
  of peak main-memory bandwidth a class of kernels actually sustains,
  and optionally a measured wall-power figure.  Classes are derived
  from the op's declared structure — `"solver"` for ops with a
  sequential axis (vadvc's z-sweep Thomas solve), `"streaming"`
  otherwise (hdiff) — because that structural split is exactly what
  separates the paper's two kernels on both machines: POWER9 sustains
  ~21% of STREAM bandwidth on either compound stencil, while NERO
  streams hdiff near its HBM roof but pays for vadvc's z-dependency
  with a shallower pipeline and a larger, hotter design;
* an execution-fidelity block (`jax_backend`, `interpret_fidelity`)
  that makes ROADMAP's interpreter caveat machine-readable: walltimes
  are trustworthy only when measured on the spec's native backend.

`hierarchy.py` is now a thin shim over the default spec; `perfmodel`,
`roofline`, `memmodel`, and `autotune` all accept a `spec=` argument.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import Any, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

__all__ = ["SpecValidationError", "MemoryLevel", "Hierarchy",
           "KernelClassModel", "Collective", "HardwareSpec",
           "dtype_bytes", "spec_dir", "available_specs", "load_spec",
           "spec_from_dict", "default_spec_name", "default_spec",
           "execution_fidelity", "KERNEL_CLASSES", "kernel_class_name"]

# Where the versioned spec JSONs live: src/repro/specs/.
_SPEC_DIR = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 os.pardir, "specs"))

# The two kernel classes the sustained models are keyed on (see module
# docstring for why the split is structural, not per-op).
KERNEL_CLASSES = ("streaming", "solver")

_ROLES = ("main", "near", "reg")


class SpecValidationError(ValueError):
    """A hardware-spec JSON failed schema validation; the message names
    the offending field (dotted path) and what was wrong with it."""


def dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One level of the near-memory hierarchy."""

    name: str
    capacity_bytes: int
    bandwidth_bytes_per_s: float
    energy_pj_per_byte: float

    def seconds_for(self, nbytes: int) -> float:
        return nbytes / self.bandwidth_bytes_per_s

    def energy_joules_for(self, nbytes: int) -> float:
        return nbytes * self.energy_pj_per_byte * 1e-12


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """The full per-chip hierarchy, NERO-style: far memory feeds near
    memory feeds registers; the planner places tiles at the deepest
    level that fits.  Field names keep the TPU spelling (`hbm`/`vmem`/
    `vreg`) for every consumer; a spec's `main`/`near`/`reg` levels map
    onto them regardless of what the machine calls its memories."""

    hbm: MemoryLevel
    vmem: MemoryLevel
    vreg: MemoryLevel
    peak_flops_bf16: float = 197e12
    peak_flops_fp32: float = 197e12 / 4.0
    ici_bw: float = 50e9

    def level_for(self, nbytes: int) -> MemoryLevel:
        """Deepest (fastest) level whose capacity holds `nbytes` (the
        paper's greedy placement: URAM/BRAM if it fits, else HBM)."""
        if nbytes <= self.vreg.capacity_bytes:
            return self.vreg
        if nbytes <= self.vmem.capacity_bytes:
            return self.vmem
        return self.hbm

    def machine_balance(self, dtype=jnp.bfloat16) -> float:
        """FLOP:byte ratio at which compute and main-memory time are
        equal — the roofline ridge point (paper Fig. 1)."""
        peak = (self.peak_flops_bf16
                if jnp.dtype(dtype).itemsize <= 2 else self.peak_flops_fp32)
        return peak / self.hbm.bandwidth_bytes_per_s


@dataclasses.dataclass(frozen=True)
class KernelClassModel:
    """Sustained-efficiency model for one kernel class on one machine.

    `bw_utilization` derates peak main-memory bandwidth to what this
    class of kernels actually sustains (the gap between STREAM and a
    compound stencil's irregular access).  `compute_utilization`
    derates peak FLOP/s.  `watts`, when given, is the MEASURED
    sustained wall power for this class (the paper power-measured each
    kernel; NERO's vadvc design draws ~96 W to hdiff's ~35 W) and
    replaces the bottom-up traffic-energy estimate."""

    bw_utilization: float
    compute_utilization: float
    watts: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Collective:
    """The inter-device (or accelerator-to-host) link."""

    latency_s: float
    bandwidth_bytes_per_s: float
    links: int = 1
    energy_pj_per_byte: float = 0.0


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """A frozen, fingerprinted machine description (see module doc)."""

    name: str
    title: str
    source: str
    schema_version: int
    jax_backend: Optional[str]
    interpret_fidelity: bool
    main: MemoryLevel
    near: MemoryLevel
    reg: MemoryLevel
    peak_flops: Mapping[str, float]
    idle_watts: float
    peak_watts: float
    energy_pj_per_flop: float
    collective: Collective
    kernel_classes: Mapping[str, KernelClassModel]
    reference_points: Mapping[str, Mapping[str, float]]
    layout: Mapping[str, Tuple[int, ...]]
    near_physical_bytes: int
    host_energy_pj_per_byte: float
    fingerprint: str

    # -- derived views -------------------------------------------------------
    def hierarchy(self) -> Hierarchy:
        """This spec as the planner/perfmodel `Hierarchy` view."""
        return Hierarchy(
            hbm=self.main, vmem=self.near, vreg=self.reg,
            peak_flops_bf16=self.peak_flops["bfloat16"],
            peak_flops_fp32=self.peak_flops["float32"],
            ici_bw=self.collective.bandwidth_bytes_per_s)

    def peak_flops_for(self, dtype) -> float:
        key = str(jnp.dtype(dtype))
        if key in self.peak_flops:
            return self.peak_flops[key]
        return (self.peak_flops["bfloat16"]
                if jnp.dtype(dtype).itemsize <= 2
                else self.peak_flops["float32"])

    def kernel_class(self, op) -> KernelClassModel:
        """The sustained model for a `tiling.OpSpec` (or class name)."""
        return self.kernel_classes[kernel_class_name(op)]

    def describe(self) -> Dict[str, Any]:
        """Short JSON-serializable identity block for artifacts."""
        return {"name": self.name, "fingerprint": self.fingerprint,
                "title": self.title, "jax_backend": self.jax_backend,
                "interpret_fidelity": self.interpret_fidelity}


def kernel_class_name(op) -> str:
    """`"solver"` for ops with a sequential axis, else `"streaming"` —
    the structural split between the paper's two kernels.  Accepts a
    `tiling.OpSpec`-shaped object or a class name."""
    if isinstance(op, str):
        if op not in KERNEL_CLASSES:
            raise KeyError(f"unknown kernel class {op!r}; expected one of "
                           f"{KERNEL_CLASSES}")
        return op
    return "solver" if getattr(op, "seq_axes", ()) else "streaming"


# ---------------------------------------------------------------------------
# Schema validation (hand-rolled: no jsonschema dependency; every error
# names the bad field as a dotted path)
# ---------------------------------------------------------------------------


def _fail(where: str, field: str, why: str) -> None:
    raise SpecValidationError(f"{where}: field {field!r} {why}")


def _need(d: Mapping, field: str, where: str, types, *,
          positive: bool = False, nonneg: bool = False,
          unit_interval: bool = False):
    path = field
    cur: Any = d
    for part in field.split("."):
        if not isinstance(cur, Mapping) or part not in cur:
            _fail(where, path, "is missing")
        cur = cur[part]
    if types is bool:
        if not isinstance(cur, bool):
            _fail(where, path, f"must be a bool, got {type(cur).__name__}")
        return cur
    if not isinstance(cur, types) or isinstance(cur, bool):
        _fail(where, path, f"must be {getattr(types, '__name__', types)}, "
                           f"got {type(cur).__name__}")
    if isinstance(cur, (int, float)):
        if not math.isfinite(cur):
            _fail(where, path, f"must be finite, got {cur!r}")
        if positive and cur <= 0:
            _fail(where, path, f"must be > 0, got {cur!r}")
        if nonneg and cur < 0:
            _fail(where, path, f"must be >= 0, got {cur!r}")
        if unit_interval and not 0 < cur <= 1:
            _fail(where, path, f"must be in (0, 1], got {cur!r}")
    return cur


def _parse_level(entry: Mapping, where: str, path: str) -> MemoryLevel:
    if not isinstance(entry, Mapping):
        _fail(where, path, "must be an object")
    name = _need(entry, "name", where, str)
    cap = _need(entry, "capacity_bytes", where, (int, float), positive=True)
    bw = _need(entry, "bandwidth_bytes_per_s", where, (int, float),
               positive=True)
    pj = _need(entry, "energy_pj_per_byte", where, (int, float), nonneg=True)
    return MemoryLevel(name=name, capacity_bytes=int(cap),
                       bandwidth_bytes_per_s=float(bw),
                       energy_pj_per_byte=float(pj))


def spec_from_dict(d: Mapping[str, Any],
                   where: str = "<dict>") -> HardwareSpec:
    """Validate a raw spec dict and freeze it into a `HardwareSpec`.

    Raises `SpecValidationError` naming the first bad field (dotted
    path) — `tests/test_hwspec.py` pins the naming."""
    if not isinstance(d, Mapping):
        raise SpecValidationError(f"{where}: spec must be a JSON object, "
                                  f"got {type(d).__name__}")
    version = _need(d, "schema_version", where, int)
    if version != 1:
        _fail(where, "schema_version", f"must be 1, got {version!r}")
    name = _need(d, "name", where, str)
    title = _need(d, "title", where, str)
    source = _need(d, "source", where, str)
    backend = d.get("jax_backend", None)
    if backend is not None and not isinstance(backend, str):
        _fail(where, "jax_backend", "must be a string or null")
    fidelity = _need(d, "interpret_fidelity", where, bool)

    levels_raw = _need(d, "memory_levels", where, (list, tuple))
    by_role: Dict[str, MemoryLevel] = {}
    near_physical = None
    for i, entry in enumerate(levels_raw):
        path = f"memory_levels[{i}]"
        if not isinstance(entry, Mapping):
            _fail(where, path, "must be an object")
        role = _need(entry, "role", where, str)
        if role not in _ROLES:
            _fail(where, f"{path}.role",
                  f"must be one of {_ROLES}, got {role!r}")
        if role in by_role:
            _fail(where, f"{path}.role", f"duplicates role {role!r}")
        by_role[role] = _parse_level(entry, where, path)
        if role == "near" and "physical_capacity_bytes" in entry:
            near_physical = int(_need(entry, "physical_capacity_bytes",
                                      where, (int, float), positive=True))
    for role in _ROLES:
        if role not in by_role:
            _fail(where, "memory_levels",
                  f"must define a level with role {role!r}")
    if near_physical is None:
        near_physical = by_role["near"].capacity_bytes

    peaks_raw = _need(d, "peak_flops", where, Mapping)
    for key in ("bfloat16", "float32"):
        _need(d, f"peak_flops.{key}", where, (int, float), positive=True)
    peaks = {str(k): float(v) for k, v in peaks_raw.items()}

    idle = float(_need(d, "idle_watts", where, (int, float), nonneg=True))
    peakw = float(_need(d, "peak_watts", where, (int, float), positive=True))
    if idle > peakw:
        _fail(where, "idle_watts", f"must be <= peak_watts ({peakw}), "
                                   f"got {idle}")
    pj_flop = float(_need(d, "energy_pj_per_flop", where, (int, float),
                          nonneg=True))

    coll = Collective(
        latency_s=float(_need(d, "collective.latency_s", where,
                              (int, float), nonneg=True)),
        bandwidth_bytes_per_s=float(_need(
            d, "collective.bandwidth_bytes_per_s", where, (int, float),
            positive=True)),
        links=int(_need(d, "collective.links", where, int, positive=True)),
        energy_pj_per_byte=float(_need(
            d, "collective.energy_pj_per_byte", where, (int, float),
            nonneg=True)))

    classes: Dict[str, KernelClassModel] = {}
    _need(d, "kernel_classes", where, Mapping)
    for cls in KERNEL_CLASSES:
        bw_u = _need(d, f"kernel_classes.{cls}.bw_utilization", where,
                     (int, float), unit_interval=True)
        cu = _need(d, f"kernel_classes.{cls}.compute_utilization", where,
                   (int, float), unit_interval=True)
        watts = d["kernel_classes"][cls].get("watts", None)
        if watts is not None and (not isinstance(watts, (int, float))
                                  or isinstance(watts, bool) or watts <= 0):
            _fail(where, f"kernel_classes.{cls}.watts",
                  f"must be a positive number or null, got {watts!r}")
        classes[cls] = KernelClassModel(
            bw_utilization=float(bw_u), compute_utilization=float(cu),
            watts=None if watts is None else float(watts))

    refs_raw = d.get("reference_points", {})
    if not isinstance(refs_raw, Mapping):
        _fail(where, "reference_points", "must be an object")
    refs: Dict[str, Dict[str, float]] = {}
    for kname, entry in refs_raw.items():
        if not isinstance(entry, Mapping):
            _fail(where, f"reference_points.{kname}", "must be an object")
        refs[str(kname)] = {str(k): float(v) for k, v in entry.items()}

    layout_raw = d.get("layout", {})
    if not isinstance(layout_raw, Mapping):
        _fail(where, "layout", "must be an object")
    layout = {str(k): tuple(int(x) for x in v)
              for k, v in layout_raw.items()}

    host_pj = d.get("host_energy_pj_per_byte", 0.0)
    if not isinstance(host_pj, (int, float)) or isinstance(host_pj, bool):
        _fail(where, "host_energy_pj_per_byte", "must be a number")

    fingerprint = hashlib.sha256(
        json.dumps(d, sort_keys=True, separators=(",", ":"),
                   default=str).encode()).hexdigest()[:12]

    return HardwareSpec(
        name=name, title=title, source=source, schema_version=version,
        jax_backend=backend, interpret_fidelity=fidelity,
        main=by_role["main"], near=by_role["near"], reg=by_role["reg"],
        peak_flops=peaks, idle_watts=idle, peak_watts=peakw,
        energy_pj_per_flop=pj_flop, collective=coll,
        kernel_classes=classes, reference_points=refs, layout=layout,
        near_physical_bytes=near_physical,
        host_energy_pj_per_byte=float(host_pj), fingerprint=fingerprint)


# ---------------------------------------------------------------------------
# Loading
# ---------------------------------------------------------------------------

_CACHE: Dict[Tuple[str, str], HardwareSpec] = {}


def spec_dir() -> str:
    return _SPEC_DIR


def available_specs(directory: Optional[str] = None) -> Tuple[str, ...]:
    """Names of every spec JSON shipped under `src/repro/specs/`."""
    directory = directory or _SPEC_DIR
    return tuple(sorted(
        fn[:-len(".json")] for fn in os.listdir(directory)
        if fn.endswith(".json")))


def load_spec(name: str, directory: Optional[str] = None) -> HardwareSpec:
    """Load + validate + fingerprint the named spec (cached)."""
    directory = directory or _SPEC_DIR
    key = (directory, name)
    spec = _CACHE.get(key)
    if spec is not None:
        return spec
    path = os.path.join(directory, f"{name}.json")
    if not os.path.exists(path):
        raise KeyError(f"unknown hardware spec {name!r}; available: "
                       f"{available_specs(directory)}")
    with open(path) as fh:
        try:
            raw = json.load(fh)
        except json.JSONDecodeError as e:
            raise SpecValidationError(f"{path}: not valid JSON: {e}") from e
    spec = spec_from_dict(raw, where=os.path.basename(path))
    if spec.name != name:
        raise SpecValidationError(
            f"{path}: field 'name' must match the file stem {name!r}, "
            f"got {spec.name!r}")
    _CACHE[key] = spec
    return spec


def default_spec_name() -> str:
    """The session's default MODELING target — `REPRO_HWSPEC` (env) or
    the TPU v5e the kernels are written for."""
    return os.environ.get("REPRO_HWSPEC", "tpu_v5e")


def default_spec() -> HardwareSpec:
    return load_spec(default_spec_name())


def execution_fidelity(spec: Optional[HardwareSpec] = None
                       ) -> Dict[str, Any]:
    """ROADMAP's interpreter caveat, machine-readable: which backend this
    process executes on, whether Pallas runs interpreted, which spec the
    modeled numbers target, and whether measured WALLTIMES can be
    trusted as that machine's (only when the backend is the spec's
    native one, and — interpreted — only if the spec says the
    interpreter is faithful).  Benchmarks stamp this block on every
    `BENCH_*.json`; bench-smoke refuses artifacts whose fingerprint
    does not match the shipped spec."""
    import jax

    spec = spec or default_spec()
    backend = jax.default_backend()
    interpret = backend != "tpu"
    trustworthy = (spec.jax_backend == backend
                   and (not interpret or spec.interpret_fidelity))
    return {"backend": backend, "interpret": interpret,
            "spec": spec.name, "spec_fingerprint": spec.fingerprint,
            "walltime_trustworthy": bool(trustworthy)}
