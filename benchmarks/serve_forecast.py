"""Forecast-as-a-service under synthetic open-loop load.

Drives `repro.serve.forecast.ForecastEngine` the way a deployment would:
requests for a small catalog of stencil programs arrive on a seeded
Poisson clock (exponential interarrivals, open-loop — arrivals do NOT
wait for completions), each carrying its own initial conditions and step
count; the engine folds them into the ensemble axis of per-program cached
plans and retires them at round boundaries.

Reported metrics (docs/benchmarks.md, "BENCH_serve.json"):
  serve_forecast/latency_p50        us, admit -> result on host, p50
  serve_forecast/latency_p99        us, ditto p99 (tail = queueing)
  serve_forecast/steps_per_s_mean   per-request forecast throughput
  serve_forecast/occupancy          mean busy-slot fraction per round
  serve_forecast/cache_hit_rate     plan-cache hits / requests

Plus the supervision overhead and recovery numbers (ISSUE 7):
  serve_forecast/guard_overhead     validity-guard walltime / round
                                    walltime on a service-scale grid
  serve_forecast/recovery_rounds    rounds the chaos engine kept serving
                                    after its first injected fault

Also writes BENCH_serve.json: the latency distribution, per-request
steps/s, batch occupancy, plan-cache hit statistics, the program catalog,
the load spec, a `robustness` block (guard overhead + a deterministic
chaos segment: one poisoned request, one device loss, one forced lowering
fallback), and a `failover` block (ISSUE 8: a kill-a-device run on a
forced-4-device subprocess — recovery rounds, requests preserved across
the mesh rebuild, reshard wall time, and whether every preserved request
stayed bit-identical to a solo run on the original mesh) — everything
the CI smoke job asserts on and cross-PR perf diffs read.  BENCH_SMOKE=1
shrinks the request count and slot pool.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, smoke_mode, write_json
from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram

# The served catalog: three programs a real mesoscale service would mix —
# the fused compound step at two precisions plus a diffusion-only product.
_CATALOG = (
    StencilProgram(grid_shape=(4, 16, 16), op="dycore"),
    StencilProgram(grid_shape=(4, 16, 16), op="dycore", dtype="bfloat16"),
    StencilProgram(grid_shape=(3, 8, 8), op="hdiff"),
)


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _drive(eng: ForecastEngine, requests, arrivals):
    """Open-loop load: submit each request at its scheduled arrival time
    (whether or not the engine kept up), pump between arrivals."""
    t0 = time.perf_counter()
    pending = list(zip(arrivals, requests))
    while pending or eng.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending[0][1])
            pending.pop(0)
        busy = eng.pump()
        if not busy and pending:
            # idle until the next arrival; open-loop clients don't block
            time.sleep(max(0.0, pending[0][0]
                           - (time.perf_counter() - t0)))
    return eng.drain()


def _median_s(f, n):
    jax.block_until_ready(f())                   # warm (compile + caches)
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _guard_overhead(smoke: bool) -> dict:
    """Validity-guard cost as a fraction of round walltime, on a
    service-scale grid (the smoke catalog's toy grids are dispatch-bound,
    which would measure launch overhead, not the guard)."""
    grid, slots = (8, 48, 48), 4
    key = wprog.plan_cache_key(StencilProgram(grid_shape=grid, op="dycore"),
                               ensemble=slots)
    plan = wprog.compile(key)
    batch = fields.initial_state(jax.random.PRNGKey(7), grid,
                                 ensemble=slots)
    n = 3 if smoke else 7
    round_s = _median_s(lambda: plan.step(batch), n)
    guard_s = _median_s(lambda: wprog.slot_validity(batch, 1e6), n)
    return {"grid": list(grid), "slots": slots,
            "round_us": round_s * 1e6, "guard_us": guard_s * 1e6,
            "guard_overhead_frac": guard_s / round_s}


def _chaos_segment(slots: int) -> dict:
    """A deterministic supervised run: one poisoned request, one injected
    device loss, one forced lowering fallback — reports what the engine
    absorbed and how many rounds it kept serving past the first fault."""
    inj = FaultInjector([
        FaultSpec(kind="compile_fail", op="hdiff", attempt="native"),
        FaultSpec(kind="poison_nan", round=1),
        FaultSpec(kind="device_loss", round=2),
    ], seed=7)
    eng = ForecastEngine(slots=slots, retry_backoff_s=0.0,
                         fault_injector=inj)
    n = 6
    for i in range(n):
        prog = _CATALOG[i % len(_CATALOG)]
        state = fields.initial_state(jax.random.PRNGKey(2000 + i),
                                     prog.grid_shape, ensemble=1,
                                     dtype=prog.dtype)
        eng.submit(ForecastRequest(program=prog, state=state, steps=4))
    results = eng.drain()
    assert len(results) == n and not eng.has_work()
    stats = eng.stats()
    fault_rounds = [e["round"] for e in inj.log if "round" in e]
    recovery = (stats["rounds"] - min(fault_rounds)) if fault_rounds else 0
    return {"requests": n,
            "statuses": {s: sum(1 for r in results.values()
                                if r.status == s)
                         for s in ("ok", "failed", "expired")},
            "quarantined": stats["quarantined"],
            "round_retries": stats["round_retries"],
            "fallback_compiles": stats["fallback_compiles"],
            "lane_failures": stats["lane_failures"],
            "recovery_rounds": recovery,
            "faults_fired": inj.fired()}


_FAILOVER_SNIPPET = r"""
import json, time
import numpy as np, jax
from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import domain, fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram

kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
grid = (4, 16, 16)
prog = StencilProgram(grid_shape=grid, ensemble=1)
states = [fields.initial_state(jax.random.PRNGKey(s), grid, ensemble=1)
          for s in (0, 1, 2)]
steps = (5, 3, 4)
solo = wprog.compile(prog, mesh=mesh)
refs = [solo.run(domain.shard_state(s, mesh, solo.state_spec), n)
        for s, n in zip(states, steps)]

inj = FaultInjector([FaultSpec(kind="device_loss", round=1, device=3,
                               once=False)])
eng = ForecastEngine(slots=2, mesh=mesh, fault_injector=inj,
                     max_round_retries=1, retry_backoff_s=0.01)
t0 = time.perf_counter()
rids = [eng.submit(ForecastRequest(program=prog, state=s, steps=n))
        for s, n in zip(states, steps)]
res = eng.drain()
wall = time.perf_counter() - t0
st = eng.stats()
bitwise = all(
    res[rid].status == "ok"
    and all(np.array_equal(np.asarray(res[rid].state.fields[n]),
                           np.asarray(ref.fields[n]))
            for n in prog.fields)
    for rid, ref in zip(rids, refs))
fo = st["failovers"][0] if st["failovers"] else {}
print("FAILOVER_JSON " + json.dumps({
    "mesh_failovers": st["mesh_failovers"],
    "recovery_rounds": st["recovery_rounds"],
    "requests_preserved": st["requests_preserved"],
    "lane_failures": st["lane_failures"],
    "reshard_ms": fo.get("reshard_ms"),
    "lost_device": fo.get("lost_device"),
    "from_shape": fo.get("from_shape"),
    "to_shape": fo.get("to_shape"),
    "drain_wall_s": wall,
    "all_ok": all(res[r].status == "ok" for r in rids),
    "bitwise_vs_original_mesh": bool(bitwise),
}))
"""


def _failover_segment() -> dict:
    """Kill-a-device chaos on a forced-4-device subprocess (the main
    bench process pins a single CPU device, so the mesh run needs its own
    interpreter): device 3 dies persistently at round 1, the engine
    rebuilds 2x2 -> 2x1 and preserves every in-flight request.  Reports
    the recovery accounting BENCH_serve.json's `failover` block carries
    and CI asserts on."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _FAILOVER_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=600)
    for line in r.stdout.splitlines():
        if line.startswith("FAILOVER_JSON "):
            return json.loads(line[len("FAILOVER_JSON "):])
    raise RuntimeError(f"failover segment produced no report: "
                       f"{r.stderr[-2000:]}")


def run() -> None:
    smoke = smoke_mode()
    slots = 2 if smoke else 4
    n_requests = 8 if smoke else 32
    mean_interarrival_s = 0.05 if smoke else 0.1

    rng = np.random.default_rng(42)
    arrivals = np.cumsum(rng.exponential(mean_interarrival_s,
                                         size=n_requests))
    steps = rng.integers(1, 5 if smoke else 13, size=n_requests)
    progs = [_CATALOG[i % len(_CATALOG)] for i in range(n_requests)]
    requests = []
    for i, (prog, s) in enumerate(zip(progs, steps)):
        state = fields.initial_state(jax.random.PRNGKey(1000 + i),
                                     prog.grid_shape, ensemble=1,
                                     dtype=prog.dtype)
        requests.append(ForecastRequest(program=prog, state=state,
                                        steps=int(s)))

    eng = ForecastEngine(slots=slots)
    results = _drive(eng, requests, arrivals)
    assert len(results) == n_requests, (len(results), n_requests)
    stats = eng.stats()

    lat = [r.latency_s for r in results.values()]
    sps = [r.steps / r.latency_s for r in results.values()
           if r.latency_s > 0]
    p50, p99 = _percentile(lat, 50), _percentile(lat, 99)
    emit("serve_forecast/latency_p50", p50 * 1e6,
         f"n={n_requests} slots={slots}")
    emit("serve_forecast/latency_p99", p99 * 1e6, "tail=queueing")
    emit("serve_forecast/steps_per_s_mean", float(np.mean(sps)),
         "per-request forecast throughput")
    emit("serve_forecast/occupancy", stats["occupancy"],
         "busy-slot fraction per lane-round")
    cache = {"hits": stats["plan_cache_hits"],
             "misses": stats["plan_cache_misses"],
             "hit_rate": stats["plan_cache_hit_rate"]}
    emit("serve_forecast/cache_hit_rate", cache["hit_rate"],
         f"{len(_CATALOG)} programs, {cache['misses']} compiles")

    guard = _guard_overhead(smoke)
    chaos = _chaos_segment(slots)
    failover = _failover_segment()
    emit("serve_forecast/guard_overhead", guard["guard_overhead_frac"],
         f"guard {guard['guard_us']:.0f}us / round "
         f"{guard['round_us']:.0f}us on {tuple(guard['grid'])}")
    emit("serve_forecast/recovery_rounds", chaos["recovery_rounds"],
         f"{chaos['faults_fired']} faults, "
         f"{chaos['quarantined']} quarantined")
    emit("serve_forecast/failover_reshard_ms", failover["reshard_ms"],
         f"{failover['from_shape']}->{failover['to_shape']}, "
         f"{failover['requests_preserved']} requests preserved")

    write_json("BENCH_serve.json", {
        "slots": slots,
        "n_requests": n_requests,
        "n_programs": len(_CATALOG),
        "latency_s": {"p50": p50, "p99": p99,
                      "mean": float(np.mean(lat)),
                      "max": float(np.max(lat))},
        "steps_per_s_per_request": {"mean": float(np.mean(sps)),
                                    "p50": _percentile(sps, 50),
                                    "min": float(np.min(sps))},
        "occupancy": stats["occupancy"],
        "plan_cache": cache,
        "robustness": {**guard, **chaos},
        "failover": failover,
        "programs": [p.to_json() for p in _CATALOG],
        "load": {"model": "open-loop poisson", "seed": 42,
                 "mean_interarrival_s": mean_interarrival_s,
                 "steps_min": 1,
                 "steps_max": int(steps.max())},
    })


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
