"""jax version-compatibility shims (this container ships jax 0.4.x).

Kernel-local Pallas shims live in repro.kernels.compat (CompilerParams);
this module holds the cross-cutting ones.  Mesh axis_types guarding lives
in repro.launch.mesh.make_mesh.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):            # jax >= 0.5

    def shard_map(f, mesh, in_specs, out_specs):
        """shard_map without replication checking, either jax spelling."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:                                    # 0.4.x spelling
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, mesh, in_specs, out_specs):
        """shard_map without replication checking, either jax spelling."""
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)

__all__ = ["shard_map"]
