"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (model-derived values labeled in
the derived column; this container is CPU-only so TPU numbers are
dry-run/model projections, wall-clock numbers are real).

Machine-readable output: individual modules write their own
``BENCH_*.json`` artifacts (``dycore_fused`` writes ``BENCH_dycore.json``);
this driver additionally dumps every emitted CSV row to ``BENCH_run.json``
so the full perf trajectory is diffable across PRs.  ``BENCH_DIR`` picks
the output directory; ``BENCH_SMOKE=1`` shrinks grids/iters for the CI
smoke job (see .github/workflows/ci.yml)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (common, copy_stencil, dryrun_table, dycore_fused,
                            energy, kernel_walltime, pe_scaling,
                            roofline_kernels, serve_forecast, table3,
                            tile_autotune)
    print("name,us_per_call,derived")
    failures = []
    for mod in (roofline_kernels, copy_stencil, tile_autotune, pe_scaling,
                energy, table3, kernel_walltime, dycore_fused, dryrun_table,
                serve_forecast):
        try:
            mod.run()
        except Exception as e:     # keep the suite going; record failure
            failures.append(f"{mod.__name__}: {type(e).__name__}: {e}")
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    common.write_json("BENCH_run.json", {"rows": common.records(),
                                         "errors": failures})
    if failures:   # fail the process so the CI smoke job goes red
        sys.exit(f"{len(failures)} benchmark module(s) failed: {failures}")


if __name__ == "__main__":
    main()
