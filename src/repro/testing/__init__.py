"""repro.testing subpackage: deterministic fault injection for chaos tests.

`faults` is the seedable fault-injection harness the supervised serving
stack (`serve/forecast.py`) and the CI chaos job drive — NaN/Inf slot
poisoning, simulated compile-lowering failures, mid-round device loss,
and checkpoint file corruption (see docs/robustness.md).
"""

from repro.testing.faults import (FaultInjector, FaultSpec, InjectedFault,
                                  InjectedCompileError, InjectedDeviceLoss,
                                  bitflip_file, corrupt_checkpoint,
                                  truncate_file)

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault",
           "InjectedCompileError", "InjectedDeviceLoss", "bitflip_file",
           "corrupt_checkpoint", "truncate_file"]
