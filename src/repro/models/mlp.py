"""Feed-forward: gated (SwiGLU/GeGLU) or plain, plus MoE delegation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, f, dtype),
         "wo": dense_init(ks[1], f, d, dtype)}
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp_apply(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    act = _ACTS[cfg.act]
    h = x @ params["wi"]
    if cfg.gated_mlp:
        h = act(x @ params["wg"]) * h
    else:
        h = act(h)
    return h @ params["wo"]
