"""Pure-jnp oracle for the COSMO horizontal diffusion compound stencil.

Faithful to the COSMO/gridtools `hdiff` used by NERO (paper Algorithm 1 +
the standard flux limiter from the COSMO reference implementation; the paper's
pseudo-code elides the limiter line that its predecessor NARMADA [129] and the
gridtools reference contain).  Layout: (z, y, x); halo = 2 in y and x; output
boundary points are passed through unchanged.
"""

from __future__ import annotations

import jax.numpy as jnp

DEFAULT_COEFF = 0.025


def _s(f: jnp.ndarray, dj: int, di: int) -> jnp.ndarray:
    """View of `f` shifted by (dj, di), cropped to the interior (halo=2)."""
    nz, ny, nx = f.shape
    return f[:, 2 + dj: ny - 2 + dj, 2 + di: nx - 2 + di]


def _lap(f: jnp.ndarray, dj: int, di: int) -> jnp.ndarray:
    """5-point Laplacian of `f` centered at interior offset (dj, di).

    True-Laplacian sign (Σ neighbors - 4·center): with the output stencil
    `out = in - coeff·div(flux)` this damps (g = 1 - 64·coeff at the 2Δx
    mode in 2D); the negated convention silently amplifies and the flux
    limiter then freezes the checkerboard mode instead of removing it."""
    return ((_s(f, dj, di - 1) + _s(f, dj, di + 1)
             + _s(f, dj - 1, di) + _s(f, dj + 1, di))
            - 4.0 * _s(f, dj, di))


def hdiff(src: jnp.ndarray, coeff: float = DEFAULT_COEFF,
          limit: bool = True) -> jnp.ndarray:
    """Compound horizontal diffusion: laplace -> (limited) flux -> output.

    src: (nz, ny, nx) with ny, nx >= 5.  Returns same shape; the 2-wide
    boundary ring equals src (matching the paper's interior-only loops).
    """
    src = jnp.asarray(src)
    f = src.astype(jnp.float32) if src.dtype == jnp.bfloat16 else src

    lap_c = _lap(f, 0, 0)
    lap_xp = _lap(f, 0, 1)
    lap_xm = _lap(f, 0, -1)
    lap_yp = _lap(f, 1, 0)
    lap_ym = _lap(f, -1, 0)

    flx = lap_xp - lap_c          # flux between (i) and (i+1)
    flx_m = lap_c - lap_xm        # flux between (i-1) and (i)
    fly = lap_yp - lap_c
    fly_m = lap_c - lap_ym

    if limit:
        flx = jnp.where(flx * (_s(f, 0, 1) - _s(f, 0, 0)) > 0.0, 0.0, flx)
        flx_m = jnp.where(flx_m * (_s(f, 0, 0) - _s(f, 0, -1)) > 0.0, 0.0, flx_m)
        fly = jnp.where(fly * (_s(f, 1, 0) - _s(f, 0, 0)) > 0.0, 0.0, fly)
        fly_m = jnp.where(fly_m * (_s(f, 0, 0) - _s(f, -1, 0)) > 0.0, 0.0, fly_m)

    interior = _s(f, 0, 0) - coeff * ((flx - flx_m) + (fly - fly_m))
    out = f.at[:, 2:-2, 2:-2].set(interior)
    return out.astype(src.dtype)


def hdiff_simple(src: jnp.ndarray, coeff: float = DEFAULT_COEFF) -> jnp.ndarray:
    """Paper Algorithm-1 variant without the flux limiter."""
    return hdiff(src, coeff=coeff, limit=False)
