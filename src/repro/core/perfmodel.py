"""Analytic per-plan performance/energy model (shared by autotuner & roofline).

Mirrors the role of the paper's performance estimates during OpenTuner search:
for a TilePlan we derive the three roofline terms (compute / memory /
collective), predicted time = max of the overlappable terms (dataflow
pipelining overlaps load & compute, the paper's §3 design), and energy from
per-level pJ/byte coefficients.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

from repro.core import hierarchy as hw
from repro.core.tiling import TilePlan


@dataclasses.dataclass(frozen=True)
class PerfEstimate:
    plan: TilePlan
    compute_s: float
    memory_s: float
    collective_s: float
    vmem_s: float
    time_s: float            # pipelined: max(terms) + fill latency
    gflops: float            # useful GFLOP/s at predicted time
    energy_j: float
    bottleneck: str

    @property
    def gflops_per_watt(self) -> float:
        if self.time_s == 0:
            return 0.0
        watts = self.energy_j / self.time_s
        return self.gflops / max(watts, 1e-9)


def estimate(plan: TilePlan,
             hier: Optional[hw.Hierarchy] = None,
             chips: int = 1,
             collective_bytes: float = 0.0,
             utilization: float = 0.85) -> PerfEstimate:
    """Roofline-style time: terms overlap under the dataflow pipeline, so the
    pipeline throughput is set by the slowest stage; `utilization` derates
    peak numbers (HBM controllers, pipeline bubbles)."""
    hier = hier or hw.tpu_v5e()
    b = hw.dtype_bytes(plan.dtype)
    peak = hier.peak_flops_bf16 if b <= 2 else hier.peak_flops_fp32

    flops = plan.flops_total
    hbm_bytes = plan.hbm_bytes_total
    vmem_bytes = hbm_bytes * 2.0   # staged in + consumed out of VMEM

    compute_s = flops / (chips * peak * utilization)
    memory_s = hbm_bytes / (chips * hier.hbm.bandwidth_bytes_per_s * utilization)
    vmem_s = vmem_bytes / (chips * hier.vmem.bandwidth_bytes_per_s)
    coll_s = collective_bytes / (chips * hier.ici_bw) if collective_bytes else 0.0

    # Pipeline fill: one tile's worth of latency before steady state.
    fill_s = (plan.hbm_bytes_per_tile /
              (hier.hbm.bandwidth_bytes_per_s * utilization))
    time_s = max(compute_s, memory_s, vmem_s, coll_s) + fill_s

    terms = {"compute": compute_s, "memory": memory_s,
             "vmem": vmem_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    energy = (hbm_bytes * hier.hbm.energy_pj_per_byte
              + vmem_bytes * hier.vmem.energy_pj_per_byte
              + collective_bytes * hw.ENERGY_PJ_PER_BYTE["ici"]
              + flops * hw.ENERGY_PJ_PER_FLOP_BF16) * 1e-12
    energy += hw.CHIP_IDLE_WATTS * time_s * chips   # static power floor

    gflops = flops / time_s / 1e9 if time_s > 0 else 0.0
    return PerfEstimate(plan=plan, compute_s=compute_s, memory_s=memory_s,
                        collective_s=coll_s, vmem_s=vmem_s, time_s=time_s,
                        gflops=gflops, energy_j=energy, bottleneck=bottleneck)


def roofline_fraction(est: PerfEstimate,
                      hier: Optional[hw.Hierarchy] = None,
                      chips: int = 1) -> float:
    """Achieved fraction of the roofline bound for this op's arithmetic
    intensity (1.0 = sitting on the roof)."""
    hier = hier or hw.tpu_v5e()
    b = hw.dtype_bytes(est.plan.dtype)
    peak = hier.peak_flops_bf16 if b <= 2 else hier.peak_flops_fp32
    ai = est.plan.op.arithmetic_intensity(est.plan.dtype)
    roof = min(peak, ai * hier.hbm.bandwidth_bytes_per_s) * chips
    if est.plan.op.flops_per_point == 0.0:
        # bandwidth kernels (copy): fraction of peak HBM bandwidth instead.
        achieved_bw = est.plan.hbm_bytes_total / est.time_s
        return achieved_bw / (hier.hbm.bandwidth_bytes_per_s * chips)
    achieved = est.plan.flops_total / est.time_s
    return achieved / roof
