"""Roofline-term extraction from compiled SPMD artifacts.

The dry-run lowers+compiles each (arch x shape x mesh) cell; this module
turns the compiled artifact into the three roofline terms:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_wire_bytes_per_device / link_bw

cost_analysis() is per-device (post-SPMD-partitioning).  Collective bytes
are NOT in cost_analysis: we parse the compiled HLO text and sum result
shapes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops, with ring-algorithm wire factors.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional, Tuple

from repro.core import hierarchy as hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s*"
    r"(?P<op>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")

# Ring-algorithm wire-bytes factor per result byte (n = group size; we use
# the n->inf limit as the conservative constant).
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-type result bytes (per device) from compiled HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        out[op] = out.get(op, 0) + _shape_bytes(m.group("result"))
    return out


def wire_bytes(coll: Dict[str, int]) -> float:
    return sum(_WIRE_FACTOR.get(op, 1.0) * b for op, b in coll.items())


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_flops_ratio: float       # MODEL_FLOPS / (HLO flops x chips)
    chips: int
    # Peak FLOP/s of the machine the terms were computed against (the spec's,
    # not a global constant), so `roofline_fraction` stays consistent with
    # `analyze(spec=...)` even for non-default machines.
    peak_flops: float = hw.PEAK_BF16_FLOPS

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s achieved at the bound, vs chip peak."""
        if self.step_time_s == 0:
            return 0.0
        achieved = self.model_flops_total / self.step_time_s
        return achieved / (self.chips * self.peak_flops)


def analyze(cost: Dict[str, float], coll: Dict[str, int], chips: int,
            model_flops_total: float, dtype_bytes: int = 2,
            spec=None) -> RooflineTerms:
    """Memory term prefers the TPU-fusion-emulated byte count
    ("bytes fused", core/hlo_cost.py) when present; the raw operand+output
    count ("bytes accessed") reflects XLA:CPU's much finer fusion
    granularity and over-states TPU HBM traffic several-fold.  `spec`
    (a `hwspec.HardwareSpec`) selects the machine whose peaks the terms are
    measured against; default is the TPU v5e the artifact compiled for."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes fused") or cost.get("bytes accessed", 0.0))
    wire = wire_bytes(coll)
    if spec is None:
        peak = (hw.PEAK_BF16_FLOPS if dtype_bytes <= 2 else hw.PEAK_FP32_FLOPS)
        hbm_bw, link_bw = hw.HBM_BW, hw.ICI_BW_PER_LINK
    else:
        peak = spec.peak_flops["bfloat16" if dtype_bytes <= 2 else "float32"]
        hbm_bw = spec.main.bandwidth_bytes_per_s
        link_bw = spec.collective.bandwidth_bytes_per_s
    compute_s = flops / peak
    memory_s = byts / hbm_bw
    collective_s = wire / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ratio = (model_flops_total / (flops * chips)) if flops else 0.0
    return RooflineTerms(
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=wire, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        model_flops_total=model_flops_total, useful_flops_ratio=ratio,
        chips=chips, peak_flops=peak)


def model_flops(param_count: int, active_param_count: int, tokens: int,
                kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd), N = active."""
    n = active_param_count
    return (6.0 if kind == "train" else 2.0) * n * tokens
