"""Batched serving engine: prefill + decode with continuous slot batching.

A fixed pool of `batch` slots; finished sequences are replaced from the
request queue (continuous batching).  Slot-aligned prefill keeps one jitted
decode_step for the whole run; greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (plen,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    latency_s: float = 0.0          # THIS request's admit -> last token


class ServeEngine:
    """Single-host reference engine (the multi-chip path shards the same
    jitted fns via the dry-run shardings)."""

    def __init__(self, model: Model, params, batch: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        cfg = model.cfg
        self._decode = jax.jit(
            lambda p, c, tok, pos: model.decode_step(p, c, tok, pos))

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.key, k = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            k, logits[:, -1].astype(jnp.float32) / self.temperature))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Process all requests with continuous slot batching."""
        queue = list(requests)
        for r in queue:
            r.out_tokens = []
        # pad all prompts to a common prefill length (slot-aligned)
        plen = max(len(r.prompt) for r in queue)
        results: Dict[int, List[int]] = {}

        while queue:
            active = queue[:self.batch]
            queue = queue[len(active):]
            t0 = time.perf_counter()
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(active):
                toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if self.model.cfg.encdec:
                batch["frames"] = jnp.zeros(
                    (self.batch, self.model.cfg.encdec.encoder_len,
                     self.model.cfg.d_model), jnp.dtype(self.model.cfg.dtype))
            logits, cache = self.model.prefill(self.params, batch,
                                               max_len=self.max_len)
            nxt = self._sample(logits)

            def append(r, tok):
                """Record one token; a request's latency clock stops the
                moment ITS last token lands, not when the wave ends."""
                r.out_tokens.append(int(tok))
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.latency_s = time.perf_counter() - t0

            for i, r in enumerate(active):
                append(r, nxt[i])
            pos = plen
            steps = max(r.max_new_tokens for r in active) - 1
            for _ in range(max(steps, 0)):
                tok = jnp.asarray(nxt[:, None].astype(np.int32))
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(pos))
                nxt = self._sample(logits)
                pos += 1
                for i, r in enumerate(active):
                    if len(r.out_tokens) < r.max_new_tokens:
                        append(r, nxt[i])
            for r in active:
                results[r.rid] = r.out_tokens
        return results
