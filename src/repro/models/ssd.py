"""Mamba2 SSD (state-space duality) mixer — chunked scan formulation.

The chunked SSD algorithm is NERO's windowing applied to the time axis:
within-chunk work is dense (MXU-friendly einsums over an (cl, cl) decay
kernel), across-chunk state flows through a first-order recurrence — the
same forward-sweep pattern as vadvc.  Follows the minimal listing of the
Mamba2 paper (Dao & Gu, 2024), with grouped B/C (n_groups) and a depthwise
causal conv front.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.models.rglru import causal_conv1d


def _dims(cfg: ModelConfig):
    s = cfg.ssd
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return di, nh, s.head_dim, s.d_state, s.n_groups


def ssd_init(key, cfg: ModelConfig, dtype):
    di, nh, p, n, g = _dims(cfg)
    d = cfg.d_model
    cw = cfg.ssd.conv_width
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * n + nh
    conv_dim = di + 2 * g * n
    return {
        "in_proj": dense_init(ks[0], d, d_in_proj, dtype),
        "conv": (jax.random.normal(ks[1], (cw, conv_dim), jnp.float32)
                 / cw).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k],
    -inf above the diagonal.  x: (..., cl)."""
    cl = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((cl, cl), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """SSD scan.  x: (b, t, h, p); dt: (b, t, h); A: (h,);
    B, C: (b, t, g, n).  Returns (y, h_last)."""
    b, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rep = h // g

    def tochunk(a):
        return a.reshape((b, nc, chunk) + a.shape[2:])

    xc, dtc, Bc, Cc = map(tochunk, (x, dt, B, C))
    Bh = jnp.repeat(Bc, rep, axis=3)        # (b,nc,cl,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                             # (b,nc,cl,h)
    dA_cs = jnp.cumsum(dA, axis=2)           # within-chunk cumsum

    # ---- intra-chunk (dense, MXU) ----------------------------------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # (b,nc,h,cl,cl)
    xdt = xc * dtc[..., None]                           # (b,nc,cl,h,p)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", Ch, Bh, L, xdt)

    # ---- chunk states -----------------------------------------------------
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # (b,nc,cl,h)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        Bh, decay_states * dtc, xc)            # per-chunk

    # ---- inter-chunk recurrence (the vadvc-style sweep) --------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # (b,nc,h)

    def sweep(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                       # emit prev

    init = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    h_last, prev_states = jax.lax.scan(
        sweep, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                    # (b,nc,h,p,n)

    # ---- inter-chunk output -------------------------------------------------
    state_decay = jnp.exp(dA_cs)                                # (b,nc,cl,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, t, h, p)
    return y, h_last


def ssd_apply(cfg: ModelConfig, params, x: jnp.ndarray,
              state: Optional[dict] = None):
    """Full Mamba2 mixer.  x: (B, T, D) -> (out, new_state).

    state (decode): {"h": (B, nh, p, n) fp32, "conv": (B, cw-1, conv_dim)}.
    """
    di, nh, p, n, g = _dims(cfg)
    b, t, d = x.shape

    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv1d(xbc, params["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xi, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
    xi = xi.reshape(b, t, nh, p).astype(jnp.float32)
    B = B.reshape(b, t, g, n).astype(jnp.float32)
    C = C.reshape(b, t, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    h0 = state["h"] if state is not None else None
    chunk = min(cfg.ssd.chunk, t)
    pad = (-t) % chunk
    if pad:
        # Left-pad with zeros: contributes nothing to states/outputs when
        # h0 == 0 (x=0 adds nothing; decay of a zero state is zero).
        assert h0 is None, "chunk padding requires fresh state"
        zpad = lambda a: jnp.pad(a, [(0, 0), (pad, 0)] +
                                 [(0, 0)] * (a.ndim - 2))
        y, h_last = _ssd_chunked(zpad(xi), zpad(dt), A, zpad(B), zpad(C),
                                 chunk, None)
        y = y[:, pad:]
    else:
        y, h_last = _ssd_chunked(xi, dt, A, B, C, chunk, h0)
    y = y + xi * params["D"][:, None]
    y = y.reshape(b, t, di)

    # gated RMSNorm (mamba2)
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    yz = yz * jax.lax.rsqrt(jnp.mean(yz * yz, -1, keepdims=True) + 1e-6)
    yz = (yz * params["norm_scale"]).astype(x.dtype)
    out = yz @ params["out_proj"]
    new_state = {"h": h_last, "conv": new_conv}
    return out, new_state


def ssd_decode_step(cfg: ModelConfig, params, x: jnp.ndarray, state: dict):
    """Single-token recurrent step (O(1) in sequence length)."""
    return ssd_apply(cfg, params, x, state)


def ssd_init_state(cfg: ModelConfig, batch: int, dtype):
    di, nh, p, n, g = _dims(cfg)
    cw = cfg.ssd.conv_width
    conv_dim = di + 2 * g * n
    return {"h": jnp.zeros((batch, nh, p, n), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, conv_dim), dtype)}
