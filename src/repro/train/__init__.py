"""repro.train subpackage."""
