"""Pallas TPU compound kernel: one fused dycore field step per grid cell.

This is the NERO dataflow argument (arxiv 2107.08716 §3) applied to the whole
dycore step instead of a single stencil: the CPU/GPU baseline writes every
stage's result back to main memory (vadvc tendency, explicitly-updated field,
padded halo copy), while the FPGA PE streams a window once and pipelines
laplace -> flux-limit -> output plus the vertical Thomas solve entirely in
near-memory (BRAM/URAM).  The TPU formulation of that PE:

  * grid = (batch, ny/ty): each grid cell owns a full z-slab of one y-window
    (vadvc is sequential in z, so z is never tiled — the paper's PE design);
    batch rides the ensemble axis.
  * The 2-deep periodic y-halo is realized with three aliased input refs
    (prev / cur / next window) whose index maps wrap modulo the window count
    — the overlapping-window idiom from kernels/hdiff/hdiff.py, made
    periodic.  x stays whole inside the window; the periodic x-halo is a
    lane roll in VMEM.
  * Stages chain through VMEM scratch only: the forward Thomas sweep stores
    (ccol, dcol) in fp32 scratch (the paper's "intermediate buffer to allow
    for backward sweep calculation"), backward substitution writes the stage
    tendency into scratch, the point-wise update and the compound hdiff read
    it straight from VMEM, and only (f_new, stage) for the *cur* window ever
    travel back to HBM.
  * Compute is fp32 internally; bf16 I/O supported (the paper's
    half-precision mode trades HBM traffic for accuracy).

The staggered vertical velocity enters pre-combined: callers pass
w = wcon_i + wcon_{i+1} (periodic next column), which is the only combination
the solve ever uses — this keeps every block transfer a clean rectangular
HBM->VMEM DMA, the same trick vadvc.py uses with its wl/wr pre-slices.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.kernels.hdiff.ref import DEFAULT_COEFF
from repro.kernels.vadvc.ref import BET_M, BET_P, DTR_STAGE

HALO = 2   # y/x halo depth of the compound hdiff stage


def _fused_kernel(f_prev, f_cur, f_next,
                  w_prev, w_cur, w_next,
                  t_prev, t_cur, t_next,
                  s_prev, s_cur, s_next,
                  outf_ref, outs_ref,
                  fwork, wwork, rhs, ccol, dcol, stage,
                  *, nz: int, ty: int, dt: float, coeff: float):
    f32 = jnp.float32

    def asm(prev, cur, nxt):
        """Assemble the (nz, ty+4, nx) fp32 working window: cur plus a 2-row
        halo taken from the periodic prev/next windows."""
        return jnp.concatenate(
            [prev[0][:, -HALO:], cur[0], nxt[0][:, :HALO]],
            axis=1).astype(f32)

    fwork[...] = asm(f_prev, f_cur, f_next)
    wwork[...] = asm(w_prev, w_cur, w_next)
    # u_pos == u_stage == f in the dycore step, so the static part of the
    # tridiagonal RHS is precomputed once per window.
    rhs[...] = (DTR_STAGE * fwork[...] + asm(t_prev, t_cur, t_next)
                + asm(s_prev, s_cur, s_next))

    def ld(ref, k):
        return ref[pl.ds(k, 1)][0]

    # ---- vadvc forward sweep, k = 0 ---------------------------------------
    gcv = 0.25 * ld(wwork, 1)
    cs = gcv * BET_M
    ccol0 = gcv * BET_P
    bcol = DTR_STAGE - ccol0
    corr = -cs * (ld(fwork, 1) - ld(fwork, 0))
    divided = 1.0 / bcol
    ccol[pl.ds(0, 1)] = (ccol0 * divided)[None]
    dcol[pl.ds(0, 1)] = ((ld(rhs, 0) + corr) * divided)[None]

    # ---- forward sweep, 0 < k < nz-1 --------------------------------------
    def fwd_body(k, _):
        gav = -0.25 * ld(wwork, k)
        gcv = 0.25 * ld(wwork, k + 1)
        as_ = gav * BET_M
        cs = gcv * BET_M
        acol = gav * BET_P
        ccol_k = gcv * BET_P
        bcol = DTR_STAGE - acol - ccol_k
        fk = ld(fwork, k)
        corr = (-as_ * (ld(fwork, k - 1) - fk)
                - cs * (ld(fwork, k + 1) - fk))
        cprev = ccol[pl.ds(k - 1, 1)][0]
        dprev = dcol[pl.ds(k - 1, 1)][0]
        divided = 1.0 / (bcol - cprev * acol)
        ccol[pl.ds(k, 1)] = (ccol_k * divided)[None]
        dcol[pl.ds(k, 1)] = (((ld(rhs, k) + corr) - dprev * acol)
                             * divided)[None]
        return 0

    jax.lax.fori_loop(1, nz - 1, fwd_body, 0)

    # ---- forward sweep, k = nz-1 ------------------------------------------
    k = nz - 1
    gav = -0.25 * ld(wwork, k)
    as_ = gav * BET_M
    acol = gav * BET_P
    bcol = DTR_STAGE - acol
    corr = -as_ * (ld(fwork, k - 1) - ld(fwork, k))
    cprev = ccol[pl.ds(k - 1, 1)][0]
    dprev = dcol[pl.ds(k - 1, 1)][0]
    divided = 1.0 / (bcol - cprev * acol)
    dlast = ((ld(rhs, k) + corr) - dprev * acol) * divided
    dcol[pl.ds(k, 1)] = dlast[None]

    # ---- backward substitution -> stage tendency, never leaving VMEM -------
    stage[pl.ds(nz - 1, 1)] = (DTR_STAGE * (dlast - ld(fwork, nz - 1)))[None]

    def bwd_body(m, datac):
        k = nz - 2 - m
        datac = dcol[pl.ds(k, 1)][0] - ccol[pl.ds(k, 1)][0] * datac
        stage[pl.ds(k, 1)] = (DTR_STAGE * (datac - ld(fwork, k)))[None]
        return datac

    jax.lax.fori_loop(0, nz - 1, bwd_body, dlast)

    # ---- point-wise explicit update (still in VMEM) ------------------------
    stg = stage[...]                       # (nz, ty+4, nx)
    fup = fwork[...] + dt * stg

    # ---- compound hdiff on the updated field -------------------------------
    # y shifts index into the halo'd working window; x shifts are periodic
    # lane rolls (the full x extent lives in the window).
    def s(dj: int, di: int) -> jnp.ndarray:
        win = fup[:, HALO + dj: HALO + dj + ty, :]
        return jnp.roll(win, -di, axis=2) if di else win

    def lap(dj: int, di: int) -> jnp.ndarray:
        # true-Laplacian sign (see kernels/hdiff/ref.py)
        return ((s(dj, di - 1) + s(dj, di + 1)
                 + s(dj - 1, di) + s(dj + 1, di))
                - 4.0 * s(dj, di))

    lap_c, lap_xp, lap_xm = lap(0, 0), lap(0, 1), lap(0, -1)
    lap_yp, lap_ym = lap(1, 0), lap(-1, 0)

    flx = lap_xp - lap_c
    flx_m = lap_c - lap_xm
    fly = lap_yp - lap_c
    fly_m = lap_c - lap_ym
    # COSMO flux limiter.
    flx = jnp.where(flx * (s(0, 1) - s(0, 0)) > 0.0, 0.0, flx)
    flx_m = jnp.where(flx_m * (s(0, 0) - s(0, -1)) > 0.0, 0.0, flx_m)
    fly = jnp.where(fly * (s(1, 0) - s(0, 0)) > 0.0, 0.0, fly)
    fly_m = jnp.where(fly_m * (s(0, 0) - s(-1, 0)) > 0.0, 0.0, fly_m)

    out = s(0, 0) - coeff * ((flx - flx_m) + (fly - fly_m))
    outf_ref[0] = out.astype(outf_ref.dtype)
    outs_ref[0] = stg[:, HALO:HALO + ty, :].astype(outs_ref.dtype)


def fused_dycore_pallas(f: jnp.ndarray, w: jnp.ndarray, utens: jnp.ndarray,
                        utens_stage: jnp.ndarray, *,
                        coeff: float = DEFAULT_COEFF, dt: float = 0.1,
                        ty: int = 8, interpret: bool = False):
    """Fused dycore field step.  All inputs (..., nz, ny, nx), doubly
    periodic in (y, x); `w` is the pre-combined staggered vertical velocity
    wcon_i + wcon_{i+1} (see module docstring).  ny % ty == 0, ty >= 2,
    nz >= 2.  Returns (f_new, stage) shaped/typed like `f`.
    """
    shape = f.shape
    nz, ny, nx = shape[-3:]
    if ny % ty or ty < 2:
        raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 2")
    if nz < 2:
        raise ValueError(f"nz={nz} must be >= 2 (staggered vertical sweep)")
    nyb = ny // ty
    batch = math.prod(shape[:-3]) if len(shape) > 3 else 1

    spec = functools.partial(pl.BlockSpec, (1, nz, ty, nx))
    # Periodic overlapping windows: prev/next wrap modulo the window count.
    window = [
        spec(lambda b, j: (b, 0, (j + nyb - 1) % nyb, 0)),   # prev
        spec(lambda b, j: (b, 0, j, 0)),                     # cur
        spec(lambda b, j: (b, 0, (j + 1) % nyb, 0)),         # next
    ]
    out_spec = spec(lambda b, j: (b, 0, j, 0))

    kernel = functools.partial(_fused_kernel, nz=nz, ty=ty, dt=dt,
                               coeff=coeff)
    bshape = (batch, nz, ny, nx)
    scratch = pltpu.VMEM((nz, ty + 2 * HALO, nx), jnp.float32)
    fn = pl.pallas_call(
        kernel,
        grid=(batch, nyb),
        in_specs=window * 4,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct(bshape, f.dtype)] * 2,
        scratch_shapes=[scratch] * 6,   # fwork, wwork, rhs, ccol, dcol, stage
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_dycore_fused",
    )
    args = []
    for a in (f, w, utens, utens_stage):
        a = a.reshape(bshape)
        args += [a, a, a]
    f_new, stage = fn(*args)
    return f_new.reshape(shape), stage.reshape(shape)


def fused_dycore_whole_state_pallas(fs: jnp.ndarray, w: jnp.ndarray,
                                    utens: jnp.ndarray,
                                    utens_stage: jnp.ndarray, *,
                                    coeff: float = DEFAULT_COEFF,
                                    dt: float = 0.1, ty: int = 8,
                                    interpret: bool = False):
    """Whole-state fused dycore step: ONE `pallas_call` for every prognostic
    field, sharing the staggered-velocity slab across fields.

    `fs`, `utens`, `utens_stage` are field-stacked `(..., nf, nz, ny, nx)`;
    `w` is the pre-combined staggered vertical velocity `(..., nz, ny, nx)`,
    identical for every field.  The grid is `(batch, ny/ty, nf)` with the
    field axis innermost and the per-field operands flattened to
    `batch*nf` so their index maps read `b*nf + k` — while `w` keeps its
    un-stacked layout and an index map that *ignores* `k`.  Consecutive
    field iterations therefore revisit the same `w` block index, and Pallas
    elides the re-fetch: each (ensemble, y-window) slab of `w` is DMA'd
    from HBM once per step instead of once per field (~1/(3+1/nf) of input
    traffic saved, 25% at nf→∞) on top of the nf× launch amortization.

    Returns `(f_new, stage)` shaped/typed like `fs`.
    """
    shape = fs.shape
    if len(shape) < 4:
        raise ValueError(f"fs must be (..., nf, nz, ny, nx), got {shape}")
    nf, nz, ny, nx = shape[-4:]
    if ny % ty or ty < 2:
        raise ValueError(f"ny={ny} must be divisible by ty={ty} >= 2")
    if nz < 2:
        raise ValueError(f"nz={nz} must be >= 2 (staggered vertical sweep)")
    if w.shape[-3:] != (nz, ny, nx):
        raise ValueError(f"w shape {w.shape} != fields grid {(nz, ny, nx)}")
    nyb = ny // ty
    batch = math.prod(shape[:-4]) if len(shape) > 4 else 1

    spec = functools.partial(pl.BlockSpec, (1, nz, ty, nx))

    def fmap(dj: int):
        # Per-field operand: flattened (batch*nf) leading axis, periodic
        # y-window offset dj.
        return lambda b, j, k: (b * nf + k, 0, (j + dj) % nyb, 0)

    def wmap(dj: int):
        # Shared operand: the field grid index k is collapsed — the block
        # index repeats across the nf innermost iterations, so the slab is
        # fetched once per (b, j).
        return lambda b, j, k: (b, 0, (j + dj) % nyb, 0)

    fwin = [spec(fmap(nyb - 1)), spec(fmap(0)), spec(fmap(1))]
    wwin = [spec(wmap(nyb - 1)), spec(wmap(0)), spec(wmap(1))]
    out_spec = spec(lambda b, j, k: (b * nf + k, 0, j, 0))

    kernel = functools.partial(_fused_kernel, nz=nz, ty=ty, dt=dt,
                               coeff=coeff)
    fshape = (batch * nf, nz, ny, nx)
    wshape = (batch, nz, ny, nx)
    scratch = pltpu.VMEM((nz, ty + 2 * HALO, nx), jnp.float32)
    fn = pl.pallas_call(
        kernel,
        grid=(batch, nyb, nf),
        in_specs=fwin + wwin + fwin + fwin,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct(fshape, fs.dtype)] * 2,
        scratch_shapes=[scratch] * 6,   # fwork, wwork, rhs, ccol, dcol, stage
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="nero_dycore_whole_state",
    )
    args = []
    for a, s in ((fs, fshape), (w, wshape), (utens, fshape),
                 (utens_stage, fshape)):
        a = a.reshape(s)
        args += [a, a, a]
    f_new, stage = fn(*args)
    return f_new.reshape(shape), stage.reshape(shape)
