"""Serving engine: continuous batching, greedy consistency, cache reuse."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"),
                                  layers=2)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_batching_processes_all(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, batch=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i, dtype=np.int32) % 250,
                    max_new_tokens=4) for i in range(5)]
    out = eng.run(reqs)
    assert sorted(out) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < cfg.padded_vocab for v in out.values() for t in v)


def test_greedy_matches_stepwise_reference(served):
    """Engine greedy decode == hand-rolled prefill + decode_step loop."""
    cfg, model, params = served
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServeEngine(model, params, batch=1, max_len=32)
    got = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])[0]

    batch = {"tokens": jnp.asarray(prompt[None, :])}
    logits, cache = model.prefill(params, batch, max_len=32)
    want = []
    tok = int(jnp.argmax(logits[0, -1]))
    want.append(tok)
    pos = len(prompt)
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(pos))
        tok = int(jnp.argmax(lg[0, -1]))
        want.append(tok)
        pos += 1
    assert got == want


def test_latency_is_per_request_not_per_wave(served):
    """A request's latency clock stops at ITS last token, not the wave's.

    Two requests share one decode wave; the short one must report a
    strictly smaller latency than the long one (the old accounting gave
    every request the whole-wave wall time)."""
    cfg, model, params = served
    eng = ServeEngine(model, params, batch=2, max_len=64)
    short = Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=1)
    long_ = Request(rid=1, prompt=np.asarray([4, 5, 6], np.int32),
                    max_new_tokens=12)
    out = eng.run([short, long_])
    assert len(out[0]) == 1 and len(out[1]) == 12
    assert 0.0 < short.latency_s < long_.latency_s


def test_sampled_tokens_stay_in_logical_vocab(served):
    """Temperature sampling must never emit a padded-vocab token."""
    cfg, model, params = served
    eng = ServeEngine(model, params, batch=2, max_len=32, temperature=1.0,
                      seed=7)
    reqs = [Request(rid=i, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=8) for i in range(2)]
    out = eng.run(reqs)
    for toks in out.values():
        assert all(t < cfg.vocab_size for t in toks), toks
