"""Paper Fig. 7 — performance scaling with PEs, and domain-size linearity.

(1) Chip/PE scaling of vadvc+hdiff throughput from the perf model with the
    halo-exchange collective term included (the distributed dycore's real
    communication), reproducing the paper's linear-scaling claim for
    channel-per-PE designs.
(2) Measured runtime vs domain size on this CPU (paper §4.3: "runtime
    scales linearly and overall GFLOP/s remains constant").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hierarchy as hw
from repro.core import perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href
from repro.kernels.vadvc import ref as vref


def run():
    # -- (1) PE/chip scaling with halo collectives --------------------------
    grid = (64, 1024, 1024)
    for op in (tiling.VADVC, tiling.HDIFF):
        t1 = None
        for chips in (1, 4, 16, 64, 256):
            tuned = tune(op, grid, "float32", chips=chips)
            # halo bytes: 2-deep ring on the local slab boundary per chip
            ny_loc = grid[1] / max(int(np.sqrt(chips)), 1)
            halo_bytes = 2 * 2 * (ny_loc + ny_loc) * grid[0] * 4 * (
                op.fields_in)
            est = perfmodel.estimate(tuned.plan, chips=chips,
                                     collective_bytes=halo_bytes * chips)
            t1 = t1 or est.time_s
            emit(f"fig7/{op.name}_chips{chips}", est.time_s * 1e6,
                 f"gflops={est.gflops:.0f} speedup={t1 / est.time_s:.1f}x "
                 f"eff={t1 / est.time_s / chips * 100:.0f}%")

    # -- (2) measured domain-size linearity ---------------------------------
    rng = np.random.default_rng(0)
    base = None
    for n in (64, 128, 256):
        shape = (16, n, n)
        src = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        t = time_fn(jax.jit(href.hdiff), src)
        pts = float(np.prod(shape))
        base = base or t / pts
        emit(f"fig7/hdiff_domain_{n}", t,
             f"us_per_point={t / pts:.5f} linear_dev="
             f"{(t / pts) / base:.2f}x")


if __name__ == "__main__":
    run()
