"""Whole-state dycore traffic + k-step exchange accounting (memmodel)."""

import pytest

from repro.core import memmodel, tiling

def test_dycore_traffic_whole_state_beats_per_field():
    """Whole-state fused step: shared-w batching must strictly reduce
    modeled HBM traffic vs the per-field fused step, in both bounds."""
    for dtype in ("float32", "bfloat16"):
        t = memmodel.dycore_step_traffic((64, 256, 256), dtype,
                                         n_fields=4, ty=32)
        assert t["fused_whole"]["total"] < t["fused"]["total"]
        assert (t["fused_whole"]["stream_window_reads"]
                < t["fused"]["stream_window_reads"])
        assert t["reduction_x_whole"] > t["reduction_x"] > 1.0
        # shared w saves ~the per-field w stream: bounded by 1/4 of inputs
        saving = t["fused"]["total"] / t["fused_whole"]["total"]
        assert 1.05 < saving < 1.25, saving


def test_kstep_exchange_model():
    """Communication-avoiding k-step: collective rounds drop k-fold; bytes
    stay within ~1x of sequential (deep halo ~= k shallow halos, plus a
    mildly growing corner-region overhead); the redundant-flops tax grows
    monotonically with k."""
    prev_tax = -1.0
    for k in (1, 2, 4):
        m = memmodel.kstep_exchange_model((64, 256, 256), "float32",
                                          n_fields=4, k=k, shards=(2, 2))
        assert m["rounds_kstep"] == 2
        assert m["rounds_sequential"] == 2 * k
        assert 0.5 < m["bytes_ratio"] < 1.1
        assert m["redundant_flops_frac"] > prev_tax
        prev_tax = m["redundant_flops_frac"]
    with pytest.raises(ValueError):
        memmodel.kstep_exchange_model((8, 16, 16), "float32", k=4,
                                      shards=(2, 2))


def test_kstep_exchange_model_wire_dtype():
    """bf16 stacked exchange (the paper's half-precision mode on the wire):
    exactly half the ppermuted bytes of fp32 at every k, same rounds, same
    redundant-flops tax — the cast changes wire width only."""
    for k in (1, 2, 4):
        f32 = memmodel.kstep_exchange_model((64, 256, 256), "float32", k=k)
        bf = memmodel.kstep_exchange_model((64, 256, 256), "float32", k=k,
                                           exchange_dtype="bfloat16")
        assert bf["bytes_kstep"] * 2 == f32["bytes_kstep"]
        assert bf["bytes_sequential"] * 2 == f32["bytes_sequential"]
        assert bf["rounds_kstep"] == f32["rounds_kstep"]
        assert bf["redundant_flops_frac"] == f32["redundant_flops_frac"]
    # a bf16 *state* exchanged without a wire cast already ships 2-byte halos
    b16 = memmodel.kstep_exchange_model((64, 256, 256), "bfloat16", k=2)
    bfw = memmodel.kstep_exchange_model((64, 256, 256), "float32", k=2,
                                        exchange_dtype="bfloat16")
    assert b16["bytes_kstep"] == bfw["bytes_kstep"]


def test_kstep_exchange_model_wcon_ragged_depth():
    """Only wcon ships the +1 staggering column, and only to the RIGHT
    side (the left pad's extra column is never read by
    `w[c] = wcon[c] + wcon[c+1]`): its x-ride is `(k*HALO, k*HALO+1)`, so
    the x legs carry `2*k*HALO + 1` columns.  The packed total is strictly
    below both the old symmetric-wcon geometry (one spare column per
    round) and the uniform-depth whole-stack over-shipping."""
    nz, ny, nx = 64, 256, 256
    for k in (1, 2):
        m = memmodel.kstep_exchange_model((nz, ny, nx), "float32",
                                          n_fields=4, k=k, shards=(2, 2))
        ly, lx = ny // 2, nx // 2
        hy = hx = k * 2
        b = 4
        # wcon alone: symmetric hy in y, ragged (hx, hx+1) in x.
        want_wcon = nz * b * (2 * hy * lx + (2 * hx + 1) * (ly + 2 * hy))
        assert m["bytes_wcon"] == want_wcon
        # the pre-fix symmetric ride at (hy, hx+1) both ways: exactly one
        # spare (ly + 2*hy)-column per round more than the ragged ride.
        symmetric = 2 * nz * b * (hy * lx + (hx + 1) * (ly + 2 * hy))
        assert symmetric - m["bytes_wcon"] == nz * b * (ly + 2 * hy)
        # uniform-depth stack at (hy, hx+1) for all 13 operands (the old
        # over-shipping): strictly more than the ragged pack.
        uniform = 13 * symmetric
        assert m["bytes_kstep"] < uniform


def test_kstep_traffic_interstep_reduction():
    """The in-kernel k-step scan keeps prognostic state in VMEM between
    local steps: modeled inter-step state traffic (field + stage, read and
    written at HBM) drops exactly k-fold vs the scan-of-launches path, and
    the round's total stream bound beats k whole-state launches."""
    for k in (2, 4):
        t = memmodel.dycore_step_traffic((64, 256, 256), "float32",
                                         n_fields=4, ty=32, k_steps=k)
        ks = t["fused_kstep"]
        assert t["interstep_reduction_x"] == k
        assert ks["interstep_state_scan"] == k * ks["interstep_state"]
        assert t["reduction_x_kstep_vs_scan"] > 1.0
        assert ks["total"] < ks["scan_total"]
    # k_steps=1: no kstep entry (the whole-state step IS the round)
    t1 = memmodel.dycore_step_traffic((64, 256, 256), "float32", ty=32)
    assert "fused_kstep" not in t1


def test_kstep_opspec_vmem_accounting():
    """The k-step tile space stages a 3-window working slab: padded tile is
    3x the y-window, all 8 temporaries span it, and the double-buffered w
    prefetch claims 2 more padded buffers — so the same tile costs strictly
    more VMEM than in the whole-state space."""
    spec = tiling.dycore_kstep_spec(4, 2)
    assert spec.halo_tiles == (0, 1, 0) and spec.scratch_padded
    assert spec.extra_vmem_buffers == 2.0
    kplan = tiling.TilePlan(op=spec, grid_shape=(64, 256, 256),
                            tile=(64, 32, 256), dtype="float32")
    assert kplan.padded_tile == (64, 96, 256)
    wplan = tiling.TilePlan(op=tiling.dycore_whole_state_spec(4),
                            grid_shape=(64, 256, 256), tile=(64, 32, 256),
                            dtype="float32")
    assert kplan.vmem_bytes > 2 * wplan.vmem_bytes
    with pytest.raises(ValueError):
        tiling.dycore_kstep_spec(4, 0)


def test_whole_state_opspec_field_count_dependence():
    """More fields amortize the shared-w stream further (fields_in -> 3) but
    never change the resident VMEM accounting (scratch includes w)."""
    s2 = tiling.dycore_whole_state_spec(2)
    s8 = tiling.dycore_whole_state_spec(8)
    assert s8.fields_in < s2.fields_in
    assert s2.scratch_fields == s8.scratch_fields == 7
    with pytest.raises(ValueError):
        tiling.dycore_whole_state_spec(0)
