"""Shared benchmark utilities: wall-clock timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (blocks on device)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
