"""Fused vs unfused dycore step — the NERO fusion claim, measured + modeled.

Paper §3 (arxiv 2107.08716): the CPU/GPU baseline round-trips every
intermediate through main memory; the in-fabric pipeline streams each field
once.  This benchmark reports that claim three ways for one full dycore step
(4 prognostic fields), going EXCLUSIVELY through the declarative plan API
(`weather/program.py::compile_dycore`) — exactly ONE `ExecutionPlan` per
measured configuration, and any use of a deprecated flag-soup entry point
fails the run (DeprecationWarnings from our shims are promoted to errors):

  * measured wall-clock of the four execution variants — unfused oracle,
    per-field fused (4 Pallas launches), whole-state fused (ONE launch),
    and the k-step round (K timesteps in ONE launch).  (CPU note: without
    a TPU the fused kernels run in the Pallas *interpreter*, so their
    wall-clock here validates the pipelines, it does not demonstrate the
    speedup — the modeled rows do);
  * modeled HBM traffic per step from the model-grid plan's `report()`
    (`core/memmodel.dycore_step_traffic` with the plan's auto-tuned tile);
  * modeled TPU time/energy for the fused plan from core/perfmodel, and the
    k-step communication-avoiding exchange model
    (core/memmodel.kstep_exchange_model).

Emitted metric names (docs/benchmarks.md):
  dycore_fused/walltime_{unfused,fused,whole_state}  us per step (measured)
  dycore_fused/traffic_{unfused,fused,whole_state}_* modeled MB per step
  dycore_fused/model_{fused}                         modeled TPU time
  dycore_fused/kstep_k<k>                            k-step exchange model

Since the StencilOp registry landed, the benchmark also reproduces the
paper's PER-KERNEL table: hdiff-only and vadvc-only programs are compiled
through the same `compile()` planner and measured side-by-side with the
fused compound step — `BENCH_dycore.json["per_kernel"]` carries, for each
of (hdiff, vadvc, fused), the measured walltime, the plan report (op +
declared footprint + tile), and the modeled GFLOPS / GFLOPS-per-watt from
`core/perfmodel` (the paper's 21.01 vs 1.61 GFLOPS/W axis).

Also writes BENCH_dycore.json (walltime, modeled HBM bytes, steps/s, and
the distributed k-step plan's `report()` embedded verbatim as "plan") for
cross-PR perf tracking.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import warnings

import jax

from benchmarks.common import emit, smoke_mode, time_fn, write_json
from benchmarks.energy import energy_block
from benchmarks.roofline_kernels import roofline_block
from repro.core import hierarchy as hw
from repro.core import memmodel, perfmodel, tiling, trace_stats
from repro.weather import fields
from repro.weather import stencil_ops
from repro.weather.pipeline import PipelineProgram
from repro.weather.program import (DycoreProgram, StencilProgram,
                                   compile_dycore)

# Measured grid: deliberately small.  The Pallas interpreter's grid loop
# carries the full output state per iteration (O(grid_steps x state) copy
# overhead that real hardware does not have), which at large grids swamps —
# and inverts — the launch-amortization effect the whole-state step
# targets.  At this size the per-`pallas_call` dispatch cost is the visible
# term, which is exactly the 4-launches-vs-1 comparison; HBM-traffic
# effects are covered by the modeled rows at the paper's domain.
GRID = (4, 16, 16)
ENSEMBLE = 1
MODEL_GRID = (64, 256, 256)  # the paper's domain, for the modeled rows
SMOKE_GRID = (4, 16, 16)     # CI smoke job (tiny, interpret mode)
KSTEP_K = 2                  # depth of the measured/traced k-step round


# Structural counts + plan report of the distributed k-step round need >1
# shard per mesh axis, so they are produced in a subprocess with forced
# host devices (same trick as tests/test_program.py) and read back as JSON.
_STRUCT_SNIPPET = r"""
import json, jax
from repro.core import trace_stats
from repro.weather import fields
from repro.weather.program import DycoreProgram, compile_dycore
st = fields.initial_state(jax.random.PRNGKey(0), (4, 16, 16), ensemble=1)
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
plan = compile_dycore(DycoreProgram(grid_shape=(4, 16, 16),
                                    variant="kstep", k_steps=%d), mesh=mesh)
rep = plan.report()
j = jax.make_jaxpr(plan.step)(st)
counts = trace_stats.assert_plan_structure(j, rep)   # report == trace
print("STRUCT=" + json.dumps(counts))
print("PLAN=" + json.dumps(rep))
"""


def _kstep_round_structure(k: int) -> tuple:
    """Trace the distributed k-step plan on a forced 4-device CPU mesh and
    return ({"pallas_call": ..., "ppermute": ...}, plan.report())."""
    env = {k_: v for k_, v in os.environ.items() if k_ != "XLA_FLAGS"}
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _STRUCT_SNIPPET % k], env=env,
                       capture_output=True, text=True, timeout=600)
    struct = plan_rep = None
    for line in r.stdout.splitlines():
        if line.startswith("STRUCT="):
            struct = json.loads(line[len("STRUCT="):])
        elif line.startswith("PLAN="):
            plan_rep = json.loads(line[len("PLAN="):])
    if struct is None or plan_rep is None:
        raise RuntimeError(f"k-step structure trace failed: "
                           f"{r.stderr[-2000:]}")
    return struct, plan_rep


# Measured-autotuning round trip: compile(tune="measure") in a subprocess
# with a spy on autotune.measure_walltime, twice against the same cache
# dir.  The first process must MEASURE (cache miss -> store); the second
# must compile the cached winner measuring NOTHING (cache hit) — the
# persistent (program, spec fingerprint, backend) cache proven end-to-end.
_TUNE_SNIPPET = r"""
import json, jax
from repro.core import autotune
calls = {"n": 0}
_real = autotune.measure_walltime
def _spy(fn, repeats=3):
    calls["n"] += 1
    return _real(fn, repeats=1)
autotune.measure_walltime = _spy
from repro.weather import program as P
plan = P.compile(P.StencilProgram(grid_shape=(4, 16, 16)), tune="measure")
print("TUNE=" + json.dumps({"tile_ty": plan.tile_ty,
                            "measure_calls": calls["n"],
                            "stats": autotune.TUNE_CACHE_STATS}))
"""


def _measured_autotune_roundtrip() -> dict:
    """Run the two-process measured-tuning check; returns the JSON block
    (including per-process spy counts and the cache-hit verdict)."""
    def one(cache_dir: str) -> dict:
        env = dict(os.environ)
        env["REPRO_TUNE_CACHE"] = cache_dir
        env.setdefault("PYTHONPATH", "src")
        r = subprocess.run([sys.executable, "-c", _TUNE_SNIPPET], env=env,
                           capture_output=True, text=True, timeout=600)
        for line in r.stdout.splitlines():
            if line.startswith("TUNE="):
                return json.loads(line[len("TUNE="):])
        raise RuntimeError(f"measured-autotune subprocess failed: "
                           f"{r.stderr[-2000:]}")
    with tempfile.TemporaryDirectory(prefix="repro-tune-") as cache_dir:
        first = one(cache_dir)
        second = one(cache_dir)
    round_trip = (first["measure_calls"] > 0
                  and first["stats"]["stores"] == 1
                  and second["measure_calls"] == 0
                  and second["stats"]["hits"] == 1
                  and second["tile_ty"] == first["tile_ty"])
    return {"first": first, "second": second,
            "cache_round_trip": bool(round_trip)}


def run():
    # Every entry point below goes through an ExecutionPlan; the legacy
    # flag-soup shims are gone, and any stray DeprecationWarning from our
    # own modules still fails the benchmark loudly.
    with warnings.catch_warnings():
        warnings.filterwarnings("error", category=DeprecationWarning,
                                module=r"repro\..*")
        _run()


def _run():
    smoke = smoke_mode()
    grid = SMOKE_GRID if smoke else GRID
    iters, warmup = (1, 1) if smoke else (7, 2)
    st = fields.initial_state(jax.random.PRNGKey(0), grid,
                              ensemble=ENSEMBLE)
    n_fields = len(fields.PROGNOSTIC)
    backend = jax.default_backend()
    interp_note = ("" if backend == "tpu"
                   else " (Pallas interpreter — validates, not representative)")

    # ONE ExecutionPlan per measured configuration.
    def plan_for(variant, k=1):
        return compile_dycore(DycoreProgram(
            grid_shape=grid, ensemble=ENSEMBLE, variant=variant, k_steps=k))

    plans = {"unfused": plan_for("unfused"),
             "fused_per_field": plan_for("per_field"),
             "fused_whole_state": plan_for("whole_state"),
             "kstep_round": plan_for("kstep", k=KSTEP_K)}

    walltime = {}
    t_unfused = time_fn(plans["unfused"].step, st, iters=iters,
                        warmup=warmup)
    walltime["unfused"] = t_unfused
    emit("dycore_fused/walltime_unfused", t_unfused,
         f"grid={grid} ensemble={ENSEMBLE}")
    t_fused = time_fn(plans["fused_per_field"].step, st, iters=iters,
                      warmup=warmup)
    walltime["fused_per_field"] = t_fused
    emit("dycore_fused/walltime_fused", t_fused,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 4 launches{interp_note}")
    t_whole = time_fn(plans["fused_whole_state"].step, st, iters=iters,
                      warmup=warmup)
    walltime["fused_whole_state"] = t_whole
    emit("dycore_fused/walltime_whole_state", t_whole,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 1 launch, shared w{interp_note} "
         f"vs_per_field={t_fused / max(t_whole, 1e-9):.2f}x")
    # The k-step round: KSTEP_K timesteps in ONE launch (in-kernel scan,
    # state in VMEM between local steps) vs KSTEP_K whole-state launches.
    t_kstep = time_fn(plans["kstep_round"].step, st, iters=iters,
                      warmup=warmup)
    t_kseq = time_fn(
        lambda s: plans["fused_whole_state"].run(s, KSTEP_K), st,
        iters=iters, warmup=warmup)
    walltime["kstep_round"] = t_kstep
    walltime["kstep_scan_of_launches"] = t_kseq
    emit("dycore_fused/walltime_kstep", t_kstep,
         f"grid={grid} k={KSTEP_K} backend={backend} 1 launch/round"
         f"{interp_note} vs_scan={t_kseq / max(t_kstep, 1e-9):.2f}x")

    # --- the paper's PER-KERNEL table (ISSUE 5): hdiff-only, vadvc-only
    # and the fused compound step, side by side through the SAME planner.
    # Measured walltime at the bench grid; modeled GFLOPS / GFLOPS-per-watt
    # (core/perfmodel over the plan's auto-tuned tile) at the paper's
    # domain — the 12.7x/21.01-GF/W (hdiff) vs 5.3x/1.61-GF/W (vadvc) axis.
    # Modeled rows always use the paper's domain — modeling is analytic, so
    # smoke mode keeps the full-size numbers (CI asserts against them).
    model_grid = MODEL_GRID
    per_kernel = {}
    for key, op in (("hdiff", "hdiff"), ("vadvc", "vadvc"),
                    ("vadvc_update", "vadvc_update"),
                    ("hadv_upwind", "hadv_upwind"),
                    ("fused", "dycore")):
        plan = compile_dycore(StencilProgram(
            grid_shape=grid, ensemble=ENSEMBLE, op=op,
            variant="whole_state"))
        t = time_fn(plan.step, st, iters=iters, warmup=warmup)
        rep = plan.report()
        mrep = compile_dycore(StencilProgram(
            grid_shape=model_grid, ensemble=ENSEMBLE, op=op,
            variant="whole_state")).report()
        per_kernel[key] = {
            "op": op,
            "walltime_us": t,
            "modeled_gflops": mrep["model"]["gflops"],
            "modeled_gflops_per_watt": mrep["model"]["gflops_per_watt"],
            "modeled_time_us": mrep["model"]["time_us"],
            "flops_per_point": rep["footprint"]["flops_per_point"],
            "pallas_calls_per_round": rep["pallas_calls_per_round"],
            "plan": rep,
            "model_plan": mrep,
        }
        emit(f"dycore_fused/per_kernel_{key}", t,
             f"grid={grid} op={op} "
             f"model_gflops={mrep['model']['gflops']:.0f} "
             f"model_gflops_per_watt={mrep['model']['gflops_per_watt']:.2f}"
             f"{interp_note}")

    # --- flagship CHAINED pipeline (ISSUE 10): the three stages as ONE
    # plan — one fused exchange pair per direction, launches in order on
    # resident operands.  Measured walltime vs the three solo rows above;
    # modeled rows at the paper's domain carry the chained-vs-sequential
    # HBM stream (intermediates stay out of HBM) and the packed-wire model
    # (2 exchange rounds regardless of chain length vs one round set PER
    # STAGE sequentially — the chain ships deeper footprints, so its win
    # is ROUND COUNT/latency, not bytes; both sides are reported).
    pipe_stages = ("hadv_upwind", "vadvc_update", "hdiff")
    pipe_plan = compile_dycore(PipelineProgram(
        grid_shape=grid, ensemble=ENSEMBLE, coeff=0.05,
        variant="whole_state", k_steps=1, stages=pipe_stages))
    t_pipe = time_fn(pipe_plan.step, st, iters=iters, warmup=warmup)
    t_solo_sum = sum(per_kernel[k_]["walltime_us"] for k_ in pipe_stages)
    rep = pipe_plan.report()
    mrep = compile_dycore(PipelineProgram(
        grid_shape=model_grid, ensemble=ENSEMBLE, coeff=0.05,
        variant="whole_state", k_steps=1, stages=pipe_stages)).report()
    mt = mrep["traffic"]

    def _wire(opdef):
        return memmodel.packed_exchange_model(
            model_grid, "float32", rides=opdef.memmodel_rides(n_fields),
            k=1, shards=(2, 2), compute_halo=(opdef.halo, opdef.halo))

    w_chain = _wire(stencil_ops.get_stencil_op(rep["op"]))
    w_stage = {op: _wire(stencil_ops.get_stencil_op(op))
               for op in pipe_stages}
    wire = {
        "chained_bytes": w_chain["bytes_kstep"],
        "sequential_bytes": sum(w["bytes_kstep"]
                                for w in w_stage.values()),
        "chained_rounds": w_chain["rounds_kstep"],
        "sequential_rounds": sum(w["rounds_kstep"]
                                 for w in w_stage.values()),
        "by_stage_bytes": {op: w["bytes_kstep"]
                           for op, w in w_stage.items()},
    }
    per_kernel["pipeline"] = {
        "op": rep["op"],
        "stages": list(pipe_stages),
        "walltime_us": t_pipe,
        "walltime_sequential_us": t_solo_sum,
        "modeled_gflops": mrep["model"]["gflops"],
        "modeled_gflops_per_watt": mrep["model"]["gflops_per_watt"],
        "modeled_time_us": mrep["model"]["time_us"],
        "flops_per_point": rep["footprint"]["flops_per_point"],
        "pallas_calls_per_round": rep["pallas_calls_per_round"],
        "hbm_chained_per_round": mt["chained_per_round"],
        "hbm_sequential_per_round": mt["sequential_per_round"],
        "hbm_chained_reduction_x": mt["chained_reduction_x"],
        "hbm_sequential_by_stage": mt["sequential_by_stage"],
        "wire": wire,
        "plan": rep,
        "model_plan": mrep,
    }
    emit("dycore_fused/per_kernel_pipeline", t_pipe,
         f"grid={grid} stages={'->'.join(pipe_stages)} "
         f"vs_sequential={t_solo_sum / max(t_pipe, 1e-9):.2f}x "
         f"hbm_reduction={mt['chained_reduction_x']:.2f}x "
         f"wire_rounds={wire['chained_rounds']}v{wire['sequential_rounds']}"
         f"{interp_note}")

    # Modeled HBM traffic at the paper's domain: ONE model-grid plan per
    # dtype; its report() embeds the memmodel accounting at the plan's own
    # auto-tuned tile.
    traffic = {}
    for dtype in ("float32", "bfloat16"):
        model_plan = compile_dycore(DycoreProgram(
            grid_shape=model_grid, ensemble=ENSEMBLE, dtype=dtype,
            variant="kstep", k_steps=KSTEP_K))
        rep = model_plan.report()
        t = rep["traffic"]
        ty = rep["tile"]["ty"]
        traffic[dtype] = {
            "unfused": t["unfused"]["total"],
            "fused_per_field": t["fused"]["total"],
            "fused_whole_state": t["fused_whole"]["total"],
            "fused_kstep": t["fused_kstep"]["total"],
            "fused_kstep_scan": t["fused_kstep"]["scan_total"],
            "interstep_state": t["fused_kstep"]["interstep_state"],
            "interstep_state_scan": t["fused_kstep"]["interstep_state_scan"],
            "reduction_x_whole": t["reduction_x_whole"],
            "interstep_reduction_x": t["interstep_reduction_x"],
        }
        mb = 1.0 / 2**20
        emit(f"dycore_fused/traffic_unfused_{dtype}", 0.0,
             f"MB={t['unfused']['total'] * mb:.0f} "
             f"vadvc={t['unfused']['vadvc'] * mb:.0f} "
             f"pointwise={t['unfused']['pointwise'] * mb:.0f} "
             f"hdiff={(t['unfused']['hdiff'] + t['unfused']['hdiff_pad']) * mb:.0f}")
        emit(f"dycore_fused/traffic_fused_{dtype}", 0.0,
             f"MB={t['fused']['total'] * mb:.0f} ty={ty} "
             f"halo_overhead={t['halo_overhead'] * 100:.1f}% "
             f"reduction={t['reduction_x']:.2f}x "
             f"(aliased-window pessimistic bound: "
             f"MB={t['fused']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_window_reads']:.2f}x)")
        emit(f"dycore_fused/traffic_whole_state_{dtype}", 0.0,
             f"MB={t['fused_whole']['total'] * mb:.0f} ty={ty} "
             f"reduction={t['reduction_x_whole']:.2f}x "
             f"vs_per_field="
             f"{t['fused']['total'] / max(t['fused_whole']['total'], 1):.3f}x "
             f"(pessimistic bound: "
             f"MB={t['fused_whole']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_whole_window_reads']:.2f}x)")
        emit(f"dycore_fused/traffic_kstep_{dtype}", 0.0,
             f"MB={t['fused_kstep']['total'] * mb:.0f}/round k={KSTEP_K} "
             f"vs_scan={t['reduction_x_kstep_vs_scan']:.2f}x "
             f"interstep_state_MB={t['fused_kstep']['interstep_state'] * mb:.0f}"
             f" vs {t['fused_kstep']['interstep_state_scan'] * mb:.0f} "
             f"({t['interstep_reduction_x']:.0f}x fewer HBM state "
             f"round-trips)")

        # Modeled TPU time for the fused plan (per field pipeline pass).
        plan = tiling.TilePlan(op=tiling.DYCORE_FUSED, grid_shape=model_grid,
                               tile=(model_grid[0], ty, model_grid[2]),
                               dtype=dtype)
        est = perfmodel.estimate(plan)
        emit(f"dycore_fused/model_fused_{dtype}",
             est.time_s * n_fields * 1e6,
             f"bottleneck={est.bottleneck} gflops={est.gflops:.0f} "
             f"vmem={100.0 * plan.vmem_bytes / hw.tpu_v5e().vmem.capacity_bytes:.0f}%")

    # Communication-avoiding k-step exchange model (weather/program.py).
    kstep = {}
    for k in (1, 2, 4):
        try:
            m = memmodel.kstep_exchange_model(model_grid, "float32",
                                              n_fields=n_fields, k=k)
        except ValueError:
            continue
        kstep[str(k)] = m
        emit(f"dycore_fused/kstep_k{k}", 0.0,
             f"rounds={m['rounds_kstep']}v{m['rounds_sequential']} "
             f"bytes_ratio={m['bytes_ratio']:.2f} "
             f"redundant_flops={m['redundant_flops_frac'] * 100:.0f}%")

    # Structural counts of the k-step round — the regression guard that is
    # immune to interpreter-walltime noise: the single-chip round must be
    # ONE pallas_call; the distributed round additionally one ppermute pair
    # per mesh direction — and the plan's own report() must agree with the
    # trace (asserted in the subprocess via assert_plan_structure).
    local_kplan = compile_dycore(DycoreProgram(
        grid_shape=SMOKE_GRID, variant="kstep", k_steps=KSTEP_K),
        interpret=True)
    st_small = fields.initial_state(jax.random.PRNGKey(0), SMOKE_GRID)
    j = jax.make_jaxpr(lambda s: local_kplan.run(s, KSTEP_K))(st_small)
    calls_local = trace_stats.count_primitive(j, "pallas_call")
    try:
        struct, plan_rep = _kstep_round_structure(KSTEP_K)
        plan_source = "distributed_subprocess"
    except (RuntimeError, subprocess.SubprocessError) as e:
        print(f"# distributed structure trace unavailable: {e}")
        struct = {"pallas_call": calls_local, "ppermute": None}
        plan_rep = local_kplan.report()
        plan_source = "local_fallback"
    calls_round = max(calls_local, struct["pallas_call"])
    emit("dycore_fused/kstep_structure", 0.0,
         f"pallas_calls_per_round={calls_round} "
         f"collectives_per_round={struct['ppermute']} k={KSTEP_K}")

    # Cross-machine model blocks at the paper's domain (all analytic), and
    # the measured-autotune persistent-cache round trip (two subprocesses
    # sharing one REPRO_TUNE_CACHE dir; CI asserts cache_round_trip).
    model_by_hardware = per_kernel["fused"]["model_plan"]["model_by_hardware"]
    try:
        measured_autotune = _measured_autotune_roundtrip()
    except (RuntimeError, subprocess.SubprocessError) as e:
        print(f"# measured-autotune round trip unavailable: {e}")
        measured_autotune = {"cache_round_trip": False, "error": str(e)}
    emit("dycore_fused/measured_autotune", 0.0,
         f"cache_round_trip={measured_autotune['cache_round_trip']} "
         f"tile_ty={measured_autotune.get('first', {}).get('tile_ty')}")

    write_json("BENCH_dycore.json", {
        "grid": list(grid),
        "model_grid": list(model_grid),
        "ensemble": ENSEMBLE,
        "n_fields": n_fields,
        "k_steps": KSTEP_K,
        "pallas_calls_per_round": calls_round,
        "collectives_per_round": struct["ppermute"],
        # The distributed k-step plan's full report(), embedded VERBATIM —
        # variant, tile, k_steps, exchange schedule (incl. wire dtype),
        # structural counts, modeled traffic.  plan_source says whether it
        # really came from the forced-4-device trace or the single-chip
        # fallback (exchange=None) when that subprocess was unavailable —
        # cross-PR diffs must not mix the two silently.
        "plan": plan_rep,
        "plan_source": plan_source,
        # One report per measured single-chip configuration.
        "plans": {name: p.report() for name, p in plans.items()},
        # The paper's two-kernel table: hdiff vs vadvc vs fused, each with
        # measured walltime + modeled GFLOPS from its own compiled plan.
        "per_kernel": per_kernel,
        "walltime_us": walltime,
        # steps_per_s counts SIMULATED timesteps: the kstep entries' walltime
        # covers a whole KSTEP_K-step round, the others a single step.
        "steps_per_s": {
            k: (KSTEP_K if k.startswith("kstep") else 1) * 1e6
            / max(v, 1e-9) for k, v in walltime.items()},
        "modeled_hbm_bytes": traffic,
        "kstep_exchange": kstep,
        # The paper's cross-machine table (NERO vs POWER9 vs v5e) at the
        # paper's domain, from the fused model-grid plan's report, plus the
        # spec-derived energy/roofline blocks and the measured-autotune
        # persistent-cache proof.  bench-smoke asserts all four.
        "model_by_hardware": model_by_hardware,
        "energy_by_hardware": energy_block(MODEL_GRID),
        "roofline_by_hardware": roofline_block(MODEL_GRID),
        "measured_autotune": measured_autotune,
    })

    if calls_round > 1:
        # Structural regression: the k-step round fragmented into multiple
        # launches.  Fail the bench (and the CI smoke job) loudly.
        raise SystemExit(
            f"k-step structural regression: {calls_round} pallas_calls per "
            f"round (expected 1)")


if __name__ == "__main__":
    run()
