"""Oracle for the COSMO copy stencil (paper Fig. 2b): element-wise identity.

The simplest COSMO stencil; it characterizes achievable memory bandwidth of
the platform (the paper uses it to find the PE-saturation point of HBM)."""

from __future__ import annotations

import jax.numpy as jnp


def copy_stencil(src: jnp.ndarray) -> jnp.ndarray:
    return src + jnp.zeros_like(src)   # forces a real read+write pair
