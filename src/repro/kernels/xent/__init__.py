from repro.kernels.xent.xent import xent_pallas
from repro.kernels.xent.ops import fused_xent_mean
from repro.kernels.xent import ref

__all__ = ["xent_pallas", "fused_xent_mean", "ref"]
