"""copy stencil + lru_scan kernels vs oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.copy_stencil.copy_stencil import copy_pallas
from repro.kernels.copy_stencil.ref import copy_stencil as copy_ref


@pytest.mark.parametrize("shape,tr", [((64, 128), 16), ((256, 256), 64),
                                      ((512, 128), 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_copy(shape, tr, dtype, rng):
    src = jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype)
    got = copy_pallas(src, tr=tr, interpret=True)
    assert got.dtype == src.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(copy_ref(src), np.float32))


def test_lru_scan_kernel_matches_associative_scan(rng):
    from repro.kernels.lru_scan.ops import lru_scan as lru_op
    from repro.kernels.lru_scan.ref import lru_scan_ref
    for (t, c), (tt, tc) in [((32, 64), (8, 32)), ((64, 128), (16, 128)),
                             ((16, 32), (16, 16))]:
        a = jnp.asarray(
            rng.uniform(0.3, 0.99, size=(t, c)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(t, c)).astype(np.float32))
        want = np.asarray(lru_scan_ref(a, b))
        got = np.asarray(lru_op(a, b, tt=tt, tc=tc, use_pallas=True,
                                interpret=True))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
