"""repro.serve subpackage."""
