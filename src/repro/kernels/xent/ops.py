"""Jitted wrapper for the fused cross-entropy kernel: padding + mean."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.xent.xent import xent_pallas


@functools.partial(jax.jit, static_argnames=("vocab", "softcap",
                                             "interpret"))
def fused_xent_mean(hidden, head, targets, *, vocab: int = 0,
                    softcap: float = 0.0, interpret: bool = False):
    """Mean next-token NLL over (B, T) without materializing logits.

    hidden: (B, T, D); head: (D, Vp); targets: (B, T).  Pads rows to the
    block multiple with valid=0 (padding rows contribute nothing)."""
    b, t, d = hidden.shape
    n = b * t
    h = hidden.reshape(n, d)
    tg = targets.reshape(n)
    valid = jnp.ones((n,), jnp.float32)
    bn = min(128, n) if n % 128 else 128
    pad = (-n) % max(bn, 1)
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        tg = jnp.pad(tg, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    nll = xent_pallas(h, head, tg, valid, vocab=vocab, softcap=softcap,
                      block_n=min(128, h.shape[0]),
                      block_v=min(512, head.shape[1]),
                      interpret=interpret)
    return nll.sum() / n
