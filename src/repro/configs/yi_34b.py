"""Yi-34B — llama-arch GQA dense LM [arXiv:2403.04652; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    pattern=("attn",), rope_theta=5e6,
    norm="rms", gated_mlp=True, act="silu",
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
