"""Render EXPERIMENTS.md tables from the dry-run JSON cache.

Usage: PYTHONPATH=src python -m benchmarks.make_tables [variant]
Prints markdown: §Dry-run fit table, §Roofline term table, §Perf variant
comparisons (baseline vs every non-baseline variant present per cell).
"""

from __future__ import annotations

import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(mesh=None, variant="baseline"):
    out = {}
    for p in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        d = json.load(open(p))
        if d.get("variant") != variant:
            continue
        if mesh and d["mesh"] != mesh:
            continue
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(mesh="single"):
    rows = load(mesh=mesh)
    print(f"\n### Roofline — {mesh} pod "
          f"({'256' if mesh == 'single' else '512'} chips), baseline\n")
    print("| arch | shape | mb | compute s | memory s | collective s | "
          "dominant | useful | roofline % | GB/dev | fit |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, _), d in sorted(rows.items()):
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | — | — | — | — | *skipped:"
                  f" {d['reason']}* | — | — | — | — |")
            continue
        if d["status"] != "ok":
            print(f"| {arch} | {shape} | — | ERROR | | | | | | | |")
            continue
        r = d["roofline"]
        mem = d["memory"]["analytic"]["total"]
        print(f"| {arch} | {shape} | {d.get('microbatches', 1)} "
              f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
              f"| {r['collective_s']:.3f} | **{r['dominant']}** "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {100 * r['roofline_fraction']:.2f} "
              f"| {fmt_bytes(mem)} | {d['memory']['fits_16g']} |")


def variant_table():
    allv = {}
    for p in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        d = json.load(open(p))
        if d["status"] != "ok":
            continue
        key = (d["arch"], d["shape"], d["mesh"])
        allv.setdefault(key, {})[d["variant"]] = d
    print("\n### §Perf variants (hillclimbed cells)\n")
    print("| cell | variant | compute s | memory s | collective s | "
          "dominant | bound s | roofline % |")
    print("|---|---|---|---|---|---|---|---|")
    for key, vs in sorted(allv.items()):
        if len(vs) < 2:
            continue
        order = ["baseline"] + sorted(v for v in vs if v != "baseline")
        for v in order:
            d = vs[v]
            r = d["roofline"]
            cell = f"{key[0]} {key[1]} {key[2]}" if v == "baseline" else ""
            print(f"| {cell} | {v} | {r['compute_s']:.3f} "
                  f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
                  f"| {r['dominant']} | {r['step_time_bound_s']:.3f} "
                  f"| {100 * r['roofline_fraction']:.2f} |")


def main():
    roofline_table("single")
    roofline_table("multi")
    variant_table()


if __name__ == "__main__":
    main()
