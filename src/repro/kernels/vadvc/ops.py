"""Jitted public entry points for vadvc (planner-aware dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels.vadvc import ref as _ref
from repro.kernels.vadvc.vadvc import vadvc_pallas


def plan_tile(grid_shape, dtype):
    """Auto-tuned (tj, ti) horizontal window (paper's 64x2 fp32 analogue)."""
    tuned = autotune.tune_named("vadvc", grid_shape, dtype)
    _, tj, ti = tuned.plan.tile
    nz, ny, nx = grid_shape

    def snap(t, n):
        while n % t:
            t //= 2
        return max(1, t)

    return snap(tj, ny), snap(ti, nx)


@functools.partial(jax.jit, static_argnames=("use_pallas", "tj", "ti",
                                             "interpret"))
def vadvc(u_stage, wcon, u_pos, utens, utens_stage,
          use_pallas: bool = False, tj: int = 0, ti: int = 0,
          interpret: bool = True):
    if use_pallas:
        if not (tj and ti):
            tj, ti = plan_tile(u_stage.shape, u_stage.dtype)
        return vadvc_pallas(u_stage, wcon, u_pos, utens, utens_stage,
                            tj=tj, ti=ti, interpret=interpret)
    return _ref.vadvc(u_stage, wcon, u_pos, utens, utens_stage)
