"""Version compatibility shims shared by the Pallas kernel packages.

jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams` (~0.5.x);
this container ships 0.4.x.  Kernels import `CompilerParams` from here so
they build against either spelling.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
