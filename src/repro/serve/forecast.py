"""Forecast-as-a-service: a continuous-batching ensemble serving engine.

An operational forecast service runs the SAME compiled stencil programs
for many concurrent consumers — requests differ only in initial state and
step count, over a handful of plans.  This engine is that service layer
over the plan API (`weather/program.py`):

* **Plan cache, compile once / serve forever.**  Every request names a
  `StencilProgram` (ensemble 1 — one forecast).  The engine canonicalizes
  it with `program.plan_cache_key(prog, ensemble=slots)` and compiles at
  most ONE `ExecutionPlan` per distinct program, shared by every request
  that ever arrives for it.

* **Continuous batching into the ensemble axis.**  The `(e, ...)` fold is
  already the batch dimension of every kernel, so admission is a slot
  scatter (`ensemble_slot_assign`) into a zero-initialized batch state,
  and each engine round is ONE `plan.step` launch for up to `slots`
  concurrent forecasts.  Finished slots retire at round boundaries and
  are backfilled from the queue — the batch never drains to serve a
  straggler.

* **Bit-identical to solo runs.**  The correctness contract (verified by
  `tests/test_forecast_engine.py`'s property harness) is that serving a
  request batched is bit-identical to `compile(program).run(state,
  steps)` solo.  Two facts make that hold: ensemble members are computed
  independently (no cross-slot arithmetic, tile resolution per-member
  invariant), and the engine advances every request through EXACTLY the
  round sequence a solo `run()` would — `floor(steps/k)` full rounds plus
  one ragged tail of `steps mod k`, via the plan's own
  `round_plan(k')` tail machinery.  When ragged step counts force a
  shorter round than some co-batched slot's next canonical part, that
  slot runs the round anyway (slots advance together) but is ROLLED BACK
  (`ensemble_slot_select`) and not credited, so its realized sequence
  never deviates.  With `k_steps == 1` (every single-chip auto plan)
  rounds are single steps and no rollback ever happens.

* **Host I/O overlaps device compute.**  `submit` stages request arrays
  onto the device immediately (`jax.device_put` is async), so by the time
  a slot frees the admission wave's data is already resident; the slot
  scatter donates the old batch buffer on backends that support donation.
  Retirement reads back exactly one slot.

* **Warm restarts, on ANY mesh.**  `checkpoint()` persists the whole
  engine — batched in-flight state (gathered unsharded-logical), queue,
  finished results, per-request bookkeeping, and each lane's RESOLVED
  round strategy — through `ckpt.save_tree`; `ForecastEngine.restore()`
  resumes mid-forecast in a fresh process on whatever mesh it is given:
  a checkpoint written single-chip restores onto 4 devices and vice
  versa (lane batches reshard through the new plan's `state_spec`, plans
  recompile through the plan cache — still compile-once per mesh shape).
  The persisted (variant, k_steps) pin keeps every in-flight request's
  canonical round sequence intact across the transition; see
  docs/robustness.md for the mesh-compatibility matrix of which
  transitions additionally preserve exact bits.  When the newest
  checkpoint is corrupt, restore-from-latest falls back to the previous
  valid one instead of dying.

* **Supervised, safe to run unattended.**  One shared batch means one
  poisoned request could take down every co-scheduled forecast — so the
  engine supervises itself (docs/robustness.md):

  - *Validity guards*: at every round boundary a cheap fused NaN/Inf +
    bounds reduction (`program.slot_validity`) checks every slot; an
    invalid slot is QUARANTINED — its request returns `status="failed"`
    with a per-field diagnosis, the slot is re-zeroed (zeros are a
    stencil fixed point) and backfills from the queue — while every
    healthy slot keeps its exact bits (the guard only reads).
  - *Fingerprint guards*: the same fused pass (`program.slot_guard`)
    digests every slot's exact bits into a sharding-invariant uint32.
    Slots that did NOT advance a round — rolled-back and idle slots —
    must keep their digest bit-for-bit; a mismatch is cross-device/shard
    divergence (a corrupted halo wire buffer, silent per-shard rot) that
    NaN/magnitude checks can never see, caught at the round boundary
    where it occurred.  Divergent in-flight slots quarantine with a
    `fingerprint_divergence` diagnosis; divergent idle slots are
    scrubbed.
  - *Mesh failover*: on a persistent device loss, instead of failing the
    lane the engine rebuilds a mesh from the surviving devices
    (`domain.failover_meshes`, preferring shapes that keep every
    sharded axis sharded — the bitwise-safe transitions), recompiles the
    plans (pinned round depth), reshards every lane's pre-round state,
    and RERUNS the interrupted round — every in-flight request resumes
    from the last round boundary; `stats()` records `mesh_failovers`,
    `recovery_rounds`, `requests_preserved`, and a per-failover detail
    list.
  - *Round deadline watchdog*: `round_deadline_s` bounds each round
    attempt's wall clock; a straggling/hung collective counts as a
    failed attempt and goes through the same retry/degrade/failover
    escalation instead of wedging the engine.
  - *Graceful degradation*: plan compilation goes through
    `program.compile_with_fallback` (native → interpret → reference
    lowering); a failed round retries with exponential backoff, then
    degrades the plan, then fails only that lane's in-flight requests
    with a diagnosis — never the whole engine.
  - *Backpressure + deadlines*: `max_queue` bounds the queue (`submit`
    raises `QueueFullError` instead of accepting unbounded work);
    per-request `deadline_s` expires stale work at round boundaries.
  - *Watchdog*: `ckpt_every_rounds=N` auto-checkpoints every N rounds so
    a crash resumes from the last round boundary bitwise-equal to an
    uninterrupted run.
  - *Rehearsed in CI*: every one of these paths is driven
    deterministically by `repro.testing.faults.FaultInjector` (the
    engine's `fault_injector` hook) in the chaos test suite.

See docs/serving.md for the lifecycle diagrams and BENCH_serve.json for
the latency/occupancy numbers under synthetic load.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.weather import domain as _domain
from repro.weather import fields as _fields
from repro.weather import program as _wprog
from repro.weather.fields import WeatherState

__all__ = ["ForecastRequest", "ForecastResult", "ForecastEngine",
           "QueueFullError", "RoundDeadlineError", "STATUSES"]

# Result statuses (see docs/serving.md for the full table):
#   ok       — served; state is bit-identical to the solo run
#   failed   — quarantined by the validity guard or a persistent round
#              failure; `diagnosis` says why, `state` is the last state
#   expired  — per-request deadline passed before completion
STATUSES = ("ok", "failed", "expired")


class QueueFullError(RuntimeError):
    """`submit()` refused a request: the bounded queue is full.  This is
    explicit backpressure — retry later or raise `max_queue`; silently
    buffering unbounded work is how a service dies of memory instead."""


class RoundDeadlineError(RuntimeError):
    """A round attempt exceeded `round_deadline_s` — a straggling or hung
    collective.  Raised inside the supervised retry scope so it escalates
    through the same retry → degrade → failover ladder as any other round
    failure instead of wedging the engine."""


@dataclasses.dataclass
class ForecastRequest:
    """One forecast: a program (the *what*, ensemble 1), its initial
    state ((1, nz, ny, nx) leaves), and how many timesteps to advance."""

    program: _wprog.StencilProgram
    state: WeatherState
    steps: int
    rid: Optional[int] = None                   # assigned by submit()
    deadline_s: Optional[float] = None          # wall-clock budget from submit

    def validate(self) -> None:
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(f"deadline_s={self.deadline_s!r} must be a "
                             f"positive number of seconds (or None)")
        if self.program.ensemble != 1:
            raise ValueError(f"a request is ONE forecast: program.ensemble "
                             f"must be 1, got {self.program.ensemble}")
        if not isinstance(self.steps, int) or self.steps < 0:
            raise ValueError(f"steps={self.steps!r} must be a "
                             f"non-negative int")
        if self.state.grid_shape != self.program.grid_shape:
            raise ValueError(f"state grid {self.state.grid_shape} != "
                             f"program grid {self.program.grid_shape}")
        if str(self.state.wcon.dtype) != self.program.dtype:
            raise ValueError(f"state dtype {self.state.wcon.dtype} != "
                             f"program dtype {self.program.dtype}")
        if set(self.state.fields) != set(self.program.fields):
            raise ValueError(f"state fields {sorted(self.state.fields)} != "
                             f"program fields {sorted(self.program.fields)}")
        if int(self.state.wcon.shape[0]) != 1:
            raise ValueError("request state must have a leading ensemble "
                             "dim of 1")


@dataclasses.dataclass
class ForecastResult:
    """A finished forecast: the final state plus honest per-request
    accounting — `latency_s` is THIS request's admit-to-finish wall time
    (not its wave's), `queue_wait_s` the time it sat unadmitted."""

    rid: int
    program: _wprog.StencilProgram
    state: WeatherState                         # (1, ...) leaves, host-side
    steps: int
    latency_s: float
    queue_wait_s: float
    rounds: int
    status: str = "ok"                          # one of STATUSES
    steps_done: Optional[int] = None            # == steps when status=="ok"
    diagnosis: Optional[Dict[str, Any]] = None  # why, when status != "ok"

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass
class _Slot:
    rid: int
    remaining: int
    steps: int
    admit_t: float
    queue_wait_s: float
    rounds: int = 0
    deadline_s: Optional[float] = None

    @property
    def submit_t(self) -> float:
        return self.admit_t - self.queue_wait_s


@dataclasses.dataclass
class _Lane:
    """One plan's batch: all slots share the lane's compiled plan."""

    key: _wprog.StencilProgram                  # canonical, ensemble=slots
    batch: WeatherState                         # (slots, nz, ny, nx) leaves
    slots: List[Optional[_Slot]]
    # Per-slot content digests recorded at round boundaries (slot index ->
    # uint32 as int).  Sharding-invariant, so they survive a failover
    # reshard and keep guarding across it.  Entries are dropped whenever a
    # slot's bits legitimately get new content (admit, scrub).
    fps: Dict[int, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Pending:
    request: ForecastRequest
    submit_t: float
    counted: bool = False       # plan-cache hit/miss recorded once only


class ForecastEngine:
    """Continuous-batching forecast service over cached ExecutionPlans.

    `submit()` enqueues (and stages arrays onto the device), `pump()`
    admits + advances every busy lane one round, `drain()` pumps until
    idle and returns `{rid: ForecastResult}`.  `checkpoint()` /
    `ForecastEngine.restore()` persist and resume the warm engine."""

    def __init__(self, slots: int = 4, mesh=None,
                 interpret: Optional[bool] = None, ax_e: str = "pod",
                 ax_y: str = "data", ax_x: str = "model",
                 ckpt_dir: Optional[str] = None, ckpt_keep: int = 3,
                 max_queue: Optional[int] = None, guard: bool = True,
                 guard_limit: float = 1e6,
                 ckpt_every_rounds: Optional[int] = None,
                 max_round_retries: int = 2, retry_backoff_s: float = 0.05,
                 fault_injector=None, failover: bool = True,
                 round_deadline_s: Optional[float] = None):
        if slots < 1:
            raise ValueError(f"slots={slots} must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue={max_queue} must be >= 1 (or None "
                             f"for unbounded)")
        self.slots = slots
        self.mesh = mesh
        self.interpret = interpret
        self.mesh_axes = (ax_e, ax_y, ax_x)
        self.ckpt_dir = ckpt_dir
        self.ckpt_keep = ckpt_keep
        self.max_queue = max_queue
        self.guard = guard
        self.guard_limit = float(guard_limit)
        self.ckpt_every_rounds = ckpt_every_rounds
        self.max_round_retries = max_round_retries
        self.retry_backoff_s = retry_backoff_s
        self.fault_injector = fault_injector
        self.failover = failover
        self.round_deadline_s = round_deadline_s

        self._queue: collections.deque[_Pending] = collections.deque()
        self._lanes: Dict[_wprog.StencilProgram, _Lane] = {}
        self._plans: Dict[_wprog.StencilProgram, _wprog.ExecutionPlan] = {}
        self._fallbacks: Dict[_wprog.StencilProgram, Dict[str, Any]] = {}
        # First-resolution (variant, k_steps) per program key.  A lane's
        # canonical round sequence is fixed the moment its plan first
        # compiles; recompiles on a DIFFERENT mesh (failover, elastic
        # restore) re-pin the same round depth so every in-flight
        # request's realized [k, ..., k, tail] sequence — and therefore
        # its bit-identity contract — survives the mesh change.
        self._pinned: Dict[_wprog.StencilProgram, Dict[str, Any]] = {}
        self._failovers: List[Dict[str, Any]] = []
        self._results: Dict[int, ForecastResult] = {}
        self._next_rid = 0
        self._ckpt_step = 0
        self._last_ckpt_round = 0
        self._stats = {"plan_cache_hits": 0, "plan_cache_misses": 0,
                       "rounds": 0, "admitted": 0, "completed": 0,
                       "rolled_back_slot_rounds": 0,
                       "occupancy_sum": 0.0, "occupancy_samples": 0,
                       "quarantined": 0, "scrubbed_idle_slots": 0,
                       "round_retries": 0, "lane_failures": 0,
                       "fallback_compiles": 0, "rejected": 0,
                       "deadline_expired": 0, "watchdog_checkpoints": 0,
                       "mesh_failovers": 0, "recovery_rounds": 0,
                       "requests_preserved": 0, "fingerprint_divergence": 0,
                       "round_deadline_hits": 0, "plan_repins": 0}
        # Donating the pre-admission batch buffer lets XLA reuse it for
        # the scattered batch; CPU has no donation (it would only warn).
        donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        self._assign = jax.jit(_wprog.ensemble_slot_assign,
                               donate_argnums=donate)

    # -- public API ---------------------------------------------------------
    def submit(self, request: ForecastRequest) -> int:
        """Enqueue one forecast; returns its rid.  The initial state is
        device_put NOW (async) so admission later is a device-side
        scatter — staging hides behind whatever round is running.

        Raises `QueueFullError` when `max_queue` is set and the queue is
        at capacity — explicit backpressure, not silent buffering."""
        request.validate()
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            self._stats["rejected"] += 1
            raise QueueFullError(
                f"queue is full ({len(self._queue)}/{self.max_queue} "
                f"pending, slots={self.slots}): the engine is saturated — "
                f"retry after a pump()/drain(), shed load upstream, or "
                f"raise max_queue")
        if request.rid is None:
            request.rid = self._next_rid
        self._next_rid = max(self._next_rid, request.rid) + 1
        request.state = jax.device_put(request.state)
        self._queue.append(_Pending(request, time.perf_counter()))
        return request.rid

    def has_work(self) -> bool:
        return bool(self._queue) or any(
            any(s is not None for s in lane.slots)
            for lane in self._lanes.values())

    def pump(self) -> bool:
        """Admit whatever fits, advance every busy lane ONE round, retire
        finished slots.  Returns `has_work()`.  With `ckpt_every_rounds`
        set (and a ckpt_dir), the watchdog auto-checkpoints at the pump
        boundary — every lane sits at a round boundary there, so a crash
        resumes bitwise-equal to an uninterrupted run."""
        self._admit()
        for lane in self._lanes.values():
            if any(s is not None for s in lane.slots):
                self._round(lane)
        if (self.ckpt_every_rounds and self.ckpt_dir is not None
                and self._stats["rounds"] - self._last_ckpt_round
                >= self.ckpt_every_rounds):
            self.checkpoint()
            self._last_ckpt_round = self._stats["rounds"]
            self._stats["watchdog_checkpoints"] += 1
        return self.has_work()

    def drain(self) -> Dict[int, ForecastResult]:
        """Pump until idle; returns ALL results finished so far."""
        while self.pump():
            pass
        return dict(self._results)

    @property
    def results(self) -> Dict[int, ForecastResult]:
        return dict(self._results)

    def stats(self) -> Dict[str, Any]:
        """Service counters: plan-cache hit rate, mean batch occupancy
        (active slots / slots over lane-rounds), rounds/admissions."""
        s = dict(self._stats)
        lookups = s["plan_cache_hits"] + s["plan_cache_misses"]
        s["plan_cache_hit_rate"] = (
            s["plan_cache_hits"] / lookups if lookups else None)
        s["occupancy"] = (s["occupancy_sum"] / s["occupancy_samples"]
                          if s["occupancy_samples"] else 0.0)
        s["plans_cached"] = len(self._plans)
        s["queued"] = len(self._queue)
        s["active"] = sum(sum(sl is not None for sl in lane.slots)
                          for lane in self._lanes.values())
        s["failed"] = sum(1 for r in self._results.values()
                          if r.status == "failed")
        s["expired"] = sum(1 for r in self._results.values()
                           if r.status == "expired")
        s["plan_fallbacks"] = {k.op: v["stage"]
                               for k, v in self._fallbacks.items()}
        s["failovers"] = [dict(f) for f in self._failovers]
        s["mesh_devices"] = (None if self.mesh is None
                             else [int(d.id) for d in
                                   self.mesh.devices.flat])
        return s

    # -- scheduling ---------------------------------------------------------
    def _plan_for(self, key: _wprog.StencilProgram) -> _wprog.ExecutionPlan:
        plan = self._plans.get(key)
        if plan is None:
            ax_e, ax_y, ax_x = self.mesh_axes
            inj = self.fault_injector
            prog = key
            pinned = self._pinned.get(key)
            if pinned is not None:
                # Recompiling an already-served program (failover/elastic
                # restore): pin the FIRST resolution's round strategy so
                # in-flight canonical round sequences stay intact.  If the
                # pinned depth cannot compile on this mesh (e.g. a deep k
                # on a tiny shard), fall back to re-resolving — requests
                # still complete, bit-identity becomes best-effort, and
                # `plan_repins` records that it happened.
                prog = dataclasses.replace(key, variant=pinned["variant"],
                                           k_steps=pinned["k_steps"])
                try:
                    _wprog.compile(prog, mesh=self.mesh, ax_e=ax_e,
                                   ax_y=ax_y, ax_x=ax_x,
                                   interpret=self.interpret)
                except Exception:  # noqa: BLE001 — planner rejection
                    self._stats["plan_repins"] += 1
                    prog = key
            # Compile through the fallback chain (native -> interpret ->
            # reference lowering), via the module so a test spy on
            # repro.weather.program.compile observes every compilation.
            plan, fallback, errors = _wprog.compile_with_fallback(
                prog, mesh=self.mesh, ax_e=ax_e, ax_y=ax_y, ax_x=ax_x,
                interpret=self.interpret,
                attempt_hook=inj.on_compile if inj is not None else None)
            if fallback is not None:
                self._stats["fallback_compiles"] += 1
                self._fallbacks[key] = {"stage": fallback, "errors": errors}
            self._plans[key] = plan
            self._pinned.setdefault(
                key, {"variant": plan.variant, "k_steps": plan.k_steps})
        return plan

    def _lane_for(self, key: _wprog.StencilProgram) -> _Lane:
        lane = self._lanes.get(key)
        if lane is None:
            batch = _fields.zeros_state(key.grid_shape, ensemble=self.slots,
                                        dtype=key.dtype, names=key.fields)
            if self.mesh is not None:
                batch = _domain.shard_state(
                    batch, self.mesh, self._plan_for(key).state_spec)
            lane = _Lane(key=key, batch=batch,
                         slots=[None] * self.slots)
            self._lanes[key] = lane
        return lane

    def _admit(self) -> None:
        """FIFO admission: fill free slots per lane; a lane with no free
        slot does not block requests bound for other lanes.  All slots
        admitted to one lane this wave go in as ONE scatter."""
        now = time.perf_counter()
        waves: Dict[_wprog.StencilProgram,
                    List[Tuple[int, _Pending]]] = {}
        keep: collections.deque[_Pending] = collections.deque()
        free: Dict[_wprog.StencilProgram, List[int]] = {}
        for pend in self._queue:
            req = pend.request
            if (req.deadline_s is not None
                    and now - pend.submit_t > req.deadline_s):
                # Expired while queued: serving it now would waste a slot
                # on an answer nobody is waiting for.
                self._stats["deadline_expired"] += 1
                self._finish(req.rid, req.program,
                             jax.tree_util.tree_map(np.asarray, req.state),
                             steps=req.steps, admit_t=now,
                             queue_wait_s=now - pend.submit_t, rounds=0,
                             status="expired", steps_done=0,
                             diagnosis={"reason": "deadline_exceeded",
                                        "deadline_s": req.deadline_s,
                                        "waited_s": now - pend.submit_t,
                                        "where": "queue"})
                continue
            if req.steps == 0:
                # A 0-step forecast is its own answer (solo run(state, 0)
                # is the identity) — finish without occupying a slot.
                self._finish(req.rid, req.program,
                             jax.tree_util.tree_map(np.asarray, req.state),
                             steps=0, admit_t=now,
                             queue_wait_s=now - pend.submit_t, rounds=0)
                continue
            key = _wprog.plan_cache_key(req.program, ensemble=self.slots)
            # Request-level cache accounting (once per request): hit-rate
            # == the fraction of requests served by an already-compiled
            # plan, so N requests over M programs miss exactly M times.
            if not pend.counted:
                pend.counted = True
                if key in self._plans:
                    self._stats["plan_cache_hits"] += 1
                else:
                    self._stats["plan_cache_misses"] += 1
                    self._plan_for(key)
            lane = self._lane_for(key)
            if key not in free:
                free[key] = [i for i, s in enumerate(lane.slots)
                             if s is None]
            if free[key]:
                waves.setdefault(key, []).append((free[key].pop(0), pend))
            else:
                keep.append(pend)
        self._queue = keep
        for key, wave in waves.items():
            lane = self._lanes[key]
            idx = [i for i, _ in wave]
            sub = jax.tree_util.tree_map(
                lambda *xs: jnp.concatenate(xs, axis=0),
                *[p.request.state for _, p in wave])
            lane.batch = self._assign(lane.batch, jnp.asarray(idx), sub)
            admit_t = time.perf_counter()
            for i, pend in wave:
                lane.fps.pop(i, None)   # fresh content in this slot
                req = pend.request
                lane.slots[i] = _Slot(rid=req.rid, remaining=req.steps,
                                      steps=req.steps, admit_t=admit_t,
                                      queue_wait_s=admit_t - pend.submit_t,
                                      deadline_s=req.deadline_s)
                self._stats["admitted"] += 1

    def _round(self, lane: _Lane) -> None:
        """One SUPERVISED lane round.

        Scheduling is unchanged from the unsupervised engine: the shortest
        next canonical part among active slots picks the round depth;
        slots whose next part is deeper run along but are rolled back
        (uncredited) so every request's realized round sequence equals its
        solo `run()` sequence.  Around that, supervision: the step retries
        with exponential backoff on runtime failure (degrading the plan,
        then failing only this lane's in-flight requests), the fault
        injector's poison hook fires post-step, the validity guard
        quarantines invalid slots pre-credit, and per-request deadlines
        expire at the boundary."""
        plan = self._plan_for(lane.key)
        k = plan.k_steps
        parts = {i: min(s.remaining, k)
                 for i, s in enumerate(lane.slots) if s is not None}
        kk = min(parts.values())
        participants = [i for i, p in parts.items() if p == kk]
        rnd = self._stats["rounds"]
        prev = lane.batch if len(participants) < len(parts) else None
        new_batch = self._step_with_retry(lane, plan, kk, rnd)
        if new_batch is None:                    # escalation exhausted
            if self._try_failover(lane, rnd):
                return          # round re-ran on the rebuilt mesh
            self._fail_lane(lane, rnd)
            return
        lane.batch = new_batch
        if prev is not None:
            mask = np.zeros(self.slots, bool)
            mask[participants] = True
            lane.batch = _wprog.ensemble_slot_select(mask, lane.batch, prev)
            self._stats["rolled_back_slot_rounds"] += (
                len(parts) - len(participants))
        self._stats["rounds"] += 1
        self._stats["occupancy_sum"] += len(parts) / self.slots
        self._stats["occupancy_samples"] += 1
        inj = self.fault_injector
        if inj is not None:
            nonparts = tuple(i for i in range(self.slots)
                             if i not in set(participants))
            lane.batch = inj.poison(lane.batch, lane.key.op, rnd,
                                    tuple(parts), nonparticipants=nonparts,
                                    shards=plan.shards)
        bad = (self._guard_check(lane, parts, participants, rnd)
               if self.guard else {})
        for i, (diag, state) in bad.items():
            self._quarantine(lane, i, diag, state)
        for i in participants:
            if i in bad:
                continue
            slot = lane.slots[i]
            slot.remaining -= kk
            slot.rounds += 1
            if slot.remaining == 0:
                self._retire(lane, i)
        now = time.perf_counter()
        for i, slot in enumerate(lane.slots):
            if (slot is not None and slot.deadline_s is not None
                    and now - slot.submit_t > slot.deadline_s):
                self._expire_slot(lane, i, now)

    def _step_with_retry(self, lane: _Lane, plan, kk: int, rnd: int):
        """Run one round, retrying transient failures with exponential
        backoff; after `max_round_retries`, degrade the plan (force the
        interpreter) and try once more.  Returns the new batch, or None
        when every recourse failed (the caller escalates to mesh failover,
        then fails the lane).  With `round_deadline_s` set, an attempt
        whose wall clock exceeds the deadline counts as a failed attempt —
        a straggling collective goes through the same ladder instead of
        being waited on forever."""
        inj = self.fault_injector
        delay = self.retry_backoff_s
        last = None
        for attempt in range(self.max_round_retries + 1):
            try:
                t0 = time.perf_counter()
                if inj is not None:
                    inj.on_round(lane.key.op, rnd,
                                 device_ids=self._device_ids())
                out = plan.round_plan(kk).step(lane.batch)
                if (self.guard or inj is not None
                        or self.round_deadline_s is not None):
                    # Surface async runtime failures HERE, inside the
                    # retry scope, rather than at some later readback
                    # (the guard reads the batch right after anyway).
                    jax.block_until_ready(out)
                if (self.round_deadline_s is not None
                        and time.perf_counter() - t0
                        > self.round_deadline_s):
                    self._stats["round_deadline_hits"] += 1
                    raise RoundDeadlineError(
                        f"round {rnd} attempt took "
                        f"{time.perf_counter() - t0:.3f}s > "
                        f"round_deadline_s={self.round_deadline_s}")
                return out
            except Exception as e:  # noqa: BLE001 — supervised boundary
                self._stats["round_retries"] += 1
                last = e
                if attempt < self.max_round_retries:
                    time.sleep(delay)
                    delay *= 2
        # Retries exhausted: degrade to the interpreter lowering once —
        # unless the failure names a lost device (degradation cannot
        # resurrect hardware; that case belongs to mesh failover).
        if not plan.interpret and getattr(last, "lost_device", None) is None:
            try:
                ax_e, ax_y, ax_x = self.mesh_axes
                plan2 = _wprog.compile(lane.key, mesh=self.mesh, ax_e=ax_e,
                                       ax_y=ax_y, ax_x=ax_x, interpret=True)
                out = plan2.round_plan(kk).step(lane.batch)
                jax.block_until_ready(out)
                self._plans[lane.key] = plan2
                self._fallbacks[lane.key] = {
                    "stage": "interpret", "errors": [("runtime", repr(last))]}
                self._stats["fallback_compiles"] += 1
                return out
            except Exception as e:  # noqa: BLE001
                last = e
        self._last_round_error = repr(last)
        self._last_round_exc = last
        return None

    def _fail_lane(self, lane: _Lane, rnd: int) -> None:
        """A round failed beyond retry and degradation: fail ONLY this
        lane's in-flight requests (each gets a diagnosis and its pre-round
        state) and reset the lane so the rest of the engine keeps
        serving."""
        self._stats["lane_failures"] += 1
        err = getattr(self, "_last_round_error", "unknown")
        for i, slot in enumerate(lane.slots):
            if slot is None:
                continue
            lane.slots[i] = None
            state = jax.tree_util.tree_map(
                np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
            self._finish(slot.rid,
                         dataclasses.replace(lane.key, ensemble=1), state,
                         steps=slot.steps, admit_t=slot.admit_t,
                         queue_wait_s=slot.queue_wait_s, rounds=slot.rounds,
                         status="failed",
                         steps_done=slot.steps - slot.remaining,
                         diagnosis={"reason": "round_failure", "round": rnd,
                                    "error": err})
        lane.batch = jax.device_put(_fields.zeros_state(
            lane.key.grid_shape, ensemble=self.slots, dtype=lane.key.dtype,
            names=lane.key.fields))
        if self.mesh is not None:
            lane.batch = _domain.shard_state(
                lane.batch, self.mesh, self._plan_for(lane.key).state_spec)
        lane.fps.clear()

    # -- mesh failover ------------------------------------------------------
    def _device_ids(self) -> Optional[List[int]]:
        if self.mesh is None:
            return None
        return [int(d.id) for d in self.mesh.devices.flat]

    def _probe_devices(self, devs) -> List[Any]:
        """The devices among `devs` that still answer a trivial
        transfer + compute + readback (the failure-agnostic way to find
        survivors when the round error did not name the lost device)."""
        alive = []
        for d in devs:
            try:
                jax.block_until_ready(jax.device_put(jnp.zeros(()), d) + 1)
                alive.append(d)
            except Exception:  # noqa: BLE001 — that IS the probe result
                pass
        return alive

    def _try_failover(self, lane: _Lane, rnd: int) -> bool:
        """The escalation step past retry + degrade: rebuild the mesh from
        surviving devices and resume EVERY in-flight request from the last
        round boundary.  Returns True when the interrupted round re-ran on
        the new mesh (nothing was failed), False when failover is off,
        no device is identifiably lost, or no surviving shape carries the
        lanes (the caller then fails the lane as before).

        Sequence: identify the lost device (the raised error's
        `lost_device`, else a probe of every mesh device); gather every
        lane's pre-round batch to host (the reshard pivot — `_round` has
        not credited anything yet, so this IS the last round boundary);
        walk `domain.failover_meshes` best-first until one shape compiles
        every lane's plan (pinned round depth, so canonical round
        sequences survive); reshard; re-run the interrupted round.  Slot
        fingerprints are sharding-invariant and keep guarding across the
        transition."""
        if not self.failover or self.mesh is None:
            return False
        devs = list(self.mesh.devices.flat)
        lost = getattr(getattr(self, "_last_round_exc", None),
                       "lost_device", None)
        if lost is not None:
            survivors = [d for d in devs if int(d.id) != int(lost)]
        else:
            survivors = self._probe_devices(devs)
        if not survivors or len(survivors) == len(devs):
            return False        # nothing identifiably lost: not a mesh fault
        t0 = time.perf_counter()
        host = {key: _domain.gather_state(ln.batch)
                for key, ln in self._lanes.items()}
        old_mesh, old_plans, old_fb = self.mesh, self._plans, self._fallbacks
        like = (self._plans[lane.key].shards
                if lane.key in self._plans else None)
        ax_e, ax_y, ax_x = self.mesh_axes
        grids = [ln.key.grid_shape for ln in self._lanes.values()]
        chosen = None
        for mesh2 in _domain.failover_meshes(survivors, grids,
                                             axes=(ax_y, ax_x), like=like):
            self.mesh, self._plans, self._fallbacks = mesh2, {}, {}
            try:
                for key in self._lanes:
                    self._plan_for(key)
                chosen = mesh2
                break
            except Exception:  # noqa: BLE001 — try the next shape
                continue
        if chosen is None:
            self.mesh, self._plans, self._fallbacks = (
                old_mesh, old_plans, old_fb)
            return False
        for key, ln in self._lanes.items():
            ln.batch = _domain.shard_state(
                host[key], self.mesh, self._plan_for(key).state_spec)
        active = sum(sum(s is not None for s in ln.slots)
                     for ln in self._lanes.values())
        self._stats["mesh_failovers"] += 1
        self._stats["recovery_rounds"] += 1
        self._stats["requests_preserved"] += active
        self._failovers.append({
            "round": rnd,
            "lost_device": None if lost is None else int(lost),
            "from_devices": [int(d.id) for d in devs],
            "to_devices": [int(d.id) for d in self.mesh.devices.flat],
            "from_shape": None if like is None else list(like),
            "to_shape": list(self._plan_for(lane.key).shards),
            "reshard_ms": (time.perf_counter() - t0) * 1e3,
            "requests_preserved": active,
        })
        self._round(lane)       # re-run the interrupted round
        return True

    # -- validity guard / quarantine ---------------------------------------
    def _guard_check(self, lane: _Lane, parts: Dict[int, int],
                     participants: List[int],
                     rnd: int) -> Dict[int, Tuple[Dict[str, Any],
                                                  WeatherState]]:
        """The per-slot supervision pass: ONE fused reduction over the
        whole lane batch at the round boundary computing both the physics
        validity bit (NaN/Inf + bounds) and a content fingerprint per slot
        (`program.slot_guard`).  Active invalid slots are diagnosed (host
        readback of just that slot); idle slots that rot are scrubbed back
        to zeros.  Then the fingerprint check: slots that did NOT advance
        this round — rolled-back and idle slots — must keep their digest
        bit-for-bit; a mismatch is cross-device/shard divergence (e.g. a
        corrupted halo wire buffer) that magnitude checks cannot see.
        Divergent in-flight slots quarantine, divergent idle slots scrub.
        Healthy slots are only READ — their bits cannot change."""
        ok_d, fp_d = _wprog.slot_guard(lane.batch, self.guard_limit)
        ok, fp = np.asarray(ok_d), np.asarray(fp_d)
        bad: Dict[int, Tuple[Dict[str, Any], WeatherState]] = {}
        for i in parts:
            if not bool(ok[i]):
                bad[i] = self._diagnose(lane, i, rnd)
        for i, slot in enumerate(lane.slots):
            if slot is None and not bool(ok[i]):
                self._scrub(lane, i)
                self._stats["scrubbed_idle_slots"] += 1
        advanced = set(participants)
        for i in range(self.slots):
            if i in bad or not bool(ok[i]):
                continue        # already handled by the validity pass
            got = int(fp[i])
            if i in advanced or i not in lane.fps:
                # The slot legitimately has new bits (it advanced a round)
                # or has no recorded digest yet: (re)record.
                lane.fps[i] = got
                continue
            want = lane.fps[i]
            if want == got:
                continue
            self._stats["fingerprint_divergence"] += 1
            if lane.slots[i] is not None:
                bad[i] = self._diagnose_fp(lane, i, rnd, want, got)
            else:
                self._scrub(lane, i)
                self._stats["scrubbed_idle_slots"] += 1
        return bad

    def _diagnose_fp(self, lane: _Lane, i: int, rnd: int, want: int,
                     got: int) -> Tuple[Dict[str, Any], WeatherState]:
        state = jax.tree_util.tree_map(
            np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
        diag = {"reason": "fingerprint_divergence", "round": rnd,
                "expected_fp": want, "observed_fp": got,
                "note": "slot did not advance this round but its bits "
                        "changed: cross-shard/device divergence (e.g. a "
                        "corrupted halo wire buffer), invisible to "
                        "NaN/magnitude validity checks"}
        return diag, state

    def _diagnose(self, lane: _Lane, i: int,
                  rnd: int) -> Tuple[Dict[str, Any], WeatherState]:
        """Host-side diagnosis of one invalid slot (the slow path — it
        only runs on quarantine): per-leaf NaN/Inf/out-of-bounds counts."""
        state = jax.tree_util.tree_map(
            np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
        leaves = {}
        for name, a in sorted(state.fields.items()):
            leaves[f"fields/{name}"] = a
        leaves["wcon"] = np.asarray(state.wcon)
        for name, a in sorted(state.tens.items()):
            leaves[f"tens/{name}"] = a
        for name, a in sorted(state.stage_tens.items()):
            leaves[f"stage_tens/{name}"] = a
        per_leaf = {}
        for key, a in leaves.items():
            a = np.asarray(a, np.float64)
            nan = int(np.isnan(a).sum())
            inf = int(np.isinf(a).sum())
            finite = a[np.isfinite(a)]
            oob = int((np.abs(finite) > self.guard_limit).sum())
            if nan or inf or oob:
                per_leaf[key] = {"nan": nan, "inf": inf,
                                 "out_of_bounds": oob}
        diag = {"reason": "validity_guard", "round": rnd,
                "limit": self.guard_limit, "bad_leaves": per_leaf,
                "first_bad": next(iter(per_leaf), None)}
        return diag, state

    def _quarantine(self, lane: _Lane, i: int, diag: Dict[str, Any],
                    state: WeatherState) -> None:
        """Remove ONE offending slot: its request finishes `failed` with
        the diagnosis (and the offending state, for forensics), the slot
        is re-zeroed so the lane stays healthy, and the freed slot
        backfills from the queue at the next admit."""
        slot = lane.slots[i]
        lane.slots[i] = None
        self._stats["quarantined"] += 1
        self._scrub(lane, i)
        self._finish(slot.rid, dataclasses.replace(lane.key, ensemble=1),
                     state, steps=slot.steps, admit_t=slot.admit_t,
                     queue_wait_s=slot.queue_wait_s, rounds=slot.rounds,
                     status="failed",
                     steps_done=slot.steps - slot.remaining, diagnosis=diag)

    def _scrub(self, lane: _Lane, i: int) -> None:
        zero = _fields.zeros_state(lane.key.grid_shape, ensemble=1,
                                   dtype=lane.key.dtype,
                                   names=lane.key.fields)
        lane.batch = self._assign(lane.batch, jnp.asarray([i]), zero)
        lane.fps.pop(i, None)   # the slot's bits were legitimately replaced

    def _expire_slot(self, lane: _Lane, i: int, now: float) -> None:
        slot = lane.slots[i]
        lane.slots[i] = None
        self._stats["deadline_expired"] += 1
        state = jax.tree_util.tree_map(
            np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
        self._scrub(lane, i)
        self._finish(slot.rid, dataclasses.replace(lane.key, ensemble=1),
                     state, steps=slot.steps, admit_t=slot.admit_t,
                     queue_wait_s=slot.queue_wait_s, rounds=slot.rounds,
                     status="expired",
                     steps_done=slot.steps - slot.remaining,
                     diagnosis={"reason": "deadline_exceeded",
                                "deadline_s": slot.deadline_s,
                                "elapsed_s": now - slot.submit_t,
                                "where": "in_flight"})

    def _retire(self, lane: _Lane, i: int) -> None:
        slot = lane.slots[i]
        lane.slots[i] = None
        # Read back exactly this slot; blocking here IS the finish time.
        state = jax.tree_util.tree_map(
            np.asarray, _wprog.ensemble_slot_view(lane.batch, i))
        prog = dataclasses.replace(lane.key, ensemble=1)
        self._finish(slot.rid, prog, state, steps=slot.steps,
                     admit_t=slot.admit_t, queue_wait_s=slot.queue_wait_s,
                     rounds=slot.rounds)

    def _finish(self, rid: int, prog, state, *, steps: int, admit_t: float,
                queue_wait_s: float, rounds: int, status: str = "ok",
                steps_done: Optional[int] = None,
                diagnosis: Optional[Dict[str, Any]] = None) -> None:
        self._results[rid] = ForecastResult(
            rid=rid, program=prog, state=state, steps=steps,
            latency_s=time.perf_counter() - admit_t,
            queue_wait_s=queue_wait_s, rounds=rounds, status=status,
            steps_done=steps if steps_done is None else steps_done,
            diagnosis=diagnosis)
        self._stats["completed"] += 1

    # -- warm-state checkpointing ------------------------------------------
    def checkpoint(self, ckpt_dir: Optional[str] = None,
                   step: Optional[int] = None) -> int:
        """Persist the warm engine (in-flight batches, queue, results,
        bookkeeping) atomically via `ckpt.save_tree`.  Returns the
        checkpoint step.  In-flight latency clocks are stored as
        elapsed-so-far and resume ticking on restore."""
        ckpt_dir = ckpt_dir or self.ckpt_dir
        if ckpt_dir is None:
            raise ValueError("no ckpt_dir: pass one here or at __init__")
        if step is None:
            step = self._ckpt_step
        self._ckpt_step = step + 1
        now = time.perf_counter()
        lanes = list(self._lanes.values())
        tree = {
            "lanes": [lane.batch for lane in lanes],
            "queue": [p.request.state for p in self._queue],
            "results": {str(rid): r.state
                        for rid, r in self._results.items()},
        }
        extra = {
            "slots": self.slots,
            "next_rid": self._next_rid,
            "ckpt_step": self._ckpt_step,
            "stats": {k: v for k, v in self._stats.items()},
            "mesh_devices": (None if self.mesh is None
                             else int(self.mesh.devices.size)),
            "config": {
                "max_queue": self.max_queue, "guard": self.guard,
                "guard_limit": self.guard_limit,
                "ckpt_every_rounds": self.ckpt_every_rounds,
                "max_round_retries": self.max_round_retries,
                "retry_backoff_s": self.retry_backoff_s,
                "last_ckpt_round": self._last_ckpt_round,
            },
            "lanes": [{
                "program": lane.key.to_json(),
                # The resolved round strategy: restore re-pins it so the
                # canonical round sequence survives a mesh change.
                "plan": self._pinned.get(lane.key),
                "slots": [None if s is None else {
                    "rid": s.rid, "remaining": s.remaining,
                    "steps": s.steps, "rounds": s.rounds,
                    "elapsed_s": now - s.admit_t,
                    "queue_wait_s": s.queue_wait_s,
                    "deadline_s": s.deadline_s,
                } for s in lane.slots],
            } for lane in lanes],
            "queue": [{
                "rid": p.request.rid,
                "steps": p.request.steps,
                "program": p.request.program.to_json(),
                "waited_s": now - p.submit_t,
                "deadline_s": p.request.deadline_s,
            } for p in self._queue],
            "results": [{
                "rid": r.rid, "steps": r.steps, "rounds": r.rounds,
                "latency_s": r.latency_s, "queue_wait_s": r.queue_wait_s,
                "program": r.program.to_json(),
                "status": r.status, "steps_done": r.steps_done,
                "diagnosis": r.diagnosis,
            } for r in self._results.values()],
        }
        ckpt.save_tree(ckpt_dir, step, tree, extra=extra,
                       keep=self.ckpt_keep)
        return step

    @classmethod
    def restore(cls, ckpt_dir: str, step: Optional[int] = None, *,
                mesh=None, interpret: Optional[bool] = None,
                ax_e: str = "pod", ax_y: str = "data", ax_x: str = "model",
                ckpt_keep: int = 3, fault_injector=None) -> "ForecastEngine":
        """Resume a checkpointed engine — on ANY mesh.

        In-flight forecasts continue from their persisted round boundary
        (no respin), queued requests stay queued, finished results are
        preserved.  The checkpoint is mesh-elastic: lane batches are
        persisted unsharded-logical and reshard through the NEW plan's
        `state_spec`, so a checkpoint written single-chip restores onto 4
        devices and vice versa.  Plans are NOT serialized — they
        recompile through the plan cache (compile-once per mesh shape)
        with the persisted (variant, k_steps) pin, keeping every
        in-flight request's canonical round sequence intact across the
        transition; docs/robustness.md has the matrix of which
        transitions additionally preserve exact bits.  Supervision config
        (max_queue, guard, watchdog cadence, retry policy) is restored
        from the checkpoint.

        With `step=None` the newest checkpoint is used; when it is
        corrupt (`ckpt.CheckpointCorruptError`), restore falls back to
        the next-older valid one instead of dying, and raises an
        aggregated error only when every retained checkpoint is
        unreadable."""
        if step is not None:
            return cls._restore_step(
                ckpt_dir, step, mesh=mesh, interpret=interpret, ax_e=ax_e,
                ax_y=ax_y, ax_x=ax_x, ckpt_keep=ckpt_keep,
                fault_injector=fault_injector)
        steps = sorted(ckpt.all_steps(ckpt_dir), reverse=True)
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir!r}")
        failures = []
        for s in steps:
            try:
                return cls._restore_step(
                    ckpt_dir, s, mesh=mesh, interpret=interpret, ax_e=ax_e,
                    ax_y=ax_y, ax_x=ax_x, ckpt_keep=ckpt_keep,
                    fault_injector=fault_injector)
            except ckpt.CheckpointCorruptError as e:
                failures.append((s, e))
        raise ckpt.CheckpointCorruptError(
            f"every checkpoint in {ckpt_dir!r} is unreadable — "
            + "; ".join(f"step {s}: {e}" for s, e in failures))

    @classmethod
    def _restore_step(cls, ckpt_dir: str, step: int, *, mesh, interpret,
                      ax_e: str, ax_y: str, ax_x: str, ckpt_keep: int,
                      fault_injector) -> "ForecastEngine":
        def prog_of(d):
            return _wprog.StencilProgram.from_json(d)

        def template(prog, ensemble):
            return _fields.zeros_state(prog.grid_shape, ensemble=ensemble,
                                       dtype=prog.dtype, names=prog.fields)

        meta = ckpt.read_meta(ckpt_dir, step)
        try:
            extra = meta["extra"]
            slots = extra["slots"]
            tmpl = {
                "lanes": [template(prog_of(ln["program"]), slots)
                          for ln in extra["lanes"]],
                "queue": [template(prog_of(q["program"]), 1)
                          for q in extra["queue"]],
                "results": {str(r["rid"]): template(prog_of(r["program"]), 1)
                            for r in extra["results"]},
            }
        except (KeyError, TypeError) as e:
            raise ckpt.CheckpointCorruptError(
                f"checkpoint {ckpt_dir!r} step {step}: the engine sidecar "
                f"is missing or malformed at {e!r} — written by an "
                f"incompatible engine version or truncated.  Restore from "
                f"another step, or re-checkpoint with this engine."
            ) from e
        tree, _ = ckpt.restore_tree(ckpt_dir, step, tmpl)

        cfg = extra.get("config", {})
        eng = cls(slots=slots, mesh=mesh, interpret=interpret, ax_e=ax_e,
                  ax_y=ax_y, ax_x=ax_x, ckpt_dir=ckpt_dir,
                  ckpt_keep=ckpt_keep,
                  max_queue=cfg.get("max_queue"),
                  guard=cfg.get("guard", True),
                  guard_limit=cfg.get("guard_limit", 1e6),
                  ckpt_every_rounds=cfg.get("ckpt_every_rounds"),
                  max_round_retries=cfg.get("max_round_retries", 2),
                  retry_backoff_s=cfg.get("retry_backoff_s", 0.05),
                  fault_injector=fault_injector)
        eng._next_rid = extra["next_rid"]
        eng._ckpt_step = extra["ckpt_step"]
        eng._last_ckpt_round = cfg.get("last_ckpt_round", 0)
        eng._stats.update(extra["stats"])
        now = time.perf_counter()
        for ln, batch in zip(extra["lanes"], tree["lanes"]):
            key = _wprog.plan_cache_key(prog_of(ln["program"]),
                                        ensemble=slots)
            pin = ln.get("plan")
            if pin is not None:
                # Seed the round-strategy pin BEFORE the first compile so
                # the recompiled plan replays the writer's [k,...,k,tail]
                # sequences even on a different mesh shape.
                eng._pinned[key] = dict(pin)
            if mesh is not None:
                batch = _domain.shard_state(batch, mesh,
                                            eng._plan_for(key).state_spec)
            else:
                batch = jax.device_put(batch)
            eng._lanes[key] = _Lane(
                key=key, batch=batch,
                slots=[None if s is None else _Slot(
                    rid=s["rid"], remaining=s["remaining"],
                    steps=s["steps"], rounds=s["rounds"],
                    admit_t=now - s["elapsed_s"],
                    queue_wait_s=s["queue_wait_s"],
                    deadline_s=s.get("deadline_s"))
                    for s in ln["slots"]])
        for q, state in zip(extra["queue"], tree["queue"]):
            req = ForecastRequest(program=prog_of(q["program"]),
                                  state=jax.device_put(state),
                                  steps=q["steps"], rid=q["rid"],
                                  deadline_s=q.get("deadline_s"))
            eng._queue.append(_Pending(req, now - q["waited_s"]))
        for r in extra["results"]:
            eng._results[r["rid"]] = ForecastResult(
                rid=r["rid"], program=prog_of(r["program"]),
                state=jax.tree_util.tree_map(np.asarray,
                                             tree["results"][str(r["rid"])]),
                steps=r["steps"], latency_s=r["latency_s"],
                queue_wait_s=r["queue_wait_s"], rounds=r["rounds"],
                status=r.get("status", "ok"),
                steps_done=r.get("steps_done", r["steps"]),
                diagnosis=r.get("diagnosis"))
        return eng
