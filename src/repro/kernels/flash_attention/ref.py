"""Pure-jnp oracle for the flash-attention Pallas kernel.

Plain materialized-softmax GQA attention with the same masking semantics
(causal / sliding window / logit softcap) — the correctness reference the
kernel is swept against in tests/test_kernels_flash.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: int = 0,
        softcap: float = 0.0) -> jnp.ndarray:
    """q: (B, T, H, hd); k, v: (B, S, KH, hd); H % KH == 0.

    Returns (B, T, H, hd).  window > 0 keeps keys with 0 <= qpos-kpos <
    window (sliding-window attention); causal masks kpos > qpos.
    """
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    qs = (q.astype(jnp.float32) * (hd ** -0.5)).reshape(b, t, kh, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qs, k.astype(jnp.float32))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)
