"""Measured wall-clock of every framework layer on this CPU: kernels
(jnp refs + Pallas interpret), dycore step, reduced-config train step and
decode step — the 'it actually runs' numbers behind the model projections."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn


def run():
    rng = np.random.default_rng(0)

    # kernels: pallas interpret vs jnp ref (small shapes; interpret is an
    # emulation, timing recorded for completeness not for speed claims)
    from repro.kernels.hdiff import ref as href
    from repro.kernels.hdiff.hdiff import hdiff_pallas
    src = jnp.asarray(rng.normal(size=(8, 64, 64)).astype(np.float32))
    emit("wall/hdiff_jnp_8x64x64", time_fn(jax.jit(href.hdiff), src))
    emit("wall/hdiff_pallas_interp", time_fn(
        jax.jit(lambda s: hdiff_pallas(s, ty=8, interpret=True)), src))

    from repro.kernels.vadvc import ref as vref
    us, up, ut, uts = (jnp.asarray(
        rng.normal(size=(16, 32, 32)).astype(np.float32)) for _ in range(4))
    wcon = jnp.asarray(rng.uniform(-0.2, 0.2, size=(16, 32, 33))
                       .astype(np.float32))
    emit("wall/vadvc_jnp_16x32x32",
         time_fn(jax.jit(vref.vadvc), us, wcon, up, ut, uts))

    # weather stencil programs — ONE ExecutionPlan per (op, configuration)
    from repro.weather import fields
    from repro.weather.program import StencilProgram, compile
    st = fields.initial_state(jax.random.PRNGKey(0), (16, 64, 64))
    for op in ("dycore", "hdiff", "vadvc"):
        plan = compile(StencilProgram(grid_shape=(16, 64, 64), op=op))
        name = "dycore_step" if op == "dycore" else f"{op}_step"
        emit(f"wall/{name}_16x64x64", time_fn(plan.step, st))

    # reduced-config LM train + decode
    from repro.configs import registry
    from repro.models import api
    from repro.train import loop as tloop, optim
    from repro.launch.mesh import make_mesh
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"))
    model = api.build(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    _, jit_for, _ = tloop.make_train_step(model, mesh,
                                          optim.OptConfig(total_steps=10))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(4, 64)).astype(np.int32))}
    spec = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        batch)
    step = jit_for(spec)
    # donated args: rebuild state each call inside the timer would skew —
    # time with donation disabled variant
    step_nd, _, _ = tloop.make_train_step(model, mesh,
                                          optim.OptConfig(total_steps=10),
                                          donate=False)
    step_nd_j = jax.jit(step_nd)
    emit("wall/train_step_smoke", time_fn(step_nd_j, params, opt_state,
                                          batch))

    logits, cache = model.prefill(params, {"tokens": batch["tokens"]},
                                  max_len=96)
    dec = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i))
    tok = batch["tokens"][:, :1]
    emit("wall/decode_step_smoke", time_fn(dec, params, cache, tok,
                                           jnp.int32(64)))


if __name__ == "__main__":
    run()
