"""NeroEngine — the paper's execution model as a first-class API.

    engine = NeroEngine()
    plan = engine.plan("hdiff", grid_shape=(64, 256, 256), dtype=jnp.float32)
    out  = engine.run(plan, src)

`plan` runs the multi-objective tile autotuner (the paper's OpenTuner
stage) once per (op, grid, dtype) and caches the chosen `TilePlan`;
`run` dispatches to the Pallas TPU kernel with the plan's window as its
BlockSpec tiling, or to the pure-jnp oracle on hosts without TPU kernels
(CPU tests, differentiable paths).  Every memory-bound operator the
framework owns routes through this planner, so the autotuner and the
roofline report share one cost model — the paper's Fig. 1 → Fig. 6 loop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune, hierarchy as hw, perfmodel
from repro.core.tiling import COPY, HDIFF, LRU_SCAN, VADVC, OpSpec, TilePlan

OPS: Dict[str, OpSpec] = {
    "hdiff": HDIFF,
    "vadvc": VADVC,
    "copy": COPY,
    "lru_scan": LRU_SCAN,
}


def _has_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:                              # pragma: no cover
        return False


@dataclasses.dataclass
class NeroEngine:
    """Plan + dispatch for the framework's memory-bound operators."""

    hier: Optional[hw.Hierarchy] = None
    interpret: Optional[bool] = None    # None -> interpret iff no real TPU
    chips: int = 1

    def __post_init__(self):
        self.hier = self.hier or hw.tpu_v5e()
        if self.interpret is None:
            self.interpret = not _has_tpu()
        self._plans: Dict[Tuple[str, Tuple[int, ...], str],
                          autotune.TunedResult] = {}

    # -- planning ------------------------------------------------------------

    def plan(self, op_name: str, grid_shape: Tuple[int, ...], dtype,
             measure: Optional[Callable[[TilePlan], float]] = None
             ) -> autotune.TunedResult:
        key = (op_name, tuple(grid_shape), str(jnp.dtype(dtype)))
        if key not in self._plans or measure is not None:
            self._plans[key] = autotune.tune(
                OPS[op_name], grid_shape, dtype, self.hier,
                chips=self.chips, measure=measure)
        return self._plans[key]

    def estimate(self, op_name: str, grid_shape: Tuple[int, ...], dtype
                 ) -> perfmodel.PerfEstimate:
        return self.plan(op_name, grid_shape, dtype).est

    # -- dispatch ------------------------------------------------------------

    def run(self, tuned: autotune.TunedResult, *fields):
        plan = tuned.plan
        name = plan.op.name
        if name == "hdiff":
            return self._run_hdiff(plan, *fields)
        if name == "vadvc":
            return self._run_vadvc(plan, *fields)
        if name == "copy":
            return self._run_copy(plan, *fields)
        raise NotImplementedError(name)

    def _run_hdiff(self, plan: TilePlan, src, coeff: float | None = None):
        from repro.kernels.hdiff import ref
        from repro.kernels.hdiff.hdiff import hdiff_pallas
        coeff = ref.DEFAULT_COEFF if coeff is None else coeff
        ny = src.shape[1]
        ty = max(2, plan.tile[1])
        if self.interpret and src.size > 2**22:
            # interpret-mode Pallas is Python-speed; oracle is exact
            return ref.hdiff(src, coeff=coeff)
        while ny % ty:
            ty -= 1
        return hdiff_pallas(src, coeff=coeff, ty=ty,
                            interpret=self.interpret)

    def _run_vadvc(self, plan: TilePlan, u_stage, wcon, u_pos, utens,
                   utens_stage):
        from repro.kernels.vadvc import ref
        from repro.kernels.vadvc.vadvc import vadvc_pallas
        if self.interpret and u_stage.size > 2**20:
            return ref.vadvc(u_stage, wcon, u_pos, utens, utens_stage)
        _, ny, nx = u_stage.shape
        tj, ti = max(1, plan.tile[1]), max(1, plan.tile[2])
        while ny % tj:
            tj -= 1
        while nx % ti:
            ti -= 1
        return vadvc_pallas(u_stage, wcon, u_pos, utens, utens_stage,
                            tj=tj, ti=ti, interpret=self.interpret)

    def _run_copy(self, plan: TilePlan, src):
        from repro.kernels.copy_stencil.copy_stencil import copy_pallas
        return copy_pallas(src, interpret=self.interpret)
