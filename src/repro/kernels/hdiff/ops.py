"""Jitted public entry points for hdiff (planner-aware dispatch).

`hdiff(...)` picks the implementation: the Pallas kernel on TPU (or when
`interpret=True` is forced for validation), else the pure-jnp oracle — the
differentiable path used by the weather dycore during training.
Tile sizes come from the NERO autotuner unless overridden.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune, tiling
from repro.kernels.hdiff import ref as _ref
from repro.kernels.hdiff.hdiff import hdiff_pallas

HALO = 2   # the compound stencil's one-sided reach in y and x


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the Pallas kernel (paper Fig. 6 stage).

    Snapping goes through `tiling.snap_to_divisor` — the same
    largest-divisor-below rule as the fused dycore's `snap_ty` (this
    module used to halve instead, which drifted from the unified
    `resolve_tile` path for tuned sizes like 24 on ny=32)."""
    tuned = autotune.tune_named("hdiff", grid_shape, dtype)
    return tiling.snap_to_divisor(tuned.plan.tile[1], grid_shape[1], lo=2)


def resolve_tile(grid_shape, dtype) -> tiling.TilePlan:
    """Planner entry (`weather/program.py::compile`): the auto-tuned,
    snapped y-window as a full `TilePlan` over the hdiff tile space."""
    ty = plan_tile(grid_shape, dtype)
    # The kernel's grid is (nz, ny/ty): one z-plane and the whole x extent
    # per cell, so the staged window is (1, ty, nx).
    return tiling.TilePlan(op=autotune.get_op("hdiff"),
                           grid_shape=tuple(int(g) for g in grid_shape),
                           tile=(1, ty, int(grid_shape[2])),
                           dtype=str(jnp.dtype(dtype)))


@functools.partial(jax.jit, static_argnames=("coeff", "use_pallas", "ty",
                                             "interpret"))
def hdiff(src: jnp.ndarray, coeff: float = _ref.DEFAULT_COEFF,
          use_pallas: bool = False, ty: int = 0,
          interpret: bool = True) -> jnp.ndarray:
    if use_pallas:
        ty = ty or plan_tile(src.shape, src.dtype)
        return hdiff_pallas(src, coeff=coeff, ty=ty, interpret=interpret)
    return _ref.hdiff(src, coeff=coeff)
