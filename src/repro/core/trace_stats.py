"""Count primitives in traced jaxprs — launch/collective accounting.

The whole-state dycore's contract is structural, not just numerical: ONE
`pallas_call` per step, ONE `ppermute` pair per mesh direction per k-step
round.  Those invariants are asserted by counting primitive equations in
the traced jaxpr (recursing through pjit/scan/shard_map/cond sub-jaxprs),
which works on any backend — including CPU, where Pallas interpret-mode
never lowers to a custom call that HLO-level counting could find.
"""

from __future__ import annotations

from typing import Any, Dict


def _sub_jaxprs(eqn) -> list:
    subs = []
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "jaxpr"):        # ClosedJaxpr
                subs.append(x.jaxpr)
            elif hasattr(x, "eqns"):       # raw Jaxpr
                subs.append(x)
    return subs


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive `name` in `jaxpr`, recursing into every
    sub-jaxpr (pjit, scan, while, cond branches, shard_map, ...).  A scan
    body counts ONCE regardless of trip count — this counts distinct
    launches/collectives in the program text, i.e. per-iteration cost."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)   # accept ClosedJaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for sub in _sub_jaxprs(eqn):
            n += count_primitive(sub, name)
    return n


def launch_and_collective_counts(jaxpr) -> Dict[str, int]:
    """The two structural costs of a distributed dycore round: Pallas
    launches and ppermute collectives in the traced program (scan bodies
    counted once — i.e. per-round cost)."""
    return {"pallas_call": count_primitive(jaxpr, "pallas_call"),
            "ppermute": count_primitive(jaxpr, "ppermute")}


def assert_kstep_structure(jaxpr, *, pallas_calls: int = 1,
                           collectives: int = 4) -> Dict[str, int]:
    """Assert the k-step round's structural win: exactly ONE `pallas_call`
    (the in-kernel k-step scan — no launch per local step) and one
    `ppermute` pair per mesh direction (4 collectives) per round.  Returns
    the counts; raises AssertionError naming the violated invariant."""
    counts = launch_and_collective_counts(jaxpr)
    if counts["pallas_call"] != pallas_calls:
        raise AssertionError(
            f"k-step round launches {counts['pallas_call']} Pallas kernels, "
            f"expected {pallas_calls} (the round must be ONE launch)")
    if counts["ppermute"] != collectives:
        raise AssertionError(
            f"k-step round issues {counts['ppermute']} ppermutes, expected "
            f"{collectives} (one pair per mesh direction per round)")
    return counts


def assert_plan_structure(jaxpr, report: Dict[str, Any]) -> Dict[str, int]:
    """Assert a traced plan round matches the plan's OWN `report()`: the
    modeled `pallas_calls_per_round` / `collectives_per_round` must be the
    program text's actual primitive counts (a plan whose report lies about
    its structure is a planner bug).  Returns the counts."""
    counts = launch_and_collective_counts(jaxpr)
    for key, prim in (("pallas_calls_per_round", "pallas_call"),
                      ("collectives_per_round", "ppermute")):
        want = report.get(key)
        if want is not None and counts[prim] != want:
            raise AssertionError(
                f"plan.report()[{key!r}] = {want} but the traced round "
                f"contains {counts[prim]} {prim} eqns")
    return counts


def primitive_counts(jaxpr) -> Dict[str, int]:
    """Histogram of every primitive in `jaxpr` (recursive, scan bodies
    counted once)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    out: Dict[str, int] = {}

    def walk(j: Any) -> None:
        for eqn in j.eqns:
            out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return out
