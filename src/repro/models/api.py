"""Unified model API: one `Model` facade per architecture family.

    model = build(cfg)
    params = model.init(key)                      # materialized
    shapes = model.param_shapes()                 # ShapeDtypeStructs (dry-run)
    loss   = model.loss(params, batch)
    logits, cache = model.prefill(params, tokens_or_batch)
    logits, cache = model.decode_step(params, cache, token, pos)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def family(self) -> str:
        return "encdec" if self.cfg.encdec else "lm"

    # ---- params -----------------------------------------------------------
    def init(self, key):
        if self.family == "encdec":
            return encdec.init_params(self.cfg, key)
        return lm.init_params(self.cfg, key)

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- training ---------------------------------------------------------
    def loss(self, params, batch: Dict[str, jnp.ndarray],
             remat: str = "full", scan_unroll: bool = False):
        if self.family == "encdec":
            return encdec.loss_fn(self.cfg, params, batch, remat=remat,
                                  scan_unroll=scan_unroll)
        return lm.loss_fn(self.cfg, params, batch, remat=remat,
                          scan_unroll=scan_unroll)

    def batch_spec(self, batch: int, seq: int) -> Dict[str, Any]:
        """ShapeDtypeStructs for one training batch (dry-run input_specs)."""
        spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if self.family == "encdec":
            spec["frames"] = jax.ShapeDtypeStruct(
                (batch, self.cfg.encdec.encoder_len, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return spec

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        if self.family == "encdec":
            cache = encdec.init_cache(self.cfg, batch, max_len)
            cache["enc"] = jnp.zeros(
                (batch, self.cfg.encdec.encoder_len, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
            return cache
        return lm.init_cache(self.cfg, batch, max_len)

    def prefill(self, params, batch: Dict[str, jnp.ndarray],
                max_len: Optional[int] = None, scan_unroll: bool = False):
        tokens = batch["tokens"]
        if self.family == "encdec":
            enc = encdec.encode(self.cfg, params, batch["frames"],
                                scan_unroll=scan_unroll)
            cache = encdec.init_cache(self.cfg, tokens.shape[0],
                                      max_len or tokens.shape[1])
            logits, cache = encdec.decode(self.cfg, params, tokens, enc,
                                          mode="prefill", cache=cache,
                                          scan_unroll=scan_unroll)
            return logits, {"dec": cache["dec"], "enc": enc}
        return lm.prefill(self.cfg, params, tokens, max_len,
                          scan_unroll=scan_unroll)

    def decode_step(self, params, cache, token, pos,
                    frames_enc: Optional[jnp.ndarray] = None,
                    scan_unroll: bool = False):
        if self.family == "encdec":
            enc = cache["enc"] if frames_enc is None else frames_enc
            logits, new = encdec.decode(self.cfg, params, token, enc,
                                        mode="decode",
                                        cache={"dec": cache["dec"]}, pos=pos,
                                        scan_unroll=scan_unroll)
            return logits, {"dec": new["dec"], "enc": enc}
        return lm.decode_step(self.cfg, params, cache, token, pos,
                              scan_unroll=scan_unroll)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
