"""Jitted public entry points for the fused dycore step (planner-aware).

Two granularities:

* `fused_step(...)` — one prognostic field per call: builds the pre-combined
  staggered vertical velocity, picks the auto-tuned y-window (NERO's
  OpenTuner stage via core/autotune.py), and dispatches to the Pallas
  compound kernel — or to the unfused oracle composition when
  `use_pallas=False` (the differentiable fallback path).
* `fused_step_whole_state(...)` — ALL prognostic fields in ONE `pallas_call`:
  fields are stacked on a leading `nf` axis, the shared staggered-velocity
  slab is DMA'd once per (ensemble, y-window) instead of once per field, and
  the launch cost is amortized nf×.  This is the default hot path of
  `weather/dycore.py::dycore_step`.

Both default `interpret=None`, resolved via `_auto_interpret()`: native
Pallas on TPU, interpreter everywhere else.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune, tiling
from repro.kernels.dycore_fused import ref as _ref
from repro.kernels.dycore_fused.fused import (fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)

DEFAULT_COEFF = _ref.DEFAULT_COEFF
DEFAULT_DT = _ref.DEFAULT_DT


def _auto_interpret() -> bool:
    """Pallas runs natively on TPU, in interpreter mode everywhere else."""
    return jax.default_backend() != "tpu"


def snap_ty(ty: int, ny: int) -> int:
    """Largest legal y-window <= `ty`: a divisor of ny, >= 2 (falling back to
    a single whole-y window when ny has no divisor in [2, ty])."""
    ty = max(2, min(int(ty), ny))
    while ny % ty and ty > 2:
        ty -= 1
    return ty if ny % ty == 0 else ny


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the fused kernel (paper Fig. 6 stage)."""
    tuned = autotune.tune_named("dycore_fused", grid_shape, dtype)
    return snap_ty(tuned.plan.tile[1], grid_shape[1])


def plan_tile_whole_state(grid_shape, dtype, n_fields: int) -> int:
    """Auto-tuned y-window for the whole-state kernel.

    The whole-state tile space differs from the per-field one: the shared
    `w` slab amortizes to 1/n_fields of input *traffic* but stays fully
    resident in VMEM alongside the per-field windows, so the legal tile set
    (and the Pareto pick) shifts with the field count.  The default
    (4-field) space lives in the autotune registry as
    "dycore_whole_state"; here the spec for the *actual* `n_fields` is
    built and tuned directly, leaving the registry untouched.
    """
    spec = tiling.dycore_whole_state_spec(n_fields)
    tuned = autotune.tune(spec, grid_shape, dtype)
    return snap_ty(tuned.plan.tile[1], grid_shape[1])


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "use_pallas",
                                             "ty", "interpret"))
def fused_step(f: jnp.ndarray, wcon: jnp.ndarray, utens: jnp.ndarray,
               utens_stage: jnp.ndarray, coeff: float = DEFAULT_COEFF,
               dt: float = DEFAULT_DT, use_pallas: bool = True, ty: int = 0,
               interpret: bool | None = None):
    """One fused dycore field step on a doubly-periodic (..., nz, ny, nx)
    domain.  `wcon` is the unstaggered vertical velocity; the kernel's
    staggered neighbor is the periodic next x-column.  Returns
    (f_new, stage)."""
    if not use_pallas:
        return _ref.fused_step_ref_batched(f, wcon, utens, utens_stage,
                                           coeff=coeff, dt=dt)
    if interpret is None:
        interpret = _auto_interpret()
    ny = f.shape[-2]
    ty = snap_ty(ty, ny) if ty else plan_tile(f.shape[-3:], f.dtype)
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_pallas(f, w, utens, utens_stage, coeff=coeff, dt=dt,
                               ty=ty, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "use_pallas",
                                             "ty", "interpret"))
def fused_step_whole_state(fs: jnp.ndarray, wcon: jnp.ndarray,
                           utens: jnp.ndarray, utens_stage: jnp.ndarray,
                           coeff: float = DEFAULT_COEFF,
                           dt: float = DEFAULT_DT, use_pallas: bool = True,
                           ty: int = 0, interpret: bool | None = None):
    """Whole-state fused dycore step: `fs`/`utens`/`utens_stage` are
    field-stacked (..., nf, nz, ny, nx); `wcon` is the shared unstaggered
    vertical velocity (..., nz, ny, nx).  One `pallas_call` covers every
    field; see `fused_dycore_whole_state_pallas`.  Returns (f_new, stage)
    shaped like `fs`."""
    if not use_pallas:
        wb = jnp.broadcast_to(jnp.expand_dims(wcon, -4), fs.shape)
        return _ref.fused_step_ref_batched(fs, wb, utens, utens_stage,
                                           coeff=coeff, dt=dt)
    if interpret is None:
        interpret = _auto_interpret()
    nf, _, ny, _ = fs.shape[-4:]
    ty = (snap_ty(ty, ny) if ty
          else plan_tile_whole_state(fs.shape[-3:], fs.dtype, nf))
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_whole_state_pallas(fs, w, utens, utens_stage,
                                           coeff=coeff, dt=dt, ty=ty,
                                           interpret=interpret)
