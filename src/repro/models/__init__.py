"""repro.models subpackage."""
