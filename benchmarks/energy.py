"""Paper Fig. 8 / Table 3 — energy efficiency (GFLOPS/Watt) by hardware.

Model-derived (this container has no power sensors): each shipped hardware
spec (`src/repro/specs/`) carries per-level pJ/byte coefficients, static
power, and per-kernel-class sustained utilization/wall-power calibration;
`core/perfmodel.estimate(spec=...)` turns a tuned tile plan into modeled
GFLOPS/W per machine.  The paper's reference points (vadvc 1.61 GFLOPS/W,
hdiff 21.01 on NERO) now live IN the `nero_ad9h7` spec's
`reference_points`, not in this script.

`energy_block()` is the embeddable form: `benchmarks/run.py` folds it into
`BENCH_dycore.json` as `energy_by_hardware` so the artifact carries the
cross-machine energy table.
"""

from __future__ import annotations

from typing import Dict

from benchmarks.common import emit
from repro.core import hwspec, perfmodel, tiling
from repro.core.autotune import tune

GRID = (64, 256, 256)


def energy_block(grid=GRID, dtype: str = "float32") -> Dict:
    """Modeled GFLOPS/W for hdiff + vadvc under every shipped spec (each
    machine gets its own tuned tile), with the spec's recorded paper
    reference point alongside — JSON-embeddable."""
    block: Dict = {"grid_shape": list(grid), "dtype": dtype, "specs": {},
                   "kernels": {}}
    names = hwspec.available_specs()
    for n in names:
        block["specs"][n] = hwspec.load_spec(n).describe()
    for op in (tiling.HDIFF, tiling.VADVC):
        ests = perfmodel.estimate_by_hardware(op, grid, dtype, specs=names)
        row: Dict = {}
        for n, est in ests.items():
            spec = hwspec.load_spec(n)
            ref = spec.reference_points.get(op.name, {})
            row[n] = {"gflops": est.gflops,
                      "gflops_per_watt": est.gflops_per_watt,
                      "watts": (est.energy_j / est.time_s
                                if est.time_s else 0.0),
                      "kernel_class": est.kernel_class,
                      "paper_gflops_per_watt": ref.get("gflops_per_watt")}
        block["kernels"][op.name] = row
    return block


def run():
    block = energy_block()
    for kname, row in block["kernels"].items():
        for sname, r in row.items():
            ref = r["paper_gflops_per_watt"]
            emit(f"fig8/{kname}_{sname}", 0.0,
                 f"gflops_per_watt={r['gflops_per_watt']:.2f} "
                 f"watts={r['watts']:.1f}"
                 + (f" paper={ref}GF/W" if ref is not None else ""))

    # PE/chip scaling on the default spec (the paper's Fig. 8 x-axis:
    # efficiency peaks below the peak-performance PE count).
    spec = hwspec.default_spec()
    for op in (tiling.VADVC, tiling.HDIFF):
        best = None
        for chips in (1, 2, 4, 8, 16):
            tuned = tune(op, GRID, "float32", chips=chips, spec=spec)
            est = perfmodel.estimate(tuned.plan, chips=chips, spec=spec)
            gpw = est.gflops_per_watt
            best = max(best or 0.0, gpw)
            emit(f"fig8/{op.name}_chips{chips}", est.time_s * 1e6,
                 f"gflops_per_watt={gpw:.2f}")
        emit(f"fig8/{op.name}_summary", 0.0,
             f"model_best={best:.2f}GF/W spec={spec.name}")


if __name__ == "__main__":
    run()
