"""repro.launch subpackage."""
