"""Pallas TPU kernel for COSMO vertical advection (Thomas solver).

This is the paper's vadvc PE design mapped to VMEM:

  * grid = (ny/tj, nx/ti): the horizontal plane is tiled into windows — the
    paper's auto-tuned x/y tiles (z is never tiled: "vadvc has dependencies
    in the z-dimension; therefore, it cannot be parallelized in z").
  * Each window stages full z-columns of all 7 fields in VMEM (the paper's
    URAM/BRAM column buffers), runs the forward sweep storing (ccol, dcol)
    in fp32 VMEM scratch — the paper's "intermediate buffer to allow for
    backward sweep calculation" — then back-substitutes and streams the
    tendency out.
  * The i+1-staggered wcon access is materialized as two pre-sliced inputs
    (wl = wcon[..., :-1], wr = wcon[..., 1:]) so every block transfer stays
    a clean rectangular HBM->VMEM DMA (no overlapping windows needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.kernels.vadvc.ref import BET_M, BET_P, DTR_STAGE


def _vadvc_kernel(ustage_ref, wl_ref, wr_ref, upos_ref, utens_ref,
                  ustagetens_ref, out_ref, ccol_ref, dcol_ref, *, nz: int):
    f32 = jnp.float32

    def ld(ref, k):
        return ref[pl.ds(k, 1), :, :][0].astype(f32)

    # ---- forward sweep, k = 0 ---------------------------------------------
    w1 = ld(wl_ref, 1) + ld(wr_ref, 1)
    gcv = 0.25 * w1
    cs = gcv * BET_M
    ccol0 = gcv * BET_P
    bcol = DTR_STAGE - ccol0
    u0 = ld(ustage_ref, 0)
    u1 = ld(ustage_ref, 1)
    corr = -cs * (u1 - u0)
    dcol0 = (DTR_STAGE * ld(upos_ref, 0) + ld(utens_ref, 0)
             + ld(ustagetens_ref, 0) + corr)
    divided = 1.0 / bcol
    ccol_ref[pl.ds(0, 1)] = (ccol0 * divided)[None]
    dcol_ref[pl.ds(0, 1)] = (dcol0 * divided)[None]

    # ---- forward sweep, 0 < k < nz-1 ---------------------------------------
    def fwd_body(k, _):
        wk = ld(wl_ref, k) + ld(wr_ref, k)
        wk1 = ld(wl_ref, k + 1) + ld(wr_ref, k + 1)
        gav = -0.25 * wk
        gcv = 0.25 * wk1
        as_ = gav * BET_M
        cs = gcv * BET_M
        acol = gav * BET_P
        ccol = gcv * BET_P
        bcol = DTR_STAGE - acol - ccol
        ukm, uk, ukp = (ld(ustage_ref, k - 1), ld(ustage_ref, k),
                        ld(ustage_ref, k + 1))
        corr = -as_ * (ukm - uk) - cs * (ukp - uk)
        dcol = (DTR_STAGE * ld(upos_ref, k) + ld(utens_ref, k)
                + ld(ustagetens_ref, k) + corr)
        cprev = ccol_ref[pl.ds(k - 1, 1)][0]
        dprev = dcol_ref[pl.ds(k - 1, 1)][0]
        divided = 1.0 / (bcol - cprev * acol)
        ccol_ref[pl.ds(k, 1)] = (ccol * divided)[None]
        dcol_ref[pl.ds(k, 1)] = ((dcol - dprev * acol) * divided)[None]
        return 0

    jax.lax.fori_loop(1, nz - 1, fwd_body, 0)

    # ---- forward sweep, k = nz-1 -------------------------------------------
    k = nz - 1
    wk = ld(wl_ref, k) + ld(wr_ref, k)
    gav = -0.25 * wk
    as_ = gav * BET_M
    acol = gav * BET_P
    bcol = DTR_STAGE - acol
    corr = -as_ * (ld(ustage_ref, k - 1) - ld(ustage_ref, k))
    dcol = (DTR_STAGE * ld(upos_ref, k) + ld(utens_ref, k)
            + ld(ustagetens_ref, k) + corr)
    cprev = ccol_ref[pl.ds(k - 1, 1)][0]
    dprev = dcol_ref[pl.ds(k - 1, 1)][0]
    divided = 1.0 / (bcol - cprev * acol)
    dlast = (dcol - dprev * acol) * divided
    dcol_ref[pl.ds(k, 1)] = dlast[None]

    # ---- backward sweep ------------------------------------------------------
    out_ref[pl.ds(nz - 1, 1)] = (
        DTR_STAGE * (dlast - ld(upos_ref, nz - 1)))[None].astype(out_ref.dtype)

    def bwd_body(m, datac):
        k = nz - 2 - m
        dk = dcol_ref[pl.ds(k, 1)][0]
        ck = ccol_ref[pl.ds(k, 1)][0]
        datac = dk - ck * datac
        out_ref[pl.ds(k, 1)] = (
            DTR_STAGE * (datac - ld(upos_ref, k)))[None].astype(out_ref.dtype)
        return datac

    jax.lax.fori_loop(0, nz - 1, bwd_body, dlast)


def vadvc_pallas(u_stage: jnp.ndarray, wcon: jnp.ndarray, u_pos: jnp.ndarray,
                 utens: jnp.ndarray, utens_stage: jnp.ndarray,
                 tj: int = 8, ti: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Tiled vadvc.  Fields (nz, ny, nx); wcon (nz, ny, nx+1); ny%tj==nx%ti==0."""
    nz, ny, nx = u_stage.shape
    if ny % tj or nx % ti:
        raise ValueError(f"(ny={ny}, nx={nx}) must tile by (tj={tj}, ti={ti})")
    wl = wcon[:, :, :nx]
    wr = wcon[:, :, 1:nx + 1]

    spec = pl.BlockSpec((nz, tj, ti), lambda j, i: (0, j, i))
    kernel = functools.partial(_vadvc_kernel, nz=nz)
    fn = pl.pallas_call(
        kernel,
        grid=(ny // tj, nx // ti),
        in_specs=[spec] * 6,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(u_stage.shape, u_stage.dtype),
        scratch_shapes=[
            pltpu.VMEM((nz, tj, ti), jnp.float32),   # ccol
            pltpu.VMEM((nz, tj, ti), jnp.float32),   # dcol
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="nero_vadvc",
    )
    return fn(u_stage, wl, wr, u_pos, utens, utens_stage)
