"""Jitted public entry points for hdiff (planner-aware dispatch).

`hdiff(...)` picks the implementation: the Pallas kernel on TPU (or when
`interpret=True` is forced for validation), else the pure-jnp oracle — the
differentiable path used by the weather dycore during training.
Tile sizes come from the NERO autotuner unless overridden.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels.hdiff import ref as _ref
from repro.kernels.hdiff.hdiff import hdiff_pallas


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the Pallas kernel (paper Fig. 6 stage)."""
    tuned = autotune.tune_named("hdiff", grid_shape, dtype)
    ty = tuned.plan.tile[1]
    ny = grid_shape[1]
    while ny % ty or ty < 2:      # snap to a legal divisor
        ty = ty // 2 if ty > 2 else ny
        if ty == ny:
            break
    return max(2, ty)


@functools.partial(jax.jit, static_argnames=("coeff", "use_pallas", "ty",
                                             "interpret"))
def hdiff(src: jnp.ndarray, coeff: float = _ref.DEFAULT_COEFF,
          use_pallas: bool = False, ty: int = 0,
          interpret: bool = True) -> jnp.ndarray:
    if use_pallas:
        ty = ty or plan_tile(src.shape, src.dtype)
        return hdiff_pallas(src, coeff=coeff, ty=ty, interpret=interpret)
    return _ref.hdiff(src, coeff=coeff)
