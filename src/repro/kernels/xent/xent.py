"""Pallas TPU fused cross-entropy: streaming logsumexp over vocab tiles.

The LM-head NLL is the last memory hot spot the roofline flags on train
cells: the chunked-JAX path still materializes (rows, V_local) f32 logits
per chunk in HBM (268 MB/chunk for gemma3's 262k vocab at TP=16).  The
NERO discipline applies once more: tile the vocab axis into VMEM-sized
blocks, keep the online max / normalizer / gold-logit accumulators in VMEM
scratch across the vocab grid axis, and never write a logit to HBM.

grid = (N/bn, Vp/bv), vocab innermost ("arbitrary", carries scratch);
per-row NLL comes out (N, 1) f32; the scalar reduction happens outside.
Forward-only by design — the training path keeps the differentiable
chunked-JAX form; this kernel serves eval/scoring and the roofline
variant's accounting twin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _xent_kernel(h_ref, head_ref, tgt_ref, valid_ref, out_ref,
                 m_ref, l_ref, g_ref, *, bn: int, bv: int, nv: int,
                 vocab: int, softcap: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    h = h_ref[0].astype(jnp.float32)                     # (bn, D)
    w = head_ref[...].astype(jnp.float32)                # (D, bv)
    lg = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bn,bv)
    if softcap:
        lg = jnp.tanh(lg / softcap) * softcap
    cols = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    lg = jnp.where(cols < vocab, lg, NEG_INF)            # physical padding

    tgt = tgt_ref[...]                                   # (bn, 1) int32
    hit = (cols == tgt).astype(jnp.float32)
    g_ref[...] = g_ref[...] + (lg * hit).sum(axis=-1, keepdims=True)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, lg.max(axis=-1, keepdims=True))
    l_ref[...] = (l_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.exp(lg - m_new).sum(axis=-1, keepdims=True))
    m_ref[...] = m_new

    @pl.when(j == nv - 1)
    def _finalize():
        logz = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-37))
        nll = (logz - g_ref[...]) * valid_ref[...].astype(jnp.float32)
        out_ref[0] = nll[:, 0].astype(out_ref.dtype)


def xent_pallas(hidden: jnp.ndarray, head: jnp.ndarray,
                targets: jnp.ndarray, valid: jnp.ndarray | None = None, *,
                vocab: int = 0, softcap: float = 0.0, block_n: int = 128,
                block_v: int = 512, interpret: bool = False) -> jnp.ndarray:
    """Per-row NLL.  hidden (N, D); head (D, Vp); targets (N,) int32.
    N % block_n == 0 and Vp % block_v == 0 (ops.py pads)."""
    n, d = hidden.shape
    vp = head.shape[1]
    bn = min(block_n, n)
    bv = min(block_v, vp)
    if n % bn or vp % bv:
        raise ValueError(f"(N={n}, Vp={vp}) must tile by ({bn}, {bv})")
    nn, nv = n // bn, vp // bv
    vocab = vocab or vp
    if valid is None:
        valid = jnp.ones((n,), jnp.float32)
    tgt2 = targets.astype(jnp.int32).reshape(n, 1)
    val2 = valid.reshape(n, 1).astype(jnp.float32)

    kernel = functools.partial(_xent_kernel, bn=bn, bv=bv, nv=nv,
                               vocab=vocab, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(nn, nv),
        in_specs=[
            pl.BlockSpec((1, bn, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((d, bv), lambda i, j: (0, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nn, bn), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, 1), jnp.float32),            # running max
            pltpu.VMEM((bn, 1), jnp.float32),            # running sum
            pltpu.VMEM((bn, 1), jnp.float32),            # gold logit
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="nero_fused_xent",
    )(hidden.reshape(nn, bn, d), head, tgt2, val2)
    return out.reshape(n)
