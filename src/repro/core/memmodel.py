"""Analytic per-device memory model for dry-run fit checking.

XLA:CPU's memory_analysis() is the only executable-derived number available
in this container, but the CPU backend fuses far less than TPU, so its
temp_size overestimates TPU liveness several-fold (measured ~6-8x on our
cells).  This model provides the TPU-side estimate the fit check uses; both
numbers are recorded in the dry-run JSON.

Accounting (per device):
  train:   param shards (bf16) + opt state (3x f32 shards) + grad shards
           (f32, co-live 1x) + layer-carry residuals (remat=full saves the
           per-layer carry) / microbatches + bwd working set (~2 layers of
           internals) + xent chunk buffers.
  prefill: param shards + KV-cache shards + ~2 layers of activations +
           flash chunk working set.
  decode:  param shards + KV-cache shards + O(B·d) vectors.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel import sharding as shd


def _shard_bytes(shapes_tree, shard_tree) -> int:
    """Sum per-device bytes of a pytree given its NamedShardings."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(shapes_tree),
                        jax.tree.leaves(shard_tree, is_leaf=lambda x: hasattr(
                            x, "spec"))):
        shape = leaf.shape
        spec = sh.spec
        mesh = sh.mesh
        n = 1
        for i, s in enumerate(shape):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = math.prod(mesh.shape[a] for a in axes)
            s = -(-s // div)
            n *= s / shape[i]
        total += int(n * math.prod(shape)) * np.dtype(leaf.dtype).itemsize
    return total


def estimate(cfg: ModelConfig, shape: ShapeConfig, mesh, p_shapes, p_shard,
             cache_shapes=None, cache_shard=None, *, microbatches: int = 1,
             xent_chunk: int = 512) -> Dict[str, int]:
    model_par = mesh.shape.get("model", 1)
    b_axes = shd.batch_sharding(mesh, shape.global_batch)
    dp = 1
    if b_axes:
        axes = b_axes if isinstance(b_axes, tuple) else (b_axes,)
        dp = math.prod(mesh.shape[a] for a in axes)
    b_loc = -(-shape.global_batch // dp)
    t = shape.seq_len
    d = cfg.d_model
    vocab_loc = -(-cfg.padded_vocab // model_par)

    params_b = _shard_bytes(p_shapes, p_shard)
    out = {"params": params_b}

    if shape.kind == "train":
        out["opt_state"] = params_b * 2 * 3        # 3x f32 vs bf16 shards
        out["grads"] = params_b * 2                # f32 grad shards
        # remat=full checkpoints at scan-carry (superblock) boundaries:
        # one (B, T, D) residual per scan step + remainder blocks, NOT one
        # per layer (intra-period blocks are rematerialized).
        n_carries = cfg.n_repeats + cfg.n_remainder
        carry = n_carries * b_loc * (t // microbatches) * d * 2
        out["remat_carries"] = carry
        ff_loc = max(cfg.d_ff // model_par, d // model_par, 1)
        working = 6 * b_loc * (t // microbatches) * (d + ff_loc) * 4
        out["bwd_working_set"] = working
        out["xent"] = 2 * b_loc * min(xent_chunk, t) * vocab_loc * 4 * 2
    else:
        if cache_shapes is not None and cache_shard is not None:
            out["cache"] = _shard_bytes(cache_shapes, cache_shard)
        if shape.kind == "prefill":
            ff_loc = max(cfg.d_ff // model_par, d // model_par, 1)
            out["activations"] = 4 * b_loc * t * (d + ff_loc) * 2
            out["logits_tail"] = b_loc * vocab_loc * 4
        else:
            out["activations"] = 8 * b_loc * d * 4
            out["logits"] = b_loc * vocab_loc * 4

    out["total"] = sum(out.values())
    out["fits_16g"] = bool(out["total"] <= 16 * 2**30)
    return out
