"""Declarative stencil programs: spec → plan → launch, over registered ops.

NERO's key design move (paper §4) is separating the *what* — a compound
stencil over a field set — from the *how* — a synthesized dataflow: tiling,
line buffers, burst schedule — so the host calls ONE compiled accelerator
action.  Since this PR the *what* names a REGISTERED STENCIL OPERATOR
(`weather/stencil_ops.py`), not just the fused dycore:

* `StencilProgram` is the *what*: the op (`"dycore"`, `"hdiff"`,
  `"vadvc"`, or anything `register_stencil_op` admitted), grid shape,
  ensemble, field set, precision policy (state dtype + exchange wire
  dtype), boundary, and the steps-per-round policy (`k_steps`, possibly
  `"auto"`).  `DycoreProgram` is the dycore spec's thin alias.
* `compile(program, mesh=None, ...)` is the planner: it resolves the whole
  execution strategy ONCE — execution variant, the tile plan via the op's
  declared tile spaces (`resolve_tile` hooks over `core/tiling` /
  `core/autotune`), the communication-avoiding depth
  (`core/autotune.resolve_k_steps` fed the op's declared flops and reach,
  VMEM-clamped), and the packed-exchange schedule derived ENTIRELY from
  the op's per-operand `(lo, hi)` footprint (`OperandRide`) — wcon's
  right-only staggering column and vadvc's single-ppermute wcon ride fall
  out of the declaration, not out of planner special cases.
  `compile_dycore` is the historical alias.
* `ExecutionPlan` is the *how*, immutable: `plan.step(state)` advances one
  round (`k_steps` timesteps), `plan.run(state, steps)` advances any step
  count (a shorter ragged TAIL round `k' = steps mod k` is compiled on
  demand), and `plan.report()` returns the machine-readable strategy —
  the op's declared footprint, modeled HBM traffic and per-op wire bytes
  (`core/memmodel`, footprint-driven), modeled GFLOPS
  (`core/perfmodel`), and the structural launch/collective counts that
  `core/trace_stats.assert_plan_structure` verifies against the traced
  jaxpr — which benchmarks embed verbatim in `BENCH_dycore.json`
  (`per_kernel` blocks: hdiff vs vadvc vs fused, the paper's table).

The legacy flag-soup entry points (`dycore_step`/`run`/
`make_distributed_step`) are GONE — retired ROADMAP item; every caller
builds a program and compiles it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core import autotune, hwspec, memmodel, perfmodel, tiling
from repro.kernels.dycore_fused import ops as fused_ops
from repro.weather import stencil_ops as _sops
from repro.weather.fields import PROGNOSTIC, WeatherState, zeros_state
from repro.weather.stencil_ops import (StencilOpDef, get_stencil_op,
                                       register_stencil_op,
                                       registered_stencil_ops)

VARIANTS = _sops.VARIANTS

__all__ = ["StencilProgram", "DycoreProgram", "ExchangeSchedule",
           "ExecutionPlan", "compile", "compile_dycore",
           "compile_with_fallback", "reference_program", "StencilOpDef",
           "get_stencil_op", "register_stencil_op",
           "registered_stencil_ops", "VARIANTS", "plan_cache_key",
           "ensemble_slot_view", "ensemble_slot_assign",
           "ensemble_slot_select", "slot_validity", "slot_guard"]


@dataclasses.dataclass(frozen=True)
class StencilProgram:
    """The *what* of a stencil run: op + field set + grid + policies.

    `op` names a registered `StencilOpDef` (`"dycore"`, `"hdiff"`,
    `"vadvc"`, ...).  `variant` names the execution strategy, `"auto"`
    lets the planner pick (the op's k-step round when `k_steps > 1`
    resolves, else whole-state).  `k_steps` is the steps-per-round policy:
    a positive int, or `"auto"` to let the planner resolve it from the
    op's footprint-driven exchange model (distributed; single-chip
    `"auto"` resolves to 1 — there are no collectives to amortize).
    `dtype` is the state/compute precision policy; `exchange_dtype` the
    wire precision of the packed halo exchange (e.g. `"bfloat16"`).
    `halo` defaults to the op's declared stencil reach and only exists so
    a mismatched expectation fails loudly.  `hardware` names the
    `hwspec` spec the plan's MODELED numbers target (`"tpu_v5e"`,
    `"power9"`, `"nero_ad9h7"`; None = the session default spec) — it
    changes the model, never the lowering."""

    grid_shape: Tuple[int, int, int]            # (nz, ny, nx)
    ensemble: int = 1
    fields: Tuple[str, ...] = PROGNOSTIC        # field set (fields.py)
    halo: Optional[int] = None                  # op's reach; checked if given
    dtype: str = "float32"
    boundary: str = "periodic"
    coeff: float = 0.025
    dt: float = 0.1
    variant: str = "auto"
    k_steps: Any = "auto"                       # int or "auto"
    exchange_dtype: Optional[str] = None
    op: str = "dycore"
    hardware: Optional[str] = None              # hwspec spec name, or default

    def __post_init__(self):
        object.__setattr__(self, "grid_shape",
                           tuple(int(g) for g in self.grid_shape))
        object.__setattr__(self, "fields", tuple(self.fields))
        # Normalize dtype spellings (jnp.float32, np.dtype, "float32") to
        # the canonical string so plan comparison, _check_state, and
        # report()'s JSON stay consistent.
        object.__setattr__(self, "dtype", str(jnp.dtype(self.dtype)))
        if self.exchange_dtype is not None:
            object.__setattr__(self, "exchange_dtype",
                               str(jnp.dtype(self.exchange_dtype)))
        try:
            opdef = get_stencil_op(self.op)
        except KeyError as e:
            raise ValueError(str(e)) from None
        if self.halo is None:
            object.__setattr__(self, "halo", opdef.halo)
        if len(self.grid_shape) != 3 or min(self.grid_shape) < 1:
            raise ValueError(f"grid_shape={self.grid_shape} must be a "
                             f"positive (nz, ny, nx) triple")
        if not self.fields:
            raise ValueError("a StencilProgram needs at least one field")
        if self.ensemble < 1:
            raise ValueError(f"ensemble={self.ensemble} must be >= 1")
        if self.boundary != "periodic":
            raise ValueError(f"boundary={self.boundary!r}: only 'periodic' "
                             f"is implemented (the paper's dycore test "
                             f"setup; halo exchange supplies shard edges)")
        if self.halo != opdef.halo:
            raise ValueError(f"halo={self.halo}: op {self.op!r} declares a "
                             f"fixed stencil reach of {opdef.halo}")
        if self.variant != "auto" and self.variant not in opdef.variants:
            raise ValueError(f"variant={self.variant!r} not supported by "
                             f"op {self.op!r} (supported: "
                             f"{('auto',) + opdef.variants})")
        if self.k_steps != "auto" and (not isinstance(self.k_steps, int)
                                       or self.k_steps < 1):
            raise ValueError(f"k_steps={self.k_steps!r} must be a positive "
                             f"int or 'auto'")
        if (isinstance(self.k_steps, int) and self.k_steps > 1
                and "kstep" not in opdef.variants):
            raise ValueError(f"k_steps={self.k_steps}: op {self.op!r} has "
                             f"no k-step round (its footprint does not "
                             f"deepen with k)")
        if (self.variant in ("unfused", "per_field", "whole_state")
                and self.k_steps not in ("auto", 1)):
            raise ValueError(f"variant={self.variant!r} with "
                             f"k_steps={self.k_steps}: k_steps > 1 is the "
                             f"k-step strategy — use variant='kstep' (or "
                             f"'auto')")
        if self.variant == "kstep" and self.k_steps == 1:
            raise ValueError("variant='kstep' needs k_steps >= 2 (or "
                             "'auto'); k_steps=1 IS the whole-state step")
        if self.hardware is not None:
            try:
                hwspec.load_spec(self.hardware)
            except KeyError as e:
                raise ValueError(str(e)) from None

    @property
    def n_fields(self) -> int:
        return len(self.fields)

    def to_json(self) -> Dict[str, Any]:
        """Plain-JSON spec (the `report()["program"]` block); round-trips
        through `from_json` — serving checkpoints persist programs this
        way so a restarted engine rebuilds its plan cache from keys."""
        d = dataclasses.asdict(self)
        d["grid_shape"] = list(self.grid_shape)
        d["fields"] = list(self.fields)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "StencilProgram":
        d = dict(d)
        if "stages" in d and cls is StencilProgram:
            # A serialized PipelineProgram: dispatch to the subclass (late
            # import — pipeline.py builds on this module).
            from repro.weather.pipeline import PipelineProgram
            return PipelineProgram.from_json(d)
        d["grid_shape"] = tuple(d["grid_shape"])
        d["fields"] = tuple(d["fields"])
        return cls(**d)


# The dycore spec is a thin alias: `op` already defaults to "dycore".
DycoreProgram = StencilProgram


def plan_cache_key(program: StencilProgram,
                   ensemble: Optional[int] = None) -> StencilProgram:
    """The canonical compile-once-serve-forever cache key for `program`.

    `StencilProgram.__post_init__` already normalizes every field (dtype
    spellings, tuple-ization), and the spec is frozen and hashable — so
    the program itself IS the key.  `ensemble` rebinds the batch axis:
    a serving engine folds single-member requests into the ensemble axis
    of one shared plan, so requests that differ ONLY in ensemble share a
    compiled plan keyed at the engine's slot count."""
    if ensemble is not None and ensemble != program.ensemble:
        program = dataclasses.replace(program, ensemble=ensemble)
    return program


# --- ensemble-slot views: requests <-> the (e, ...) batch axis -------------
# Every WeatherState leaf is (E, nz, ny, nx); a serving slot is one member.


def ensemble_slot_view(state: WeatherState, e: int) -> WeatherState:
    """Member `e` of a batched state as an ensemble-1 state (a view — no
    copy until the caller materializes it)."""
    return jax.tree_util.tree_map(lambda a: a[e:e + 1], state)


def ensemble_slot_assign(batch: WeatherState, indices,
                         sub: WeatherState) -> WeatherState:
    """Functionally write `sub` (leading dim = len(indices)) into the given
    ensemble slots of `batch`."""
    idx = jnp.asarray(indices, jnp.int32)
    return jax.tree_util.tree_map(lambda b, s: b.at[idx].set(s), batch, sub)


def ensemble_slot_select(mask, new: WeatherState,
                         old: WeatherState) -> WeatherState:
    """Per-slot select: slots where `mask` (shape (E,)) is True take `new`,
    the rest keep `old` — how a serving engine rolls back slots that sat
    out a shorter-than-their-next-part round."""
    def sel(n, o):
        m = jnp.reshape(jnp.asarray(mask), (-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree_util.tree_map(sel, new, old)


@jax.jit
def slot_validity(state: WeatherState, limit) -> jnp.ndarray:
    """Per-slot physics validity: a fused NaN/Inf + magnitude-bound
    reduction over every leaf, returning a ``(E,)`` bool — True where the
    member is entirely finite and within ``|x| <= limit``.  One cheap
    jitted reduction per round boundary is the serving engine's guard; it
    reads every leaf once and writes E booleans, so it cannot perturb any
    slot's bits."""
    def per_leaf(a):
        axes = tuple(range(1, a.ndim))      # no reshape: stays shardable
        finite = jnp.all(jnp.isfinite(a), axis=axes)
        mag = jnp.max(jnp.where(jnp.isfinite(a), jnp.abs(a), 0.0),
                      axis=axes)
        return finite & (mag <= limit)
    per = [per_leaf(leaf) for leaf in jax.tree_util.tree_leaves(state)]
    return jnp.all(jnp.stack(per), axis=0)


# Odd 32-bit mixing constants (Knuth/FNV lineage) for the fingerprint.
_FP_MIX = np.uint32(0x9E3779B1)
_FP_LEAF = np.uint32(0x01000193)
_FP_AXIS = (np.uint32(0x85EBCA6B), np.uint32(0xC2B2AE35),
            np.uint32(0x27D4EB2F), np.uint32(0x165667B1))


@jax.jit
def slot_guard(state: WeatherState, limit):
    """`slot_validity` plus a per-slot content FINGERPRINT, one fused
    jitted pass: returns ``(ok, fp)`` with `ok` the ``(E,)`` validity
    bool and `fp` an ``(E,)`` uint32 digest of every leaf's exact bits.

    The fingerprint is the cross-device divergence guard the validity
    reduction cannot be: finite, in-bounds corruption (a bad halo wire
    buffer, a flipped mantissa bit on one shard) passes every NaN/Inf/
    magnitude test, but it changes the digest.  The serving engine
    records each slot's digest at round boundaries and demands that slots
    which did NOT advance a round (rolled-back and idle slots) keep it
    bit-for-bit — so per-shard divergence is caught at the boundary where
    it occurs, not steps later when it blows up.

    Construction: element bits (bitcast, never rounded) are mixed with a
    position hash (per-axis `broadcasted_iota` — no reshape, so the
    reduction stays shardable and the digest is a function of GLOBAL
    positions, invariant to how the array is sharded) and XOR-folded over
    every non-ensemble axis; leaves combine order-sensitively.  XOR makes
    the fold order-independent, so per-shard partial folds under jit
    compose to the same digest on ANY mesh — the property the elastic
    failover relies on when it compares digests across a reshard."""
    def leaf_ok(a):
        axes = tuple(range(1, a.ndim))      # no reshape: stays shardable
        finite = jnp.all(jnp.isfinite(a), axis=axes)
        mag = jnp.max(jnp.where(jnp.isfinite(a), jnp.abs(a), 0.0),
                      axis=axes)
        return finite & (mag <= limit)

    def leaf_fp(a):
        u = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}.get(
            a.dtype.itemsize)
        if u is not None:
            bits = jax.lax.bitcast_convert_type(a, u).astype(jnp.uint32)
        else:                               # 8-byte leaves: (..., 2) u32
            bits = jax.lax.bitcast_convert_type(a, jnp.uint32)
        pos = jnp.zeros((), jnp.uint32)
        for d in range(1, bits.ndim):
            iota = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, d)
            pos = pos + iota * _FP_AXIS[d % len(_FP_AXIS)]
        v = (bits + pos) * _FP_MIX
        v = v ^ (v >> 16)                   # element swaps don't cancel
        # XOR-fold every non-ensemble axis by repeated halving (XLA has
        # no built-in xor reduction on every backend; a log-n cascade of
        # elementwise XORs lowers everywhere and computes the same fold).
        for axis in range(v.ndim - 1, 0, -1):
            while v.shape[axis] > 1:
                n = v.shape[axis]
                h = n // 2
                r = (jax.lax.slice_in_dim(v, 0, h, axis=axis)
                     ^ jax.lax.slice_in_dim(v, h, 2 * h, axis=axis))
                if n % 2:
                    r = jnp.concatenate(
                        [r, jax.lax.slice_in_dim(v, 2 * h, n, axis=axis)],
                        axis=axis)
                v = r
        return v.reshape(v.shape[0])

    oks, fp = [], None
    for leaf in jax.tree_util.tree_leaves(state):
        oks.append(leaf_ok(leaf))
        f = leaf_fp(leaf)
        fp = f if fp is None else (fp * _FP_LEAF) ^ f
    return jnp.all(jnp.stack(oks), axis=0), fp


@dataclasses.dataclass(frozen=True)
class ExchangeSchedule:
    """Resolved halo-exchange strategy of a distributed plan.

    `mode="packed"` is the stacked ragged exchange: every operand shares
    one flattened wire buffer per direction (at most one `ppermute` pair
    each; a side nothing rides is elided).  `rides` are the RESOLVED
    per-operand `(lo, hi)` depths straight from the op's registry
    declaration — e.g. the dycore's `wcon` at `(k·HALO, k·HALO + 1)` in x
    (the `+1` staggering column comes from the RIGHT neighbor only), or
    vadvc's lone `("wcon", (0, 0), (0, 1))` single-ppermute ride.
    `mode="per_operand"` is the legacy per-field exchange of the dycore's
    per-field/unfused variants."""

    mode: str                                   # "packed" | "per_operand"
    shards: Tuple[int, int]                     # (py, px)
    rides: Tuple[Tuple[str, Tuple[int, int], Tuple[int, int]], ...]
    wire_dtype: Optional[str]

    def _ride(self, operand: str):
        for name, dy, dx in self.rides:
            if name == operand:
                return dy, dx
        return None

    @property
    def depth_y(self) -> int:
        r = self._ride("fields")
        return r[0][1] if r else 0

    @property
    def depth_x(self) -> int:
        r = self._ride("fields")
        return r[1][0] if r else 0

    @property
    def wcon_depth_x(self) -> Optional[Tuple[int, int]]:
        r = self._ride("wcon")
        return r[1] if r else None

    def describe(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "mode": self.mode, "shards": list(self.shards),
            "rides": {name: {"depth_y": list(dy), "depth_x": list(dx)}
                      for name, dy, dx in self.rides},
            "depth_y": self.depth_y, "depth_x": self.depth_x,
            "wire_dtype": self.wire_dtype}
        if self.wcon_depth_x is not None:
            d["wcon_depth_x"] = list(self.wcon_depth_x)
        return d


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The *how*: an immutable, fully-resolved execution strategy.

    Produced by `compile`; exposes `step(state)` (one round = `k_steps`
    timesteps), `run(state, steps)` (any step count; a shorter tail round
    is compiled for `steps % k_steps`), and `report()` (the
    machine-readable strategy benchmarks embed verbatim)."""

    program: StencilProgram
    variant: str                                # resolved, never "auto"
    k_steps: int                                # resolved int
    tile_ty: Optional[int]                      # None for unfused
    tile_plan: Optional[Any]                    # tiling.TilePlan
    local_grid: Tuple[int, int, int]            # per-shard (nz, ly, lx)
    compute_grid: Tuple[int, int, int]          # grid the kernel tiles over
    rides: Tuple[Tuple[str, Tuple[int, int], Tuple[int, int]], ...]
    interpret: bool
    prefetch_w: bool
    exchange: Optional[ExchangeSchedule]        # None on a single chip
    pallas_calls_per_round: int
    collectives_per_round: int
    mesh: Optional[Mesh] = dataclasses.field(default=None, repr=False,
                                             compare=False)
    mesh_axes: Tuple[Optional[str], str, str] = ("pod", "data", "model")
    _cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                     compare=False)

    # -- public API ---------------------------------------------------------
    @property
    def op_def(self) -> StencilOpDef:
        return get_stencil_op(self.program.op)

    @property
    def hardware(self) -> str:
        """Spec name the plan's modeled numbers target (never None)."""
        return self.program.hardware or hwspec.default_spec_name()

    def hardware_spec(self) -> hwspec.HardwareSpec:
        return hwspec.load_spec(self.hardware)

    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    @property
    def shards(self) -> Tuple[int, int]:
        return self.exchange.shards if self.exchange is not None else (1, 1)

    @property
    def state_spec(self) -> Optional[P]:
        """PartitionSpec for `domain.shard_state`; None on a single chip."""
        if self.mesh is None:
            return None
        ax_e, ax_y, ax_x = self.mesh_axes
        have_e = ax_e is not None and ax_e in self.mesh.axis_names
        return P(ax_e if have_e else None, None, ax_y, ax_x)

    def step(self, state: WeatherState) -> WeatherState:
        """Advance ONE round: `k_steps` timesteps in the plan's strategy."""
        self._check_state(state)
        return self._step_fn()(state)

    def run(self, state: WeatherState, steps: int) -> WeatherState:
        """Advance `steps` timesteps: `steps // k_steps` full rounds plus,
        when `steps % k_steps != 0`, one shorter TAIL round at
        `k' = steps mod k_steps` (a derived plan, compiled on demand) —
        no step count is rejected."""
        if not isinstance(steps, int) or steps < 0:
            raise ValueError(f"steps={steps!r} must be a non-negative int")
        self._check_state(state)
        rounds, tail = divmod(steps, self.k_steps)
        if rounds:
            if self.mesh is None:
                state = self._rounds_fn(rounds)(state)
            else:
                # Deliberately a Python loop, not a scan: each round is one
                # jitted shard_map program, which keeps run() composable
                # with host-side work between rounds (checkpoints, I/O) and
                # keeps the traced round — what the structural tests and
                # report() describe — the unit of execution.
                step = self._step_fn()
                for _ in range(rounds):
                    state = step(state)
        if tail:
            state = self.round_plan(tail).step(state)
        return state

    def round_plan(self, k: int) -> "ExecutionPlan":
        """The plan that advances a round of exactly `k` timesteps: `self`
        when `k == k_steps`, else a derived plan for the shorter round
        (cached — this is `run()`'s ragged-TAIL machinery, public so a
        serving engine can retire ragged step counts at round boundaries
        through the exact same lowering a solo `run()` would use)."""
        if not isinstance(k, int) or not 1 <= k <= self.k_steps:
            raise ValueError(f"round_plan(k={k!r}): k must be an int in "
                             f"[1, k_steps={self.k_steps}]")
        if k == self.k_steps:
            return self
        return self._tail_plan(k)

    def report(self) -> Dict[str, Any]:
        """Machine-readable strategy: the resolved op + variant + tile + k
        + exchange, the op's declared footprint, the structural
        launch/collective counts per round (verifiable against a traced
        jaxpr via `trace_stats.assert_plan_structure`), and the modeled
        HBM-traffic / wire-byte / GFLOPS numbers.  Plain JSON-serializable
        types only — benchmarks embed it verbatim."""
        prog = self.program
        opdef = self.op_def
        rep: Dict[str, Any] = {
            "op": prog.op,
            "program": {
                "op": prog.op,
                "grid_shape": list(prog.grid_shape),
                "ensemble": prog.ensemble,
                "fields": list(prog.fields),
                "halo": prog.halo,
                "dtype": prog.dtype,
                "boundary": prog.boundary,
                "coeff": prog.coeff,
                "dt": prog.dt,
                "variant": prog.variant,
                "k_steps": prog.k_steps,
                "exchange_dtype": prog.exchange_dtype,
                "hardware": prog.hardware,
                # A PipelineProgram's chain: report()["program"] must
                # round-trip through StencilProgram.from_json like
                # to_json() does (serving checkpoints persist it).
                **({"stages": [st.describe()
                               for st in getattr(prog, "stages")]}
                   if getattr(prog, "stages", None) else {}),
            },
            "variant": self.variant,
            "k_steps": self.k_steps,
            "footprint": opdef.describe(prog.n_fields, self.k_steps),
            "tile": (None if self.tile_plan is None
                     else {"ty": self.tile_ty, **self.tile_plan.describe()}),
            "interpret": self.interpret,
            "prefetch_w": self.prefetch_w,
            "distributed": self.distributed,
            "mesh_axes": list(self.mesh_axes),
            "local_grid": list(self.local_grid),
            "compute_grid": list(self.compute_grid),
            "exchange": (None if self.exchange is None
                         else self.exchange.describe()),
            "pallas_calls_per_round": self.pallas_calls_per_round,
            "collectives_per_round": self.collectives_per_round,
        }
        # The traffic model needs a tile; unfused plans have none, so model
        # at the tile the default variant WOULD resolve (recorded as
        # traffic_model_ty so the artifact is self-describing; cached — it
        # is an autotune sweep and report() is advertised as cheap).
        model_ty = self.tile_ty
        if model_ty is None:
            model_ty = self._cache.get("traffic_model_ty")
            if model_ty is None:
                # Resolve over the PHYSICAL grid (not the padded/folded
                # compute grid): the traffic model below is evaluated on
                # the physical grid, so the modeled tile must be a legal
                # window of it.
                tp = opdef.resolve_tile("whole_state", prog.grid_shape,
                                        prog.dtype, prog.n_fields,
                                        prog.ensemble, 1)
                model_ty = tp.tile[1]
                self._cache["traffic_model_ty"] = model_ty
        rep["traffic_model_ty"] = model_ty
        rep["traffic"] = opdef.traffic(self, model_ty)
        if (self.exchange is not None and self.exchange.mode == "packed"
                and opdef.exchange_model is not None):
            rep["exchange_model"] = opdef.exchange_model(self)
        else:
            rep["exchange_model"] = None
        # Modeled performance of the resolved tile plan on the program's
        # target hardware spec — the per-op GFLOPS / GFLOPS-per-watt axis
        # of the paper's two-kernel table.
        if self.tile_plan is not None:
            est = self._cache.get("perf_est")
            if est is None:
                est = perfmodel.estimate(self.tile_plan,
                                         spec=self.hardware_spec())
                self._cache["perf_est"] = est
            rep["model"] = {"time_us": est.time_s * 1e6,
                            "gflops": est.gflops,
                            "gflops_per_watt": est.gflops_per_watt,
                            "bottleneck": est.bottleneck,
                            "hardware": est.hardware,
                            "kernel_class": est.kernel_class,
                            "spec_fingerprint":
                                self.hardware_spec().fingerprint}
        else:
            rep["model"] = None
        rep["model_by_hardware"] = self.model_by_hardware()
        return rep

    def model_by_hardware(self, grid_shape: Optional[Tuple[int, int, int]]
                          = None) -> Dict[str, Any]:
        """The paper's cross-machine two-kernel table, modeled: for hdiff
        and vadvc (the paper's kernels) and every shipped hardware spec,
        re-tune the tile window FOR that machine's hierarchy and model
        time / GFLOPS / GFLOPS-per-watt under its spec, plus the modeled
        speedup over the POWER9 baseline.  `grid_shape` defaults to the
        program's grid (benchmarks evaluate it at the paper's domain);
        cached per grid — it is a handful of analytic autotune sweeps."""
        grid = tuple(int(g) for g in (grid_shape or self.program.grid_shape))
        cached = self._cache.get(("model_by_hardware", grid))
        if cached is not None:
            return cached
        spec_names = hwspec.available_specs()
        out: Dict[str, Any] = {
            "grid_shape": list(grid),
            "dtype": self.program.dtype,
            "baseline": "power9",
            "specs": {n: hwspec.load_spec(n).describe() for n in spec_names},
            "kernels": {},
        }
        for kname in ("hdiff", "vadvc"):
            try:
                ests = perfmodel.estimate_by_hardware(
                    autotune.get_op(kname), grid, self.program.dtype,
                    specs=spec_names)
            except ValueError:
                # No legal tile at this grid for this kernel (tiny smoke
                # grids): the table row is simply absent, never a crash.
                continue
            t_p9 = ests["power9"].time_s if "power9" in ests else 0.0
            row: Dict[str, Any] = {}
            for name, est in ests.items():
                row[name] = {
                    "time_us": est.time_s * 1e6,
                    "gflops": est.gflops,
                    "gflops_per_watt": est.gflops_per_watt,
                    "bottleneck": est.bottleneck,
                    "kernel_class": est.kernel_class,
                    "speedup_vs_power9": (t_p9 / est.time_s
                                          if est.time_s > 0 else 0.0),
                }
            out["kernels"][kname] = row
        self._cache[("model_by_hardware", grid)] = out
        return out

    # -- internals ----------------------------------------------------------
    def _check_state(self, state: WeatherState) -> None:
        if state.grid_shape != self.program.grid_shape:
            raise ValueError(
                f"state grid {state.grid_shape} does not match the "
                f"program's {self.program.grid_shape}; compile a plan for "
                f"this grid")
        if str(state.wcon.dtype) != self.program.dtype:
            raise ValueError(
                f"state dtype {state.wcon.dtype} does not match the "
                f"program's precision policy {self.program.dtype!r}")
        if (state.wcon.ndim == 4
                and int(state.wcon.shape[0]) != self.program.ensemble):
            raise ValueError(
                f"state ensemble {int(state.wcon.shape[0])} does not match "
                f"the program's ensemble={self.program.ensemble} (the "
                f"report() must describe what actually runs)")
        missing = [n for n in self.program.fields if n not in state.fields]
        if missing:
            raise ValueError(f"state is missing program fields {missing}")

    def _step_fn(self):
        fn = self._cache.get("step")
        if fn is None:
            fn = (_build_distributed_step(self) if self.mesh is not None
                  else _build_local_step(self))
            self._cache["step"] = fn
        return fn

    def _rounds_fn(self, rounds: int):
        """Jitted scan of `rounds` full rounds (single-chip), cached per
        round count so repeated `run` calls don't re-trace the scan."""
        fn = self._cache.get(("rounds", rounds))
        if fn is None:
            step = self._step_fn()

            @jax.jit
            def fn(state):
                def body(s, _):
                    return step(s), ()
                out, _ = jax.lax.scan(body, state, (), length=rounds)
                return out
            self._cache[("rounds", rounds)] = fn
        return fn

    def _tail_plan(self, k_tail: int) -> "ExecutionPlan":
        plan = self._cache.get(("tail", k_tail))
        if plan is None:
            prog = dataclasses.replace(self.program, variant="auto",
                                       k_steps=k_tail)
            ax_e, ax_y, ax_x = self.mesh_axes
            plan = compile(prog, mesh=self.mesh, ax_e=ax_e,
                           ax_y=ax_y, ax_x=ax_x,
                           interpret=self.interpret,
                           prefetch_w=self.prefetch_w)
            self._cache[("tail", k_tail)] = plan
        return plan


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------


def compile(program: StencilProgram, mesh: Optional[Mesh] = None, *,
            ax_e: Optional[str] = "pod", ax_y: str = "data",
            ax_x: str = "model", interpret: Optional[bool] = None,
            prefetch_w: Optional[bool] = None,
            tune: Optional[str] = None,
            _tile_ty: Optional[int] = None) -> ExecutionPlan:
    """Resolve `program`'s whole execution strategy once; return the plan.

    Works over any REGISTERED stencil op: the exchange schedule, the
    structural launch/collective counts, the k-step resolution, and the
    tile plan are all derived from the op's `StencilOpDef` declaration
    (footprint rides, flops, tile spaces, lowering hooks) — the planner
    has no per-op branches.

    With `mesh`, the plan shards y over `ax_y`, x over `ax_x`, the
    ensemble over `ax_e` when present (z always chip-local), and its step
    runs the distributed round: the op's packed halo exchange + the
    chip-local kernel + interior crop.  Overrides: `interpret` (default:
    auto — native Pallas on TPU, interpreter elsewhere) and `prefetch_w`
    (the dycore k-step kernel's double-buffered `w` DMA pipeline; default:
    on outside interpret mode).

    `tune` picks the tuning mode: None / `"model"` resolve the tile from
    the analytic model (the paper's "model-guided" mode); `"measure"`
    re-tunes the y-window EMPIRICALLY — each candidate plan is compiled
    and wall-clock timed on THIS process's jax backend (the paper's
    "auto-tuned" mode, `autotune.tune(measure=...)`) and the winner is
    persisted to an on-disk cache keyed on (program, hardware-spec
    fingerprint, backend), so a plan is measured once and every later
    process reuses the pick.  `_tile_ty` is the internal pin the measured
    path re-enters with."""
    if not isinstance(program, StencilProgram):
        raise TypeError(f"compile wants a StencilProgram, got "
                        f"{type(program).__name__}")
    if tune not in (None, "model", "measure"):
        raise ValueError(f"tune={tune!r}: expected None, 'model', or "
                         f"'measure'")
    opdef = get_stencil_op(program.op)
    nz, ny, nx = program.grid_shape
    nf = program.n_fields
    halo = opdef.halo
    if interpret is None:
        interpret = fused_ops._auto_interpret()

    if mesh is not None:
        for ax in (ax_y, ax_x):
            if ax not in mesh.axis_names:
                raise ValueError(f"mesh {dict(mesh.shape)} has no axis "
                                 f"{ax!r}")
        py, px = int(mesh.shape[ax_y]), int(mesh.shape[ax_x])
        if ny % py or nx % px:
            raise ValueError(f"grid (ny={ny}, nx={nx}) does not divide over "
                             f"(py={py}, px={px}) shards")
    else:
        py = px = 1
    ly, lx = ny // py, nx // px

    # --- steps-per-round: the communication-avoiding k (one resolver,
    # fed the OP'S declared flops/reach and footprint-driven wire model) ---
    k = program.k_steps
    if k == "auto":
        if ("kstep" not in opdef.variants
                or program.variant not in ("auto", "kstep") or mesh is None):
            # The op (or pinned variant) steps once per round, or there
            # are no collectives at all: nothing to amortize.
            k = 1
        else:
            def exchange_model(kk):
                return memmodel.packed_exchange_model(
                    program.grid_shape, program.dtype,
                    rides=opdef.memmodel_rides(nf), k=kk, shards=(py, px),
                    compute_halo=(kk * halo, kk * halo))
            if opdef.kstep_vmem_check is not None:
                # The op declares its OWN in-kernel k-step legality.
                vmem_check = opdef.kstep_vmem_check(program, (py, px))
            elif opdef.inkernel_kstep:
                vmem_check = None     # the fused dycore's default check
            else:
                vmem_check = lambda kk: None
            k = autotune.resolve_k_steps(
                program.grid_shape, program.dtype, (py, px), n_fields=nf,
                halo=halo, flops_per_point=opdef.flops_per_point,
                exchange_model=exchange_model, vmem_check=vmem_check)

    # --- execution variant ---
    variant = program.variant
    if variant == "auto":
        variant = "kstep" if k > 1 else "whole_state"
    if variant == "kstep" and k == 1:
        variant = "whole_state"    # k resolved to 1: same round, one step
    if k > 1 and variant != "kstep":
        raise ValueError(f"k_steps={k} requires the k-step round "
                         f"(variant {variant!r} steps one at a time)")
    if (program.exchange_dtype is not None
            and variant not in opdef.packed_variants):
        raise ValueError("exchange_dtype requires a packed (stacked) "
                         "exchange variant of op "
                         f"{program.op!r} ({opdef.packed_variants})")

    # --- exchange schedule + the grid the kernel actually tiles over,
    # both derived from the op's declared footprint ---
    rides = opdef.resolved_rides(k)
    hy = hx = k * halo
    pads = (mesh is not None) or opdef.pads_single_chip
    compute_grid = ((nz, ly + 2 * hy, lx + 2 * hx) if pads
                    else program.grid_shape)
    if pads:
        # A ride deeper than the local slab would need data from beyond
        # the adjacent neighbor (or, single-chip, wrap more than one
        # period) — refuse at compile time, loudly.
        for name, dy, dx in rides:
            if max(dy) > ly or max(dx) > lx:
                raise ValueError(
                    f"k_steps={k} needs a ({max(dy)}, {max(dx)})-deep halo "
                    f"for {name!r} but the local slab is only ({ly}, {lx}); "
                    f"use fewer shards, a bigger grid, or a smaller "
                    f"k_steps")
    exchange = None
    if mesh is not None:
        if variant in opdef.packed_variants:
            exchange = ExchangeSchedule(mode="packed", shards=(py, px),
                                        rides=rides,
                                        wire_dtype=program.exchange_dtype)
        else:
            # Legacy per-operand exchange (dycore per_field/unfused): one
            # exchange per operand at the per-step reach.
            exchange = ExchangeSchedule(mode="per_operand", shards=(py, px),
                                        rides=opdef.resolved_rides(1),
                                        wire_dtype=None)
            compute_grid = (nz, ly + 2 * halo, lx + 2 * halo)

    # --- tile plan: the op's own resolver over its registered spaces ---
    tile_plan = opdef.resolve_tile(variant, compute_grid, program.dtype,
                                   nf, program.ensemble, k)
    if _tile_ty is not None and tile_plan is not None:
        # The measured-tuning pin: same plan, y-window overridden by the
        # empirical winner (always a candidate of the same tile space).
        tile_plan = dataclasses.replace(
            tile_plan, tile=(tile_plan.tile[0], int(_tile_ty),
                             tile_plan.tile[2]))
    ty = tile_plan.tile[1] if tile_plan is not None else None

    # --- structural costs per round (trace-verifiable, see trace_stats) ---
    pallas_calls = opdef.pallas_calls(variant, nf, k)
    if mesh is None:
        collectives = 0
    else:
        collectives = (opdef.collectives(variant, nf, py, px, k)
                       if opdef.collectives is not None else None)
        if collectives is None:
            collectives = opdef.generic_collectives(py, px, k)

    resolved_prefetch = (not interpret) if prefetch_w is None else prefetch_w

    plan = ExecutionPlan(
        program=program, variant=variant, k_steps=k, tile_ty=ty,
        tile_plan=tile_plan, local_grid=(nz, ly, lx),
        compute_grid=compute_grid, rides=rides, interpret=interpret,
        prefetch_w=resolved_prefetch, exchange=exchange,
        pallas_calls_per_round=pallas_calls,
        collectives_per_round=collectives, mesh=mesh,
        mesh_axes=(ax_e, ax_y, ax_x))
    if tune == "measure" and _tile_ty is None:
        plan = _measured_retune(plan, program, mesh, ax_e=ax_e, ax_y=ax_y,
                                ax_x=ax_x, interpret=interpret,
                                prefetch_w=prefetch_w)
    return plan


def _measured_retune(plan: ExecutionPlan, program: StencilProgram,
                     mesh: Optional[Mesh], *, ax_e, ax_y, ax_x,
                     interpret, prefetch_w) -> ExecutionPlan:
    """The `tune="measure"` path: empirically pick the y-window.

    The candidate set is the analytic tuner's own (the op's tile space at
    the plan's compute grid), scored by `autotune.tune(measure=...)` with
    a wall-clock measure callable: a candidate that keeps the kernel's
    streamed axes whole (same z/x window as the resolved plan — the
    y-window is the lowering's one pinnable knob) is compiled with its
    `ty` pinned and its round timed on this process's backend; any other
    candidate scores `inf`.  The winning ty is persisted keyed on
    (program cache key + shards, spec fingerprint, backend) — a second
    process compiles the winner directly, measuring nothing."""
    if plan.tile_plan is None:
        return plan   # oracle variant: no tile to tune
    spec = plan.hardware_spec()
    backend = jax.default_backend()
    shards = plan.shards
    cache_key = autotune.tune_cache_key(
        (plan_cache_key(program), shards), spec, backend)
    entry = autotune.tune_cache_load(cache_key)
    if entry is None:
        entry = _measure_tile_candidates(plan, program, mesh, ax_e=ax_e,
                                         ax_y=ax_y, ax_x=ax_x,
                                         interpret=interpret,
                                         prefetch_w=prefetch_w)
        entry.update({"backend": backend, "spec": spec.name,
                      "spec_fingerprint": spec.fingerprint,
                      "k_steps": plan.k_steps})
        autotune.tune_cache_store(cache_key, entry)
    ty = entry.get("tile_ty")
    if ty is None or int(ty) == plan.tile_ty:
        return plan
    return compile(program, mesh=mesh, ax_e=ax_e, ax_y=ax_y, ax_x=ax_x,
                   interpret=interpret, prefetch_w=prefetch_w,
                   _tile_ty=int(ty))


def _measure_tile_candidates(plan: ExecutionPlan, program: StencilProgram,
                             mesh: Optional[Mesh], *, ax_e, ax_y, ax_x,
                             interpret, prefetch_w,
                             max_measured: int = 8) -> Dict[str, Any]:
    """Wall-clock-score the tile candidates; returns the cache entry."""
    base = plan.tile_plan
    state = zeros_state(program.grid_shape, program.ensemble,
                        program.dtype, names=program.fields)
    timed: Dict[int, float] = {}
    # Distinct measurable ty values, analytically ordered; cap how many we
    # actually time (each costs a compile + a few steps).
    cands = tiling.candidate_tiles(base.op, base.grid_shape, program.dtype,
                                   plan.hardware_spec().hierarchy())
    ty_pool = sorted({p.tile[1] for p in cands
                      if p.tile[0] == base.tile[0]
                      and p.tile[2] == base.tile[2]})
    if len(ty_pool) > max_measured:
        stride = len(ty_pool) / max_measured
        ty_pool = sorted({ty_pool[int(i * stride)]
                          for i in range(max_measured)})
    allowed = set(ty_pool)

    def measure(cand: tiling.TilePlan) -> float:
        ty = cand.tile[1]
        if (cand.tile[0] != base.tile[0] or cand.tile[2] != base.tile[2]
                or ty not in allowed):
            return math.inf
        if ty not in timed:
            try:
                cp = compile(program, mesh=mesh, ax_e=ax_e, ax_y=ax_y,
                             ax_x=ax_x, interpret=interpret,
                             prefetch_w=prefetch_w, _tile_ty=ty)

                def run_once():
                    jax.block_until_ready(cp.step(state))
                timed[ty] = autotune.measure_walltime(run_once)
            except Exception:   # noqa: BLE001 — kernel rejects this window
                timed[ty] = math.inf
        return timed[ty]

    try:
        tuned = autotune.tune(base.op, base.grid_shape, program.dtype,
                              spec=plan.hardware_spec(), measure=measure)
        best_ty = int(tuned.plan.tile[1])
        best_s = timed.get(best_ty)
    except ValueError:
        best_ty, best_s = None, None
    if best_s is None or not math.isfinite(best_s):
        best_ty, best_s = None, None      # nothing ran: keep analytic pick
    return {"tile_ty": plan.tile_ty if best_ty is None else best_ty,
            "measured_s": best_s,
            "measured": {str(k): v for k, v in sorted(timed.items())}}


# The historical dycore entry point: same planner, op defaults to "dycore".
compile_dycore = compile


def reference_program(program: StencilProgram) -> StencilProgram:
    """`program` rebound to its op's REFERENCE lowering: the unfused
    (oracle) variant when the op declares one, step-at-a-time rounds, no
    wire compression — the maximally-conservative availability fallback.
    Numerics are the same physics but NOT guaranteed bitwise-equal to the
    fused variants (different loop structure); callers that degrade this
    far must surface it (see `compile_with_fallback`)."""
    opdef = get_stencil_op(program.op)
    ref = "unfused" if "unfused" in opdef.variants else opdef.variants[0]
    return dataclasses.replace(program, variant=ref, k_steps=1,
                               exchange_dtype=None)


def compile_with_fallback(program: StencilProgram,
                          mesh: Optional[Mesh] = None, *,
                          ax_e: Optional[str] = "pod", ax_y: str = "data",
                          ax_x: str = "model",
                          interpret: Optional[bool] = None,
                          prefetch_w: Optional[bool] = None,
                          attempt_hook=None
                          ) -> Tuple[ExecutionPlan, Optional[str], list]:
    """`compile` with graceful degradation: a retry chain over

      1. ``native``    — the program exactly as asked (Pallas lowering,
         `interpret` as given / auto),
      2. ``interpret`` — the SAME plan forced through the Pallas
         interpreter (survives backend codegen/lowering failures; on a
         backend where auto-interpret already resolves True this is the
         identical plan, so results stay bit-identical),
      3. ``reference`` — `reference_program(program)`: the op's unfused
         oracle lowering, one step per round (availability over
         bit-identity — the last resort).

    Returns ``(plan, fallback, errors)``: `fallback` is None when the
    native attempt won, else the winning stage name; `errors` lists
    ``(stage, repr(exc))`` for every failed attempt.  Raises the LAST
    error only if every stage fails.  `attempt_hook(program, stage)` is
    the fault-injection seam — `testing.faults.FaultInjector.on_compile`
    plugs in here to rehearse lowering failures deterministically."""
    attempts = [
        ("native", program, {"interpret": interpret}),
        ("interpret", program, {"interpret": True}),
        ("reference", reference_program(program), {"interpret": True}),
    ]
    errors: list = []
    for stage, prog, kw in attempts:
        try:
            if attempt_hook is not None:
                attempt_hook(prog, stage)
            plan = compile(prog, mesh=mesh, ax_e=ax_e, ax_y=ax_y, ax_x=ax_x,
                           prefetch_w=prefetch_w, **kw)
            return plan, (None if stage == "native" else stage), errors
        except Exception as e:  # noqa: BLE001 — any lowering failure degrades
            errors.append((stage, repr(e)))
            last = e
    raise RuntimeError(
        f"compile fallback chain exhausted for op={program.op!r}: "
        f"{errors}") from last


# ---------------------------------------------------------------------------
# Lowering: plan -> step callable (shared shard_map/jit scaffolding; the
# per-op compute comes from the registry's lowering hooks)
# ---------------------------------------------------------------------------


def _build_local_step(plan: ExecutionPlan):
    """Single-chip lowering.  Ops with a dedicated periodic-domain path
    (the dycore's kernels wrap in-kernel) supply `build_local_step`;
    otherwise the op's shard-local round runs directly — its packed
    exchange degenerates to wrap padding on one shard.  Either way the
    round is ONE jax.jit dispatch."""
    opdef = plan.op_def
    if opdef.build_local_step is not None:
        return opdef.build_local_step(plan)
    local = opdef.build_shard_local(plan)

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        new_fields, new_stage = local(state.fields, state.wcon,
                                      state.tens, state.stage_tens)
        return WeatherState(fields=new_fields, wcon=state.wcon,
                            tens=state.tens, stage_tens=new_stage)
    return step


def _build_distributed_step(plan: ExecutionPlan):
    """Distributed lowering: the op's chip-local round (halo exchange per
    the plan's footprint-derived schedule + local kernel + interior crop),
    shard_mapped over the mesh.

    See `weather/domain.py` for the exchange primitives and the design
    rationale (NERO's scale-out story)."""
    local_step = plan.op_def.build_shard_local(plan)
    spec = plan.state_spec
    sharded = _shard_map(local_step, plan.mesh,
                         in_specs=(spec, spec, spec, spec),
                         out_specs=(spec, spec))

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        new_fields, new_stage = sharded(state.fields, state.wcon,
                                        state.tens, state.stage_tens)
        return WeatherState(fields=new_fields, wcon=state.wcon,
                            tens=state.tens, stage_tens=new_stage)

    return step
