"""Pure-jnp oracle for first-order upwind horizontal advection.

The COSMO dycore advects every prognostic field horizontally each large
step; the donor-cell (upwind) flux form with unit positive velocities is
the textbook building block:

    f' = f - cfl * ((f - f[y-1]) + (f - f[x-1]))

Layout: (z, y, x).  The stencil only reaches *backward* (the wind blows
from low y / low x), so the halo is asymmetric: one point on the low side
of each horizontal axis, zero on the high side — which is exactly why the
op earns its own `OperandRide` shape in the registry instead of reusing
hdiff's symmetric one.  The 1-wide low-side boundary ring passes through
unchanged (interior-only loops, like hdiff's ring).
"""

from __future__ import annotations

import jax.numpy as jnp

DEFAULT_CFL = 0.1   # dt * u / dx for the unit-velocity donor cell


def hadv_upwind(src: jnp.ndarray, cfl: float = DEFAULT_CFL) -> jnp.ndarray:
    """Upwind advection step.  src: (nz, ny, nx) with ny, nx >= 2.

    Returns same shape; row 0 and column 0 equal src (low-side ring)."""
    src = jnp.asarray(src)
    f = src.astype(jnp.float32) if src.dtype == jnp.bfloat16 else src

    c = f[:, 1:, 1:]
    ym = f[:, :-1, 1:]
    xm = f[:, 1:, :-1]
    interior = c - cfl * ((c - ym) + (c - xm))
    out = f.at[:, 1:, 1:].set(interior)
    return out.astype(src.dtype)
