"""repro.ckpt subpackage."""
