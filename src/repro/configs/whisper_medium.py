"""Whisper-medium — enc-dec audio backbone, conv frontend stubbed
[arXiv:2212.04356; unverified]."""

from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    pattern=("attn",), rope_theta=0.0,        # sinusoidal/absolute positions
    norm="ln", gated_mlp=False, act="gelu",
    encdec=EncDecConfig(encoder_layers=24, encoder_len=1500),
    skip_shapes=(("long_500k", "full-attention enc-dec"),),
)
