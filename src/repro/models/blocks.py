"""Decoder block assembly: one init/apply pair per block kind.

Kinds: "attn"/"global" (full causal attention + FFN), "local" (sliding
window + FFN), "rec" (RG-LRU + FFN), "ssd" (Mamba2 mixer, no FFN).
All applies share the signature
    apply(cfg, params, x, *, positions, mode, cache, pos) -> (x, cache', aux)
where mode ∈ {"train", "prefill", "decode"}; caches are pytrees (None when
kind needs none in that mode).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.common import (dense_init, norm_apply, norm_init,
                                 qk_norm_apply, rope_apply)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.rglru import rglru_block_apply, rglru_init, rglru_init_state
from repro.models.ssd import ssd_apply, ssd_init, ssd_init_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, nq, dtype),
         "wk": dense_init(ks[1], d, nkv, dtype),
         "wv": dense_init(ks[2], d, nkv, dtype),
         "wo": dense_init(ks[3], nq, d, dtype)}
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_scale"] = jnp.ones((hd,), jnp.float32)
    return p


def block_init(kind: str, key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_init(cfg, cfg.d_model)}
    if kind in ("attn", "global", "local"):
        p["attn"] = attn_init(ks[0], cfg, dtype)
    elif kind == "rec":
        p["rec"] = rglru_init(ks[0], cfg, dtype)
    elif kind == "ssd":
        p["ssd"] = ssd_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "ssd":
        p["norm2"] = norm_init(cfg, cfg.d_model)
        p["ffn"] = (moe_init(ks[1], cfg, dtype) if cfg.moe
                    else mlp_init(ks[1], cfg, dtype))
    if cfg.sandwich_norm:
        p["post1"] = norm_init(cfg, cfg.d_model)
        if kind != "ssd":
            p["post2"] = norm_init(cfg, cfg.d_model)
    return p


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype):
    if kind in ("attn", "global"):
        s = max_len
    elif kind == "local":
        s = min(cfg.window, max_len)
    elif kind == "rec":
        return rglru_init_state(cfg, batch, dtype)
    elif kind == "ssd":
        return ssd_init_state(cfg, batch, dtype)
    else:
        raise ValueError(kind)
    shape = (batch, s, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_dtype == "int8":
        sshape = (batch, s, cfg.n_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _kv_quant(x):
    """(B, T, K, hd) -> int8 values + per-(pos, head) absmax scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-10)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(
        jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _attention_mixer(kind, cfg: ModelConfig, params, h, *, positions, mode,
                     cache, pos, causal: bool = True):
    b, t, d = h.shape
    hd = cfg.hd
    q = (h @ params["wq"]).reshape(b, t, cfg.n_heads, hd)
    k = (h @ params["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
    v = (h @ params["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = qk_norm_apply(q, params["q_scale"])
        k = qk_norm_apply(k, params["k_scale"])
    theta = cfg.rope_theta
    if kind == "local" and cfg.rope_theta_local:
        theta = cfg.rope_theta_local
    if theta:                      # theta == 0 -> no rope (whisper backbone)
        q = rope_apply(q, positions, theta, cfg.mrope_sections)
        k = rope_apply(k, positions, theta, cfg.mrope_sections)
    window = cfg.window if kind == "local" else 0

    quant = cfg.kv_dtype == "int8"
    if mode == "decode":
        s = cache["k"].shape[1]
        slot = pos % s if kind == "local" else pos
        if quant:
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], kq, slot, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], vq, slot, axis=1),
                "k_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ks, slot, axis=1),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vs, slot, axis=1),
            }
            ck = _kv_dequant(new_cache["k"], new_cache["k_scale"], k.dtype)
            cv = _kv_dequant(new_cache["v"], new_cache["v_scale"], v.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=1)
            new_cache = {"k": ck, "v": cv}
        out = attn_lib.decode_attention(
            q, ck, cv, pos, window=(s if kind == "local" else 0))
    else:
        out = attn_lib.flash_attention(q, k, v, causal=causal, window=window)
        if mode == "prefill":
            s = cache["k"].shape[1]
            if kind == "local" and t > s:
                # keep the last `window` keys, ring-aligned so that global
                # position p sits at slot p % s.
                start = t - s
                rot = start % s
                kk = jnp.roll(k[:, start:], shift=rot, axis=1)
                vv = jnp.roll(v[:, start:], shift=rot, axis=1)
            else:
                pad = [(0, 0), (0, s - t), (0, 0), (0, 0)]
                kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
            if quant:
                kq, ks = _kv_quant(kk)
                vq, vs = _kv_quant(vv)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": kk, "v": vv}
        else:
            new_cache = cache
    return out.reshape(b, t, cfg.n_heads * hd) @ params["wo"], new_cache


def block_apply(kind: str, cfg: ModelConfig, params, x, *, positions, mode,
                cache=None, pos=None, causal: bool = True):
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg, params["norm1"], x)
    if kind in ("attn", "global", "local"):
        mix, new_cache = _attention_mixer(kind, cfg, params["attn"], h,
                                          positions=positions, mode=mode,
                                          cache=cache, pos=pos, causal=causal)
    elif kind == "rec":
        state = cache if mode == "decode" else None
        mix, new_state = rglru_block_apply(cfg, params["rec"], h, state)
        new_cache = new_state if mode != "train" else cache
    elif kind == "ssd":
        state = cache if mode == "decode" else None
        mix, new_state = ssd_apply(cfg, params["ssd"], h, state)
        new_cache = new_state if mode != "train" else cache
    else:
        raise ValueError(kind)
    if cfg.sandwich_norm:
        mix = norm_apply(cfg, params["post1"], mix)
    x = x + mix

    if kind != "ssd":
        h = norm_apply(cfg, params["norm2"], x)
        if cfg.moe:
            ff, aux = moe_apply(cfg, params["ffn"], h)
        else:
            ff = mlp_apply(cfg, params["ffn"], h)
        if cfg.sandwich_norm:
            ff = norm_apply(cfg, params["post2"], ff)
        x = x + ff
    return x, new_cache, aux
