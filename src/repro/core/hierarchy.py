"""TPU memory-hierarchy model — the FPGA URAM/BRAM/HBM analogue.

NERO (the paper) builds an application-specific scratchpad hierarchy out of the
FPGA's heterogeneous memories (HBM -> URAM -> BRAM -> FF).  On TPU the same
levels exist but are fixed silicon: HBM -> VMEM (software-managed scratchpad)
-> VREG.  This module is the single source of truth for capacities,
bandwidths, and energy-per-byte used by the tile planner, the perf model, the
autotuner, and the roofline analysis.

All numbers are per-chip TPU v5e (the assignment's hardware constants), with
energy coefficients from public literature (Horowitz ISSCC'14 scaled to 7nm,
JEDEC HBM2 specs); they are *model* constants, labeled as such in benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Per-chip hardware constants (TPU v5e — assignment-provided where given).
# ---------------------------------------------------------------------------

PEAK_BF16_FLOPS = 197e12        # FLOP/s per chip (assignment constant)
PEAK_FP32_FLOPS = PEAK_BF16_FLOPS / 4.0   # MXU fp32 passthrough estimate
HBM_BYTES = 16 * 2**30          # 16 GiB HBM per chip
HBM_BW = 819e9                  # B/s per chip (assignment constant)
ICI_BW_PER_LINK = 50e9          # B/s per ICI link (assignment constant)
ICI_LINKS = 4                   # v5e 2D torus: 4 links/chip
VMEM_BYTES = 128 * 2**20        # 128 MiB VMEM per core
VMEM_USABLE = 64 * 2**20        # budget we let the planner claim (pipeline
                                # double-buffering + compiler headroom)
VMEM_BW = 8 * HBM_BW            # VMEM is ~an order of magnitude faster; model 8x
VREG_BYTES = 512 * 1024         # vector registers (order of magnitude)
MXU_TILE = (128, 128)           # systolic array native tile
VPU_LANES = (8, 128)            # sublane x lane layout granularity

# Energy model (pJ/byte moved, pJ/flop) — used by benchmarks/energy.py.
# HBM2 ~3.9 pJ/bit ≈ 31 pJ/B; on-chip SRAM ~0.1-0.2 pJ/bit; ICI ~10 pJ/B.
ENERGY_PJ_PER_BYTE: Dict[str, float] = {
    "hbm": 31.0,
    "vmem": 1.5,
    "vreg": 0.08,
    "ici": 10.0,
    "host": 62.0,   # PCIe/host DMA, the OCAPI analogue
}
ENERGY_PJ_PER_FLOP_BF16 = 0.15
CHIP_IDLE_WATTS = 60.0
CHIP_PEAK_WATTS = 170.0


def dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    """One level of the near-memory hierarchy."""

    name: str
    capacity_bytes: int
    bandwidth_bytes_per_s: float
    energy_pj_per_byte: float

    def seconds_for(self, nbytes: int) -> float:
        return nbytes / self.bandwidth_bytes_per_s

    def energy_joules_for(self, nbytes: int) -> float:
        return nbytes * self.energy_pj_per_byte * 1e-12


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """The full per-chip hierarchy, NERO-style: far memory feeds near memory
    feeds registers; the planner places tiles at the deepest level that fits."""

    hbm: MemoryLevel
    vmem: MemoryLevel
    vreg: MemoryLevel
    peak_flops_bf16: float = PEAK_BF16_FLOPS
    peak_flops_fp32: float = PEAK_FP32_FLOPS
    ici_bw: float = ICI_BW_PER_LINK

    def level_for(self, nbytes: int) -> MemoryLevel:
        """Deepest (fastest) level whose capacity holds `nbytes` (the paper's
        greedy placement: URAM/BRAM if it fits, else HBM)."""
        if nbytes <= self.vreg.capacity_bytes:
            return self.vreg
        if nbytes <= self.vmem.capacity_bytes:
            return self.vmem
        return self.hbm

    def machine_balance(self, dtype=jnp.bfloat16) -> float:
        """FLOP:byte ratio at which compute and HBM time are equal — the
        roofline ridge point (paper Fig. 1)."""
        peak = (self.peak_flops_bf16
                if jnp.dtype(dtype).itemsize <= 2 else self.peak_flops_fp32)
        return peak / self.hbm.bandwidth_bytes_per_s


def tpu_v5e() -> Hierarchy:
    return Hierarchy(
        hbm=MemoryLevel("hbm", HBM_BYTES, HBM_BW, ENERGY_PJ_PER_BYTE["hbm"]),
        vmem=MemoryLevel("vmem", VMEM_USABLE, VMEM_BW, ENERGY_PJ_PER_BYTE["vmem"]),
        vreg=MemoryLevel("vreg", VREG_BYTES, 16 * VMEM_BW, ENERGY_PJ_PER_BYTE["vreg"]),
    )


# The paper's POWER9 baseline, for the reproduction of Fig. 1 in
# benchmarks/roofline_kernels.py (peak numbers from the paper's roofline plot).
POWER9_PEAK_FLOPS = 1.0e12       # ~1 TFLOP/s fp32, 16 cores
POWER9_DRAM_BW = 110e9           # ~110 GB/s host DRAM (measured in paper's Fig 1)
