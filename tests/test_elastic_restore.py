"""Elastic scaling: a checkpoint written on one mesh restores onto another
(different device count and sharding), bit-exactly — the remesh path a
launcher uses after node failure or pool resize."""

import os
import subprocess
import sys

_SNIPPET = r"""
import jax, numpy as np, tempfile
import jax.numpy as jnp
from repro.ckpt import checkpoint as ckpt_lib
from repro.configs import registry
from repro.models import api
from repro.parallel import sharding as shd
from repro.train import optim
from repro.launch.mesh import make_mesh

cfg = registry.reduced_config(registry.get_config("olmo-1b"), layers=2)
model = api.build(cfg)
params = model.init(jax.random.PRNGKey(0))
opt_state = optim.init_opt_state(params)

d = tempfile.mkdtemp()
# write on a 1x1 mesh (single host survivor)
ckpt_lib.save(d, 7, params, opt_state)

# restore onto a 2x2 mesh (scaled-up pool), production sharding rules
mesh = make_mesh((2, 2), ("data", "model"))
p_shard = shd.params_sharding(model.param_shapes(), mesh, "train")
o_shard = {"m": p_shard, "v": p_shard, "master": p_shard,
           "step": jax.sharding.NamedSharding(
               mesh, jax.sharding.PartitionSpec())}
p2, o2, step = ckpt_lib.restore(d, 7, mesh, p_shard, o_shard)
assert step == 7
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
# restored leaves actually carry the 2x2 sharding
leaf = p2["superblocks"]["b0"]["attn"]["wq"]
assert len(leaf.sharding.device_set) == 4
print("ELASTIC_OK")
"""


def test_restore_onto_larger_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
