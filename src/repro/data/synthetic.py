"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) so checkpoint-resume replays the
exact stream from any step — the data-iterator state *is* the step counter
(stored in the optimizer state), which makes restarts bit-reproducible.
Sharded host->device placement via the batch sharding rules; a one-deep
prefetch overlaps host generation with device compute.
"""

from __future__ import annotations

import threading
import queue
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def lm_batch(cfg: ModelConfig, seed: int, step: int, batch: int, seq: int,
             kind: str = "arith") -> Dict[str, np.ndarray]:
    """kind="arith": learnable modular arithmetic sequences (per-sequence
    random start/stride) so train-loss visibly decreases; "uniform": i.i.d.
    tokens (bandwidth/throughput benchmarks, nothing learnable)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if kind == "uniform":
        tokens = rng.integers(0, cfg.vocab_size, size=(batch, seq),
                              dtype=np.int32)
    else:
        start = rng.integers(0, cfg.vocab_size, size=(batch, 1))
        stride = rng.integers(1, 9, size=(batch, 1))
        idx = np.arange(seq)[None, :]
        tokens = ((start + stride * idx) % cfg.vocab_size).astype(np.int32)
    out = {"tokens": tokens}
    if cfg.encdec:
        out["frames"] = rng.normal(
            0, 1, size=(batch, cfg.encdec.encoder_len, cfg.d_model)
        ).astype(np.float32)
    return out


def iterator(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
             start_step: int = 0, shardings=None,
             prefetch: int = 1, kind: str = "arith"
             ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite deterministic iterator with background prefetch."""
    def gen(step):
        b = lm_batch(cfg, seed, step, batch, seq, kind=kind)
        if shardings is not None:
            b = jax.tree.map(jax.device_put, b, shardings)
        return b

    if prefetch <= 0:
        step = start_step
        while True:
            yield gen(step)
            step += 1
        return

    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(gen(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
