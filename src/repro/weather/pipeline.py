"""Pipelined stencil programs: a stage chain compiled to ONE fused plan.

NERO's near-memory argument is about *chains*, not single kernels: the
paper's dycore wins because vadvc's tendencies never round-trip main
memory before the point-wise update and hdiff consume them (§3 — the
baseline's intermediates bounce through DRAM between kernels).  The
registry (`weather/stencil_ops.py`) gave every operator a solo program;
this module gives chains the same one-plan treatment WITHOUT writing a
fused mega-kernel per combination:

* `PipelineProgram` is a `StencilProgram` whose op is an ordered list of
  registered stages (`PipelineStage`: op name + optional field binding).
  Constructing one synthesizes and registers a chain `StencilOpDef` — so
  `program.compile` plans it like any other op, with NO pipeline branches
  in the planner.
* **One fused exchange.**  A backward validity analysis walks the stages
  in reverse, accumulating how far beyond the interior each operand must
  be valid BEFORE the chain runs (stage reach = the stage's own declared
  k=1 ride; written operands reset the requirement).  The merged
  per-operand `(lo, hi)` depths become the chain op's `OperandRide`s:
  the whole round is ONE packed ppermute pair per mesh direction —
  max-over-stages depth per operand side, ragged per operand — instead of
  one exchange per stage.  The analysis runs at k=1 and k=2 and the
  depths are encoded as `k*base + fixed` (verified linear at k=3), so the
  chain inherits the communication-avoiding k-step round for free.
* **Ordered resident launches.**  The lowering exchanges once, edge-pads
  every operand to the common slab target, then launches the stages IN
  ORDER via their `apply_stage` hooks on the shared padded slabs: an
  operand written by stage i is stage i+1's input WITHOUT an intermediate
  HBM round trip or re-exchange (validity shrinks stage by stage, exactly
  as the analysis accounted).  One interior crop ends the round.
* **Traffic model.**  `core/memmodel.pipeline_step_traffic` prices the
  chained single-pass against the sum of solo stages; the chain's tile
  space is `core/tiling.pipeline_spec` (flops sum, streams union,
  sequential axes union), registered in `core/autotune` under the chain
  name.

Stage semantics: stages share the program's `coeff`/`dt` scalars and may
write only `fields` / `stage_tens` (the round contract — `wcon` and the
slow tendencies are read-only).  A stage binding (`fields=("u",)`)
restricts the stage to a subset of the program's fields; unbound fields
pass through bitwise.  Zero-ride chains (e.g. a lone `asselin`) compile
to ZERO collectives — the packed exchange elides every direction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import autotune, memmodel, tiling
from repro.weather import domain as _domain
from repro.weather import stencil_ops as _sops
from repro.weather.program import StencilProgram
from repro.weather.stencil_ops import (OperandRide, StencilOpDef,
                                       get_stencil_op, register_stencil_op)

__all__ = ["PipelineStage", "PipelineProgram", "pipeline_op_name"]

# Operand slots a stage may write (the round returns (fields, stage_tens);
# wcon and the slow tendencies pass through every registered lowering).
_WRITABLE = ("fields", "stage_tens")
_PER_FIELD = ("fields", "tens", "stage_tens")
_ZERO = ((0, 0), (0, 0))


@dataclasses.dataclass(frozen=True)
class PipelineStage:
    """One chain link: a registered op plus an optional field binding.

    `fields=None` binds the stage to every program field; a tuple
    restricts it (unbound fields pass through that stage bitwise)."""

    op: str
    fields: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.fields is not None:
            object.__setattr__(self, "fields", tuple(self.fields))

    def describe(self) -> Dict[str, Any]:
        return {"op": self.op,
                "fields": None if self.fields is None else list(self.fields)}


def pipeline_op_name(stages) -> str:
    """Canonical synthesized op name: the chain signature.  Bindings are
    part of the name because the merged rides depend on them — two
    pipelines with the same signature share one registry entry."""
    sig = []
    for st in stages:
        s = st.op
        if st.fields is not None:
            s += "[" + ",".join(st.fields) + "]"
        sig.append(s)
    return "pipeline(" + "->".join(sig) + ")"


# ---------------------------------------------------------------------------
# Backward validity analysis -> merged OperandRides
# ---------------------------------------------------------------------------


def _req_add(a, b):
    return ((a[0][0] + b[0][0], a[0][1] + b[0][1]),
            (a[1][0] + b[1][0], a[1][1] + b[1][1]))


def _req_max(a, b):
    return ((max(a[0][0], b[0][0]), max(a[0][1], b[0][1])),
            (max(a[1][0], b[1][0]), max(a[1][1], b[1][1])))


def _chain_requirements(stages, field_names, k: int):
    """Walk `k` chain repetitions BACKWARD, accumulating per-(operand,
    field) validity requirements: how far beyond the interior each slot
    must be valid before the round runs so the final interior crop is
    exact.  A stage's reads need (max requirement over its written slots)
    + the stage's own declared per-operand reach; writing a slot RESETS
    its requirement to what the stage itself reads it at."""
    req: Dict[Tuple[str, Optional[str]], Any] = {}

    def get(key):
        return req.get(key, _ZERO)

    for _ in range(k):
        for st in reversed(stages):
            od = get_stencil_op(st.op)
            bound = st.fields if st.fields is not None else field_names
            reach = {r.operand: r.depths(1) for r in od.rides}
            needed = _ZERO
            for w in od.writes:
                for f in bound:
                    needed = _req_max(needed, get((w, f)))
            new_read: Dict[Tuple[str, Optional[str]], Any] = {}
            for o in od.reads:
                cand = _req_add(needed, reach.get(o, _ZERO))
                if o in _PER_FIELD:
                    for f in bound:
                        new_read[(o, f)] = cand
                else:
                    new_read[(o, None)] = cand
            written = {(w, f) for w in od.writes for f in bound}
            for key, cand in new_read.items():
                if key not in written:
                    req[key] = _req_max(get(key), cand)
            for key in written:
                req[key] = new_read.get(key, _ZERO)
    merged: Dict[str, Any] = {}
    for (o, _f), r in req.items():
        merged[o] = _req_max(merged.get(o, _ZERO), r)
    return merged


def _chain_rides(stages, field_names):
    """Merged per-operand rides in `k*base + fixed` form, plus whether the
    footprint is LINEAR in k (the k-step precondition: the analysis at
    k=3 must match the extrapolation from k=1 and k=2)."""
    r1 = _chain_requirements(stages, field_names, 1)
    r2 = _chain_requirements(stages, field_names, 2)
    r3 = _chain_requirements(stages, field_names, 3)
    operands = sorted(set(r1) | set(r2) | set(r3))
    rides, linear, deepens = [], True, False
    for o in operands:
        a = r1.get(o, _ZERO)
        b = r2.get(o, _ZERO)
        c = r3.get(o, _ZERO)
        base = ((b[0][0] - a[0][0], b[0][1] - a[0][1]),
                (b[1][0] - a[1][0], b[1][1] - a[1][1]))
        if (min(base[0] + base[1]) < 0
                or _req_add(b, base) != c):
            linear = False
        if any(d > 0 for d in base[0] + base[1]):
            deepens = True
        fixed = ((a[0][0] - base[0][0], a[0][1] - base[0][1]),
                 (a[1][0] - base[1][0], a[1][1] - base[1][1]))
        if not any(d > 0 for d in a[0] + a[1] + base[0] + base[1]):
            continue              # never rides: zero at every k
        rides.append(OperandRide(o, y=base[0], x=base[1],
                                 y_fixed=fixed[0], x_fixed=fixed[1],
                                 per_field=o in _PER_FIELD))
    return tuple(rides), linear, deepens


# ---------------------------------------------------------------------------
# Synthesized chain op: tile space, lowering, traffic
# ---------------------------------------------------------------------------


def _stage_tile_spec(st: PipelineStage) -> tiling.OpSpec:
    """The autotune OpSpec a stage models as: its op's whole-state tile
    space when it registers one, else the op's own registered spec."""
    od = get_stencil_op(st.op)
    name = dict(od.tile_spaces).get("whole_state", st.op)
    return autotune.get_op(name)


def _make_chain_spec(name, stages, field_names) -> tiling.OpSpec:
    reads = set()
    writes = set()
    for st in stages:
        od = get_stencil_op(st.op)
        reads.update(od.reads)
        writes.update(od.writes)
    nf = max(1, len(field_names))
    fields_in = (sum(1 for o in _PER_FIELD if o in reads)
                 + (1.0 / nf if "wcon" in reads else 0.0))
    fields_out = sum(1 for o in _PER_FIELD if o in writes)
    halo = sum(get_stencil_op(st.op).halo for st in stages)
    return tiling.pipeline_spec(
        name, [_stage_tile_spec(st) for st in stages],
        fields_in=fields_in, fields_out=fields_out, halo=(0, halo, halo))


def _pipeline_resolve_tile(spec: tiling.OpSpec):
    def resolve(variant, compute_grid, dtype, n_fields, ensemble, k):
        if variant == "unfused":
            return None
        grid = tuple(int(g) for g in compute_grid)
        tuned = autotune.tune(spec, grid, dtype)
        tz, ty, tx = tuned.plan.tile
        ty = tiling.snap_to_divisor(ty, grid[1], lo=1)
        return tiling.TilePlan(op=spec, grid_shape=grid, tile=(tz, ty, tx),
                               dtype=str(jnp.dtype(dtype)))
    return resolve


def _pipeline_traffic(spec: tiling.OpSpec, stages):
    def traffic(plan, model_ty):
        prog = plan.program
        nz, ny, nx = prog.grid_shape
        tile = (nz if 0 in spec.seq_axes else 1,
                tiling.snap_to_divisor(model_ty, ny, lo=1), nx)
        pairs = [(_stage_tile_spec(st),
                  len(st.fields) if st.fields is not None
                  else prog.n_fields) for st in stages]
        return memmodel.pipeline_step_traffic(
            spec, pairs, prog.grid_shape, prog.dtype, tile=tile,
            k_steps=plan.k_steps)
    return traffic


def _pipeline_pallas_calls(stages):
    def calls(variant, nf, k):
        if variant == "unfused":
            return 0
        per_chain = sum(
            get_stencil_op(st.op).pallas_calls(
                "whole_state",
                len(st.fields) if st.fields is not None else nf, 1)
            for st in stages)
        return k * per_chain
    return calls


def _pipeline_shard_local(stages):
    """The chain round the distributed step shard_maps (and, via
    `pads_single_chip`, the single-chip step): ONE packed exchange per
    direction at the merged ragged depths, edge-pad to the common slab
    target, then the stages IN ORDER on the resident slabs, one crop."""

    def build(plan):
        prog = plan.program
        names = prog.fields
        variant, interp, k = plan.variant, plan.interpret, plan.k_steps
        use_ref = variant == "unfused"
        _, ax_y, ax_x = plan.mesh_axes
        py, px = plan.shards
        wire = prog.exchange_dtype
        rides = {name: (dy, dx) for name, dy, dx in plan.rides}

        def depth(o):
            return rides.get(o, _ZERO)

        reads = set()
        for st in stages:
            reads.update(get_stencil_op(st.op).reads)
        writes = set()
        for st in stages:
            writes.update(get_stencil_op(st.op).writes)
        # Per-field operands every stage sees on the slab; canonical order.
        slab_ops = tuple(o for o in _PER_FIELD if o in reads)
        wcon_read = "wcon" in reads
        # Common slab target: per-side max over the per-field operands —
        # every operand a stage stacks together must share one geometry.
        ty_lo = max([depth(o)[0][0] for o in slab_ops] or [0])
        ty_hi = max([depth(o)[0][1] for o in slab_ops] or [0])
        tx_lo = max([depth(o)[1][0] for o in slab_ops] or [0])
        tx_hi = max([depth(o)[1][1] for o in slab_ops] or [0])
        stage_fns = [
            (get_stencil_op(st.op).apply_stage(
                prog, st.fields if st.fields is not None else names,
                interp, use_ref), st)
            for st in stages]

        def pad_to(a, have, want_lo, want_hi, dim):
            d_lo, d_hi = want_lo - have[0], want_hi - have[1]
            if d_lo == 0 and d_hi == 0:
                return a
            pw = [(0, 0)] * a.ndim
            pw[dim] = (d_lo, d_hi)
            # Edge values, not zeros: finite garbage the validity analysis
            # already bounds away from the interior (a NaN would poison the
            # stencil windows that straddle the pad ring).
            return jnp.pad(a, pw, mode="edge")

        def local(fields, wcon, tens, stage_tens):
            e, nz, ly, lx = wcon.shape
            src = {"fields": fields, "tens": tens,
                   "stage_tens": stage_tens}
            stacked = {o: jnp.stack([src[o][n] for n in names], axis=1)
                       for o in slab_ops}
            # ONE packed ppermute pair per direction for the WHOLE chain:
            # every operand rides at its own merged depth (ragged; zero
            # sides ship nothing, all-zero directions are elided).
            parts = [(stacked[o], depth(o)[0]) for o in slab_ops]
            if wcon_read:
                parts.append((wcon, depth("wcon")[0]))
            parts = _domain._exchange_packed(parts, ax_y, py, dim=-2,
                                             wire_dtype=wire)
            parts = _domain._exchange_packed(
                [(p, depth(o)[1]) for p, o in
                 zip(parts, slab_ops + (("wcon",) if wcon_read else ()))],
                ax_x, px, dim=-1, wire_dtype=wire)
            slabs = dict(zip(slab_ops, parts))
            # Edge-pad every operand to the common target so the stages
            # share one slab geometry; wcon keeps its one-wider-on-high-x
            # staggering contract.
            for o in slab_ops:
                dy, dx = depth(o)
                a = pad_to(slabs[o], dy, ty_lo, ty_hi, dim=-2)
                slabs[o] = pad_to(a, dx, tx_lo, tx_hi, dim=-1)
            if wcon_read:
                dy, dx = depth("wcon")
                wconp = pad_to(parts[-1], dy, ty_lo, ty_hi, dim=-2)
                wconp = pad_to(wconp, dx, tx_lo, tx_hi + 1, dim=-1)
            else:
                wconp = wcon
            un = {o: {n: slabs[o][:, i] for i, n in enumerate(names)}
                  for o in slab_ops}
            fdict = un.get("fields", dict(fields))
            tdict = un.get("tens", dict(tens))
            sdict = un.get("stage_tens", dict(stage_tens))
            # The chain: stages in order on the RESIDENT slabs — stage i's
            # writes are stage i+1's inputs with no exchange and no HBM
            # round trip in between; k chain repetitions on one deep
            # exchange (validity shrinks exactly as the rides account).
            for _ in range(k):
                for fn, _st in stage_fns:
                    fdict, sdict = fn(fdict, wconp, tdict, sdict)
            crop = lambda a: a[..., ty_lo:ty_lo + ly, tx_lo:tx_lo + lx]
            new_fields = ({n: crop(fdict[n]) for n in names}
                          if "fields" in writes else dict(fields))
            new_stage = ({n: crop(sdict[n]) for n in names}
                         if "stage_tens" in writes else dict(stage_tens))
            return new_fields, new_stage
        return local
    return build


def _ensure_registered(name: str, stages: Tuple[PipelineStage, ...],
                       field_names: Tuple[str, ...]) -> StencilOpDef:
    """Synthesize + register the chain's StencilOpDef and tile space
    (idempotent: the name encodes the signature AND bindings, so a second
    program with the same chain reuses the entry)."""
    if name in _sops.STENCIL_OPS:
        return get_stencil_op(name)
    rides, linear, deepens = _chain_rides(stages, field_names)
    halo = sum(get_stencil_op(st.op).halo for st in stages)
    variants = ("unfused", "whole_state")
    if linear and deepens and halo > 0:
        variants = variants + ("kstep",)
    spec = _make_chain_spec(name, stages, field_names)
    autotune.register_op(spec)
    flops = sum(
        get_stencil_op(st.op).flops_per_point for st in stages)
    reads, writes = [], []
    for o in ("fields", "wcon", "tens", "stage_tens"):
        if any(o in get_stencil_op(st.op).reads for st in stages):
            reads.append(o)
        if any(o in get_stencil_op(st.op).writes for st in stages):
            writes.append(o)
    op = StencilOpDef(
        name=name,
        title="fused stage chain: " + " -> ".join(st.op for st in stages),
        reads=tuple(reads),
        writes=tuple(writes),
        halo=halo,
        flops_per_point=flops,
        rides=rides,
        variants=variants,
        tile_spaces=tuple((v, name) for v in variants if v != "unfused"),
        inkernel_kstep=False,
        pads_single_chip=True,
        packed_variants=variants,
        resolve_tile=_pipeline_resolve_tile(spec),
        build_shard_local=_pipeline_shard_local(stages),
        pallas_calls=_pipeline_pallas_calls(stages),
        traffic=_pipeline_traffic(spec, stages),
    )
    op = dataclasses.replace(
        op, exchange_model=_sops._generic_exchange_model(op))
    return register_stencil_op(op)


# ---------------------------------------------------------------------------
# The program
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineProgram(StencilProgram):
    """A `StencilProgram` whose op is an ordered stage chain.

    Construction synthesizes and registers the chain's `StencilOpDef`
    (merged rides, fused-exchange lowering, chained traffic model) under
    the canonical signature name, then validates like any program —
    `program.compile` needs no pipeline awareness.  `op` is derived; do
    not set it."""

    stages: Tuple[PipelineStage, ...] = ()

    def __post_init__(self):
        stages = []
        for st in self.stages:
            if isinstance(st, PipelineStage):
                stages.append(st)
            elif isinstance(st, str):
                stages.append(PipelineStage(op=st))
            elif isinstance(st, dict):
                f = st.get("fields")
                stages.append(PipelineStage(
                    op=st["op"], fields=None if f is None else tuple(f)))
            else:
                raise TypeError(f"stage {st!r}: expected a PipelineStage, "
                                f"op name, or {{'op': ...}} dict")
        stages = tuple(stages)
        object.__setattr__(self, "stages", stages)
        if not stages:
            raise ValueError("a PipelineProgram needs at least one stage")
        names = tuple(self.fields)
        for st in stages:
            od = get_stencil_op(st.op)      # raises on unknown ops
            if od.apply_stage is None:
                raise ValueError(
                    f"op {st.op!r} cannot ride in a pipeline (no "
                    f"apply_stage lowering)")
            bad = set(od.writes) - set(_WRITABLE)
            if bad:
                raise ValueError(
                    f"stage {st.op!r} writes {sorted(bad)}: a pipeline "
                    f"round may only write {list(_WRITABLE)}")
            if st.fields is not None:
                missing = [f for f in st.fields if f not in names]
                if missing:
                    raise ValueError(
                        f"stage {st.op!r} binds unknown fields {missing} "
                        f"(program fields: {list(names)})")
                if not st.fields:
                    raise ValueError(f"stage {st.op!r}: an explicit "
                                    f"binding needs at least one field")
        name = pipeline_op_name(stages)
        if self.op not in ("dycore", name):
            raise ValueError(f"op={self.op!r}: a PipelineProgram derives "
                             f"its op from the stages ({name!r}); leave "
                             f"it unset")
        object.__setattr__(self, "op", name)
        opdef = _ensure_registered(name, stages, names)
        if self.halo is not None and self.halo != opdef.halo:
            raise ValueError(f"halo={self.halo}: chain {name!r} reaches "
                             f"{opdef.halo} per step")
        super().__post_init__()

    def to_json(self) -> Dict[str, Any]:
        d = super().to_json()
        d["stages"] = [st.describe() for st in self.stages]
        return d
