"""Pipeline programs: fused exchange, ordered launches, bit-identity.

The contract of `repro.weather.pipeline` (ISSUE 10): a chain of
registered stages compiles to ONE execution plan whose single packed
exchange pair per direction carries every stage's operand footprint at
the chain's back-propagated depths, whose stage launches run in order on
resident operands (no HBM round trip between stages), and whose output
is BIT-IDENTICAL to running the same stages as sequential solo programs
— on one chip and on a forced-4-device mesh alike.  The property sweep
uses `hypothesis` when the dev extra is installed and a seeded
deterministic sweep of the same property otherwise.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import memmodel
from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.weather import fields
from repro.weather import program as wprog
from repro.weather.pipeline import (PipelineProgram, PipelineStage,
                                    pipeline_op_name)
from repro.weather.program import StencilProgram, compile, plan_cache_key

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

_GRID = (3, 8, 8)
_FLAGSHIP = ("hadv_upwind", "vadvc_update", "hdiff")
# Chainable zoo: every op with an apply_stage lowering.
_CHAINABLE = ("hadv_upwind", "vadvc_update", "hdiff", "vadvc", "asselin")


def _state(grid=_GRID, ensemble=1, seed=0):
    return fields.initial_state(jax.random.PRNGKey(seed), grid,
                                ensemble=ensemble)


def _pipe(stages, grid=_GRID, ensemble=1, **kw):
    kw.setdefault("variant", "whole_state")
    kw.setdefault("k_steps", 1)
    return PipelineProgram(grid_shape=grid, ensemble=ensemble, coeff=0.05,
                           stages=tuple(stages), **kw)


def _solo_chain(stages, state, grid=_GRID, ensemble=1):
    """Reference: the same stages as sequential solo programs."""
    for op in stages:
        p = compile(StencilProgram(grid_shape=grid, ensemble=ensemble,
                                   coeff=0.05, op=op, variant="whole_state",
                                   k_steps=1))
        state = p.step(state)
    return state


def _assert_state_equal(a, b, names=fields.PROGNOSTIC):
    for n in names:
        np.testing.assert_array_equal(np.asarray(a.fields[n]),
                                      np.asarray(b.fields[n]), err_msg=n)
        np.testing.assert_array_equal(np.asarray(a.stage_tens[n]),
                                      np.asarray(b.stage_tens[n]), err_msg=n)


# ---------------------------------------------------------------------------
# Single-chip bit-identity
# ---------------------------------------------------------------------------

def test_flagship_chain_matches_sequential_solos():
    """hadv_upwind -> vadvc_update -> hdiff as ONE plan is bitwise equal
    to the three solo programs run back to back, and launches exactly one
    pallas call per stage per round."""
    st_ = _state(ensemble=2)
    plan = compile(_pipe(_FLAGSHIP, ensemble=2))
    rep = plan.report()
    assert rep["pallas_calls_per_round"] == len(_FLAGSHIP)
    assert rep["collectives_per_round"] == 0        # single chip
    _assert_state_equal(plan.step(st_),
                        _solo_chain(_FLAGSHIP, st_, ensemble=2))


def test_pinned_kstep_round_matches_two_chain_rounds():
    """A k=2 pipeline round reuses ONE (deeper) fused exchange and is
    bitwise equal to two k=1 rounds."""
    st_ = _state()
    p1 = compile(_pipe(_FLAGSHIP))
    p2 = compile(_pipe(_FLAGSHIP, variant="kstep", k_steps=2))
    assert p2.report()["pallas_calls_per_round"] == 2 * len(_FLAGSHIP)
    _assert_state_equal(p2.step(st_), p1.step(p1.step(st_)))


def test_run_ragged_tail_matches_sequential_rounds():
    """run(state, 3) on a k=2 chain (one full round + ragged tail) equals
    three sequential chain rounds."""
    st_ = _state()
    p1 = compile(_pipe(_FLAGSHIP))
    p2 = compile(_pipe(_FLAGSHIP, variant="kstep", k_steps=2))
    ref = st_
    for _ in range(3):
        ref = p1.step(ref)
    _assert_state_equal(p2.run(st_, 3), ref)


def test_subset_binding_applies_stage_to_bound_fields_only():
    """pipeline(hadv_upwind -> hdiff[u,v]) diffuses only u and v; t and
    pp pass through the hdiff stage untouched (bitwise)."""
    st_ = _state()
    plan = compile(PipelineProgram(
        grid_shape=_GRID, ensemble=1, coeff=0.05,
        variant="whole_state", k_steps=1,
        stages=(PipelineStage(op="hadv_upwind"),
                PipelineStage(op="hdiff", fields=("u", "v")))))
    out = plan.step(st_)
    adv = _solo_chain(("hadv_upwind",), st_)
    full = _solo_chain(("hadv_upwind", "hdiff"), st_)
    for n in ("u", "v"):
        np.testing.assert_array_equal(np.asarray(out.fields[n]),
                                      np.asarray(full.fields[n]), err_msg=n)
    for n in ("t", "pp"):
        np.testing.assert_array_equal(np.asarray(out.fields[n]),
                                      np.asarray(adv.fields[n]), err_msg=n)


def test_asselin_chain_elides_every_exchange():
    """A zero-ride chain declares no rides and costs zero collectives on
    any mesh shape (checked here via the generic model), while staying
    bitwise equal to the solo filter."""
    st_ = _state()
    prog = _pipe(("asselin",))
    opdef = __import__("repro.weather.stencil_ops",
                       fromlist=["get_stencil_op"]).get_stencil_op(prog.op)
    assert opdef.resolved_rides(1) == ()
    assert opdef.halo == 0
    assert opdef.generic_collectives(2, 2, 1) == 0
    plan = compile(prog)
    assert plan.report()["collectives_per_round"] == 0
    _assert_state_equal(plan.step(st_), _solo_chain(("asselin",), st_))


def test_chain_rides_match_backpropagated_depths():
    """The flagship chain's registered rides are the hand-derived
    backward-validity depths: fields (3,2)/(3,2), wcon (2,2)y (2,3)x,
    tens and stage_tens (2,2)/(2,2) — and they deepen linearly in k."""
    prog = _pipe(_FLAGSHIP)
    fp = compile(prog).report()["footprint"]
    got = {r["operand"]: (tuple(r["depth_y"]), tuple(r["depth_x"]))
           for r in fp["rides"]}
    assert got == {"fields": ((3, 2), (3, 2)),
                   "stage_tens": ((2, 2), (2, 2)),
                   "tens": ((2, 2), (2, 2)),
                   "wcon": ((2, 2), (2, 3))}
    assert fp["halo"] == 3 and "kstep" in fp["variants"]


# ---------------------------------------------------------------------------
# Property: any chainable subset/ordering == sequential solos, bitwise
# ---------------------------------------------------------------------------

def _check_chain_property(stages, seed):
    st_ = _state(seed=seed)
    plan = compile(_pipe(stages))
    _assert_state_equal(plan.step(st_), _solo_chain(stages, st_))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.sampled_from(_CHAINABLE), min_size=1, max_size=3,
                    unique=True),
           st.integers(min_value=0, max_value=2 ** 16))
    def test_random_chains_match_sequential(stages, seed):
        _check_chain_property(tuple(stages), seed)
else:
    def test_random_chains_match_sequential():
        rng = np.random.default_rng(1234)
        for i in range(6):
            size = int(rng.integers(1, 4))
            stages = tuple(rng.choice(_CHAINABLE, size=size, replace=False))
            _check_chain_property(stages, seed=int(rng.integers(2 ** 16)))


# ---------------------------------------------------------------------------
# Serialization + serving
# ---------------------------------------------------------------------------

def test_json_roundtrip_and_cache_key():
    """to_json/from_json round-trips through the BASE class dispatch (a
    serving checkpoint only knows `StencilProgram.from_json`), report()'s
    embedded program block does too, and the plan-cache key is distinct
    from every constituent solo program's."""
    prog = _pipe(_FLAGSHIP, ensemble=2)
    back = StencilProgram.from_json(prog.to_json())
    assert isinstance(back, PipelineProgram)
    assert back == prog
    rep_prog = StencilProgram.from_json(compile(prog).report()["program"])
    assert rep_prog == prog
    keys = {plan_cache_key(prog, ensemble=2)}
    for op in _FLAGSHIP:
        keys.add(plan_cache_key(
            StencilProgram(grid_shape=_GRID, ensemble=2, coeff=0.05, op=op),
            ensemble=2))
    assert len(keys) == 1 + len(_FLAGSHIP)
    assert hash(prog) is not None


def test_engine_caches_pipeline_plans(monkeypatch):
    """Six requests over {solo hdiff, pipeline-with-hdiff} compile exactly
    TWO plans: the chain's cache key never collides with the solo op's."""
    calls = []
    real_compile = wprog.compile

    def spy(program, *a, **kw):
        calls.append(program)
        return real_compile(program, *a, **kw)

    monkeypatch.setattr(wprog, "compile", spy)
    progs = [StencilProgram(grid_shape=_GRID, ensemble=1, coeff=0.05,
                            op="hdiff"),
             _pipe(("hadv_upwind", "hdiff"))]
    eng = ForecastEngine(slots=2)
    rids = []
    for i in range(6):
        rids.append(eng.submit(ForecastRequest(
            program=progs[i % 2], state=_state(seed=30 + i),
            steps=1 + i % 2)))
    results = eng.drain()
    assert sorted(results) == sorted(rids)
    assert len(calls) == 2, [p.op for p in calls]
    assert {p.op for p in calls} == {"hdiff",
                                     pipeline_op_name(progs[1].stages)}
    s = eng.stats()
    assert s["plan_cache_misses"] == 2 and s["plan_cache_hits"] == 4
    assert plan_cache_key(progs[1], ensemble=2) in eng._plans


# ---------------------------------------------------------------------------
# Traffic model
# ---------------------------------------------------------------------------

def test_chained_traffic_beats_sequential_on_realistic_grids():
    """On a production-shaped grid the fused chain's HBM stream per round
    undercuts the summed solo stages (intermediates stay resident); the
    report carries both sides and their ratio."""
    prog = _pipe(_FLAGSHIP, grid=(8, 128, 128))
    t = compile(prog).report()["traffic"]
    assert t["chained_per_round"] < t["sequential_per_round"]
    assert t["chained_reduction_x"] > 1.0
    assert set(t["sequential_by_stage"]) == set(_FLAGSHIP)
    assert sum(t["sequential_by_stage"].values()) == t["sequential_per_round"]


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_chain_validation_refuses_bad_programs():
    with pytest.raises(ValueError, match="at least one stage"):
        PipelineProgram(grid_shape=_GRID, stages=())
    with pytest.raises(KeyError, match="unknown stencil op"):
        PipelineProgram(grid_shape=_GRID, stages=("no_such_op",))
    with pytest.raises(ValueError, match="apply_stage"):
        PipelineProgram(grid_shape=_GRID, stages=("dycore",))
    with pytest.raises(ValueError, match="unknown fields"):
        PipelineProgram(grid_shape=_GRID,
                        stages=(PipelineStage(op="hdiff",
                                              fields=("bogus",)),))
    with pytest.raises(ValueError, match="derives"):
        PipelineProgram(grid_shape=_GRID, op="hdiff", stages=("hdiff",))
    with pytest.raises(TypeError, match="expected a PipelineStage"):
        PipelineProgram(grid_shape=_GRID, stages=(42,))


# ---------------------------------------------------------------------------
# Forced-4-device distributed behaviour (subprocess)
# ---------------------------------------------------------------------------

_DIST_PIPELINE_SNIPPET = """
import jax, numpy as np
from repro.core import trace_stats
from repro.weather import domain, fields
from repro.weather.program import StencilProgram, compile
from repro.weather.pipeline import PipelineProgram

kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
grid = (4, 16, 16)
st = fields.initial_state(jax.random.PRNGKey(0), grid, ensemble=2)
FLAG = ("hadv_upwind", "vadvc_update", "hdiff")

def pipe(**kw):
    kw.setdefault("variant", "whole_state")
    kw.setdefault("k_steps", 1)
    kw.setdefault("stages", FLAG)
    return PipelineProgram(grid_shape=grid, ensemble=2, coeff=0.05, **kw)

plan = compile(pipe(), mesh=mesh)
rep = plan.report()
# ONE packed exchange pair per direction, regardless of chain length.
assert rep["collectives_per_round"] == 4, rep["collectives_per_round"]
assert rep["pallas_calls_per_round"] == 3
trace_stats.assert_plan_structure(jax.make_jaxpr(plan.step)(st), rep)

sh = domain.shard_state(st, mesh, plan.state_spec)
out = plan.step(sh)
seq = sh
for op in FLAG:
    p = compile(StencilProgram(grid_shape=grid, ensemble=2, coeff=0.05,
                               op=op, variant="whole_state", k_steps=1),
                mesh=mesh)
    seq = p.step(seq)
for n in fields.PROGNOSTIC:
    assert np.array_equal(np.asarray(out.fields[n]),
                          np.asarray(seq.fields[n])), n
    assert np.array_equal(np.asarray(out.stage_tens[n]),
                          np.asarray(seq.stage_tens[n])), n

# k=2 reuses ONE deeper exchange pair per direction and matches two rounds.
kplan = compile(pipe(variant="kstep", k_steps=2), mesh=mesh)
krep = kplan.report()
assert krep["collectives_per_round"] == 4, krep["collectives_per_round"]
trace_stats.assert_plan_structure(jax.make_jaxpr(kplan.step)(st), krep)
a = plan.step(plan.step(sh))
b = kplan.step(sh)
for n in fields.PROGNOSTIC:
    assert np.array_equal(np.asarray(a.fields[n]), np.asarray(b.fields[n])), n

# Zero-ride chain: every direction's exchange is elided on the mesh.
ap = compile(PipelineProgram(grid_shape=grid, ensemble=2,
                             stages=("asselin",)), mesh=mesh)
arep = ap.report()
assert arep["collectives_per_round"] == 0, arep["collectives_per_round"]
trace_stats.assert_plan_structure(jax.make_jaxpr(ap.step)(st), arep)

# bf16 wire: still one pair per direction; error bounded, not bit-equal.
bp = compile(pipe(exchange_dtype="bfloat16"), mesh=mesh)
brep = bp.report()
assert brep["collectives_per_round"] == 4
trace_stats.assert_plan_structure(jax.make_jaxpr(bp.step)(st), brep)
outb = bp.step(sh)
errs = [float(np.abs(np.asarray(outb.fields[n]) -
                     np.asarray(out.fields[n])).max())
        for n in fields.PROGNOSTIC]
assert 0.0 < max(errs) < 0.1, errs

print("PIPELINE_DIST_OK")
"""


def _run_forced_device_snippet(snippet: str, marker: str):
    """Run `snippet` in a subprocess with 4 forced host CPU devices."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, r.stderr[-2000:]


def test_distributed_pipeline_fused_exchange_and_bit_identity():
    """Forced-4-device subprocess: the flagship chain compiles to ONE
    packed ppermute pair per direction per round (4 collectives on a 2x2
    mesh, traced == reported), its sharded step is bitwise equal to the
    sequential solo plans on the same mesh, a k=2 round still costs 4
    collectives and matches two k=1 rounds, an asselin-only chain elides
    every exchange, and a bfloat16 wire keeps the cast confined to the
    halo."""
    _run_forced_device_snippet(_DIST_PIPELINE_SNIPPET, "PIPELINE_DIST_OK")
