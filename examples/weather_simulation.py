"""End-to-end weather driver: ensemble dycore simulation with the paper's
compound kernels, optionally domain-decomposed over a device mesh.

The execution strategy comes from ONE declarative plan
(`repro.weather.program.compile_dycore`): the spec names the grid,
ensemble, and policies; the planner resolves the variant (whole-state
fused / in-kernel k-step / unfused oracle via `--no-fused`), the
auto-tuned tile, the steps-per-round depth (`--k-steps`, `auto` lets the
exchange model pick), and — on a mesh — the ragged packed halo-exchange
schedule.  `plan.run` advances any step count (a shorter tail round
covers `steps % k`).  Ensemble members (`--ensemble N`) are
data-parallel: on a mesh with a "pod" axis they shard across it with zero
extra halo traffic — see docs/architecture.md ("Scale-out: domain
decomposition and ensemble pods").

Run:  PYTHONPATH=src python examples/weather_simulation.py --steps 10
      XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/weather_simulation.py --mesh 2,2
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.weather import domain, fields
from repro.weather.program import DycoreProgram, compile_dycore
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="16,64,64")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ensemble", type=int, default=2)
    ap.add_argument("--mesh", default="",
                    help="e.g. 2,2 -> ('data','model') decomposition")
    ap.add_argument("--k-steps", default="1",
                    help="timesteps per round (int, or 'auto' to let the "
                         "planner resolve the communication-avoiding k)")
    ap.add_argument("--op", default="dycore",
                    choices=("dycore", "hdiff", "vadvc"),
                    help="which registered stencil op to run (the paper "
                         "evaluates hdiff and vadvc separately)")
    ap.add_argument("--no-fused", action="store_true",
                    help="unfused oracle composition instead of the fused "
                         "Pallas pipeline (docs/architecture.md)")
    args = ap.parse_args()

    grid = tuple(int(x) for x in args.grid.split(","))
    k_steps = args.k_steps if args.k_steps == "auto" else int(args.k_steps)
    st = fields.initial_state(jax.random.PRNGKey(0), grid,
                              ensemble=args.ensemble)
    print(f"grid={grid} ensemble={args.ensemble} steps={args.steps}")

    if args.op == "vadvc" and k_steps not in (1, "auto"):
        raise SystemExit("vadvc has no k-step round (its footprint does "
                         "not deepen with k); use --k-steps 1")
    program = DycoreProgram(
        grid_shape=grid, ensemble=args.ensemble, op=args.op,
        variant="unfused" if args.no_fused else "auto", k_steps=k_steps)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model"))
        plan = compile_dycore(program, mesh=mesh)
        st = domain.shard_state(st, mesh, plan.state_spec)
        print(f"domain-decomposed over mesh {dict(mesh.shape)}")
    else:
        plan = compile_dycore(program)
    rep = plan.report()
    print(f"plan: variant={rep['variant']} k_steps={rep['k_steps']} "
          f"tile={rep['tile']['tile'] if rep['tile'] else None} "
          f"launches/round={rep['pallas_calls_per_round']} "
          f"collectives/round={rep['collectives_per_round']}")

    t0 = time.perf_counter()
    energy0 = float(sum(jnp.sum(jnp.square(f))
                        for f in st.fields.values()))
    st = plan.run(st, args.steps)   # full rounds + ragged tail if needed
    jax.block_until_ready(st.fields["t"])
    dt = time.perf_counter() - t0
    energy1 = float(sum(jnp.sum(jnp.square(f)) for f in st.fields.values()))
    pts = args.ensemble * np.prod(grid) * args.steps
    print(f"{args.steps} steps in {dt:.2f}s "
          f"({pts / dt / 1e6:.1f}M point-updates/s)")
    print(f"field energy {energy0:.1f} -> {energy1:.1f} "
          f"(diffusion dissipates: {energy1 < energy0})")
    assert np.isfinite(energy1)
    print("weather simulation OK")


if __name__ == "__main__":
    main()
