"""Serving launcher: --arch <id> --smoke runs batched requests end-to-end."""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.reduced_config(cfg)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 12)).astype(
                                            np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine = ServeEngine(model, params, batch=args.batch, max_len=64,
                         temperature=args.temperature)
    results = engine.run(reqs)
    for rid in sorted(results):
        print(f"req {rid}: {results[rid]}")
    print(f"[serve] completed {len(results)} requests")


if __name__ == "__main__":
    main()
