"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The RG-LRU recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t) is the
same first-order linear sweep as the vadvc Thomas forward sweep — NERO's
"sequential in depth, parallel across columns" pattern.  Training/prefill
uses jax.lax.associative_scan (log-depth); decode carries (h, conv) state.
The Pallas `lru_scan` kernel implements the same sweep with VMEM-resident
carry for the TPU serving path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def rglru_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.rec.rnn_width or d
    cw = cfg.rec.conv_width
    ks = jax.random.split(key, 7)
    return {
        "w_branch_x": dense_init(ks[0], d, w, dtype),
        "w_branch_g": dense_init(ks[1], d, w, dtype),
        "conv": (jax.random.normal(ks[2], (cw, w), jnp.float32)
                 * (1.0 / cw)).astype(dtype),
        "w_rec_gate": dense_init(ks[3], w, w, dtype),
        "w_in_gate": dense_init(ks[4], w, w, dtype),
        # Λ init so a^(1/c) ∈ (0.9, 0.999) as in Griffin
        "lam": jnp.linspace(2.0, 6.0, w, dtype=jnp.float32),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def causal_conv1d(x: jnp.ndarray, kernel: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B, T, W); kernel: (cw, W).
    With `state` (B, cw-1, W) does streaming conv and returns new state."""
    cw = kernel.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, :cw - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, T+cw-1, W)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else jnp.zeros_like(x[:, :0])
    return out, new_state


def _gates(params, x):
    """a_t (decay) and gated input for the LRU, fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_in_gate"].astype(jnp.float32))
    c = 8.0
    log_a = -c * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = i * xf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def lru_scan(a: jnp.ndarray, b: jnp.ndarray,
             h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t along axis 1 (associative scan)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(cfg: ModelConfig, params, x: jnp.ndarray,
                      state: Optional[dict] = None):
    """Griffin recurrent block.  x: (B, T, D).

    state (decode): {"h": (B, W) fp32, "conv": (B, cw-1, W)}.
    Returns (out, new_state)."""
    xb = x @ params["w_branch_x"]
    gb = jax.nn.gelu(x @ params["w_branch_g"])
    conv_state = state["conv"] if state is not None else None
    xb, new_conv = causal_conv1d(xb, params["conv"], conv_state)
    a, b = _gates(params, xb)
    h0 = state["h"] if state is not None else None
    h = lru_scan(a, b, h0)
    out = (h.astype(x.dtype) * gb) @ params["w_out"]
    new_state = {"h": h[:, -1], "conv": new_conv}
    return out, new_state


def rglru_init_state(cfg: ModelConfig, batch: int, dtype):
    w = cfg.rec.rnn_width or cfg.d_model
    cw = cfg.rec.conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}
