"""Pallas TPU flash attention (GQA, causal / sliding-window / softcap).

The NERO discipline applied to attention: the (T, S) score matrix — the
HBM-traffic hot spot the roofline pass identifies in every transformer cell
— never leaves VMEM.  Per (batch, head, q-block) the KV stream is tiled
into VMEM blocks and consumed with an online-softmax dataflow; running max
/ normalizer / accumulator live in VMEM scratch across the kv grid axis
(the Pallas analogue of the paper's per-PE URAM/BRAM intermediate buffers,
with the same load/compute/store overlap via the Pallas grid pipeline).

Grid: (B, H, nq, nk), kv innermost ("arbitrary" — carries scratch state);
GQA maps query head h to kv head h // (H // KH) in the k/v index_maps, so
no KV replication is ever materialized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  softcap: float, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_mha_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     causal: bool = True, window: int = 0,
                     softcap: float = 0.0, block_q: int = 128,
                     block_k: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, T, H, hd); k, v: (B, S, KH, hd).  T % block_q == S % block_k
    == 0 (pick blocks with kernels.flash_attention.ops.auto_blocks)."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(block_q, t)
    bk = min(block_k, s)
    if t % bq or s % bk:
        raise ValueError(f"(T={t}, S={s}) must tile by ({bq}, {bk})")
    nq, nk = t // bq, s // bk

    qt = q.transpose(0, 2, 1, 3)                         # (B, H, T, hd)
    kt = k.transpose(0, 2, 1, 3)                         # (B, KH, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        softcap=softcap, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),            # running max
            pltpu.VMEM((bq, 1), jnp.float32),            # running sum
            pltpu.VMEM((bq, hd), jnp.float32),           # output accum
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="nero_flash_mha",
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)                     # (B, T, H, hd)
