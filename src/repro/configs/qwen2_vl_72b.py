"""Qwen2-VL-72B — VLM text backbone with M-RoPE; vision frontend stubbed
[arXiv:2409.12191; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    pattern=("attn",), rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    norm="rms", gated_mlp=True, act="silu",
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
