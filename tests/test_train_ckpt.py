"""Train loop + checkpointing: loss decreases, resume is bit-exact,
keep-N GC, async saver, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs import registry
from repro.data import synthetic
from repro.models import api
from repro.train import loop, optim
from repro.launch.mesh import make_mesh


@pytest.fixture()
def tiny():
    cfg = registry.reduced_config(registry.get_config("tinyllama-1.1b"),
                                  layers=2)
    model = api.build(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    return cfg, model, mesh


def test_loss_decreases(tiny):
    cfg, model, mesh = tiny
    data = synthetic.iterator(cfg, batch=4, seq=32, prefetch=0)
    opt_cfg = optim.OptConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    _, _, hist = loop.fit(model, mesh, data, steps=30, opt_cfg=opt_cfg,
                          log_every=0, log_fn=lambda *_: None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_microbatch_equivalence(tiny):
    """Grad accumulation over microbatches == single big batch (same data)."""
    cfg, model, mesh = tiny
    opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                              clip_norm=1e9)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = optim.init_opt_state(params)
    batch = synthetic.lm_batch(cfg, 0, 0, 8, 32)
    batch = jax.tree.map(jnp.asarray, batch)

    step1, jit_for, _ = loop.make_train_step(model, mesh, opt_cfg,
                                             microbatches=1, remat="none")
    step4, _, _ = loop.make_train_step(model, mesh, opt_cfg,
                                       microbatches=4, remat="none")
    p1, _, m1 = step1(params, opt_state, batch)
    p4, _, m4 = step4(params, opt_state, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_checkpoint_roundtrip_and_keepn(tiny, tmp_path):
    cfg, model, mesh = tiny
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    d = str(tmp_path / "ck")
    for step in (1, 2, 3, 4, 5):
        ckpt_lib.save(d, step, params, opt_state, keep=2)
    assert ckpt_lib.all_steps(d) == [4, 5]
    assert ckpt_lib.latest_step(d) == 5

    from repro.parallel import sharding as shd
    p_shard = shd.params_sharding(model.param_shapes(), mesh, "train")
    o_shard = {"m": p_shard, "v": p_shard, "master": p_shard,
               "step": jax.sharding.NamedSharding(
                   mesh, jax.sharding.PartitionSpec())}
    p2, o2, step = ckpt_lib.restore(d, 5, mesh, p_shard, o_shard)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_resume_reproduces_uninterrupted_run(tiny, tmp_path):
    """Fault-tolerance: train 6 steps; train 3 + crash + resume 3 must land
    on identical weights (deterministic data = f(seed, step))."""
    cfg, model, mesh = tiny
    d = str(tmp_path / "ck")
    opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=0, total_steps=6)

    def run(steps, ckpt_every):
        data = synthetic.iterator(cfg, batch=2, seq=16, prefetch=0)
        return loop.fit(model, mesh, data, steps=steps, opt_cfg=opt_cfg,
                        ckpt_dir=d, ckpt_every=ckpt_every, log_every=0,
                        log_fn=lambda *_: None)

    p_full, _, _ = run(6, ckpt_every=100)        # uninterrupted
    import shutil
    shutil.rmtree(d)
    run(3, ckpt_every=3)                         # "crash" after step 3
    p_res, _, _ = run(6, ckpt_every=100)         # auto-resumes from 3
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_async_saver(tiny, tmp_path):
    cfg, model, mesh = tiny
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init_opt_state(params)
    s = ckpt_lib.AsyncSaver(str(tmp_path / "ck"))
    s.save(7, params, opt_state)
    s.wait()
    assert ckpt_lib.latest_step(str(tmp_path / "ck")) == 7


def test_watchdog_flags_stragglers():
    w = loop.WatchdogStats(threshold=2.0)
    for _ in range(10):
        assert not w.record(0.1)
    assert w.record(1.0)
    assert w.slow_steps == 1


def test_schedule_warmup_and_decay():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(optim.schedule(cfg, jnp.int32(0))) < 0.2
    assert float(optim.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0,
                                                                      abs=.1)
    assert float(optim.schedule(cfg, jnp.int32(99))) < 0.01
