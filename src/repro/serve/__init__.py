"""repro.serve subpackage: serving engines.

`engine.ServeEngine` is the LM token-serving reference; `forecast` is the
weather-stack service layer — `ForecastEngine` continuous-batches
concurrent forecast requests into the ensemble axis of cached
ExecutionPlans (see docs/serving.md).
"""

from repro.serve.forecast import (ForecastEngine, ForecastRequest,
                                  ForecastResult, QueueFullError)

__all__ = ["ForecastEngine", "ForecastRequest", "ForecastResult",
           "QueueFullError"]
