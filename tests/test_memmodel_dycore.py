"""Whole-state dycore traffic + k-step exchange accounting (memmodel)."""

import pytest

from repro.core import memmodel, tiling

def test_dycore_traffic_whole_state_beats_per_field():
    """Whole-state fused step: shared-w batching must strictly reduce
    modeled HBM traffic vs the per-field fused step, in both bounds."""
    for dtype in ("float32", "bfloat16"):
        t = memmodel.dycore_step_traffic((64, 256, 256), dtype,
                                         n_fields=4, ty=32)
        assert t["fused_whole"]["total"] < t["fused"]["total"]
        assert (t["fused_whole"]["stream_window_reads"]
                < t["fused"]["stream_window_reads"])
        assert t["reduction_x_whole"] > t["reduction_x"] > 1.0
        # shared w saves ~the per-field w stream: bounded by 1/4 of inputs
        saving = t["fused"]["total"] / t["fused_whole"]["total"]
        assert 1.05 < saving < 1.25, saving


def test_kstep_exchange_model():
    """Communication-avoiding k-step: collective rounds drop k-fold; bytes
    stay within ~1x of sequential (deep halo ~= k shallow halos); the
    redundant-flops tax grows monotonically with k."""
    prev_tax = -1.0
    for k in (1, 2, 4):
        m = memmodel.kstep_exchange_model((64, 256, 256), "float32",
                                          n_fields=4, k=k, shards=(2, 2))
        assert m["rounds_kstep"] == 2
        assert m["rounds_sequential"] == 2 * k
        assert 0.5 < m["bytes_ratio"] <= 1.0 + 1e-9
        assert m["redundant_flops_frac"] > prev_tax
        prev_tax = m["redundant_flops_frac"]
    with pytest.raises(ValueError):
        memmodel.kstep_exchange_model((8, 16, 16), "float32", k=4,
                                      shards=(2, 2))


def test_whole_state_opspec_field_count_dependence():
    """More fields amortize the shared-w stream further (fields_in -> 3) but
    never change the resident VMEM accounting (scratch includes w)."""
    s2 = tiling.dycore_whole_state_spec(2)
    s8 = tiling.dycore_whole_state_spec(8)
    assert s8.fields_in < s2.fields_in
    assert s2.scratch_fields == s8.scratch_fields == 7
    with pytest.raises(ValueError):
        tiling.dycore_whole_state_spec(0)
