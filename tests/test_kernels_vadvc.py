"""vadvc: Pallas vs numpy/jnp oracles + the algebraic Thomas property."""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels.vadvc import ref
from repro.kernels.vadvc.vadvc import vadvc_pallas
from repro.kernels.vadvc.ops import vadvc as vadvc_op


def make_fields(rng, nz, ny, nx, scale=0.2):
    fields = [rng.normal(size=(nz, ny, nx)).astype(np.float32)
              for _ in range(4)]
    wcon = rng.uniform(-scale, scale,
                       size=(nz, ny, nx + 1)).astype(np.float32)
    return fields, wcon


SHAPES = [(4, 4, 8), (8, 8, 16), (16, 2, 8), (64, 4, 8)]


@pytest.mark.parametrize("shape", SHAPES)
def test_np_vs_jnp_oracles(shape, rng):
    (us, up, ut, uts), wcon = make_fields(rng, *shape)
    a = ref.vadvc_np(us, wcon, up, ut, uts)
    b = np.asarray(ref.vadvc(*map(jnp.asarray, (us, wcon, up, ut, uts))))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape,tiles", [
    ((4, 4, 8), (2, 4)), ((8, 8, 16), (4, 8)), ((8, 8, 16), (8, 16)),
    ((16, 2, 8), (2, 8)), ((16, 4, 8), (1, 4)),
])
def test_pallas_matches_oracle(shape, tiles, rng):
    (us, up, ut, uts), wcon = make_fields(rng, *shape)
    want = ref.vadvc_np(us, wcon, up, ut, uts)
    tj, ti = tiles
    got = np.asarray(vadvc_pallas(
        *map(jnp.asarray, (us, wcon, up, ut, uts)), tj=tj, ti=ti,
        interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ops_dispatch(rng):
    (us, up, ut, uts), wcon = make_fields(rng, 8, 4, 8)
    a = np.asarray(vadvc_op(*map(jnp.asarray, (us, wcon, up, ut, uts))))
    b = np.asarray(vadvc_op(*map(jnp.asarray, (us, wcon, up, ut, uts)),
                            use_pallas=True, tj=2, ti=4))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 12), st.integers(1, 4),
       st.integers(1, 6))
def test_thomas_solves_the_system(seed, nz, ny, nx):
    """Property: output reconstructs x with A x = d (paper's implicit
    vertical discretization), for ANY well-conditioned wcon."""
    rng = np.random.default_rng(seed)
    (us, up, ut, uts), wcon = make_fields(rng, nz, ny, nx)
    out = ref.vadvc_np(us, wcon, up, ut, uts)
    res = ref.tridiagonal_residual(us, wcon, up, ut, uts, out)
    assert res < 1e-9, f"residual {res}"


def test_pallas_solution_satisfies_system(rng):
    (us, up, ut, uts), wcon = make_fields(rng, 8, 4, 8)
    got = np.asarray(vadvc_pallas(
        *map(jnp.asarray, (us, wcon, up, ut, uts)), tj=2, ti=4,
        interpret=True), np.float64)
    res = ref.tridiagonal_residual(us, wcon, up, ut, uts, got)
    assert res < 1e-4          # fp32 kernel vs fp64 residual check
