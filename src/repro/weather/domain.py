"""Distributed dycore primitives: halo exchange + sharding utilities.

This is NERO's scale-out story made real (paper §5: "HBM provides an
attractive solution for scale-out computation" with one memory channel per
PE): every chip owns an (ny/Py, nx/Px) slab of the horizontal domain in its
own HBM; the compound stencils run chip-locally out of VMEM; the only
communication is a circular halo exchange (`jax.lax.ppermute` over the mesh
axes).  Vertical columns are never split (vadvc's z dependency), matching
the paper's PE design.

The strategy that *uses* these primitives — which stencil op runs
chip-locally, which variant, how deep each operand's halo is, what rides
the wire at which dtype — is resolved by the plan API
(`weather/program.py::compile` over the StencilOp registry,
`weather/stencil_ops.py`); the distributed lowerings there compose:

* `_exchange` — per-operand circular exchange (the per-field paths);
* `_exchange_packed` — the stacked RAGGED exchange: several tensors with
  PER-TENSOR (and per-SIDE) halo depths share one flattened wire buffer
  per direction, so the collective count stays at most one `ppermute` pair
  per mesh direction per round no matter how many operands ride or how
  ragged their depths are.  Depths come straight from the registered op's
  declared footprint and may be ZERO per side — a direction nothing rides
  is elided entirely (vadvc's right-only wcon column is ONE ppermute);
  the dycore's `wcon` ships its `+1` staggering x-column to the RIGHT
  side only (`w[c] = wcon[c] + wcon[c+1]` needs the right neighbor,
  never the left — the left pad's extra column was provably unread);
* `_staggered_w` / `_right_column` — the x-staggered velocity build;
* `_local_hdiff` / `_local_vadvc` — exchanged per-kernel local stencils
  (the unfused oracle's distributed form);
* `shard_state` — placing a `WeatherState` onto the mesh.

The legacy `make_distributed_step(...)` flag-soup shim is gone (retired
ROADMAP item): build a `StencilProgram`/`DycoreProgram` and call
`repro.weather.program.compile(program, mesh=mesh)`.  Ensemble members
ride the "pod" axis of the multi-pod mesh — see docs/architecture.md
("Scale-out: domain decomposition and ensemble pods").
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import WeatherState
from repro.weather.dycore import HALO


def _exchange(f: jnp.ndarray, axis_name: str, n: int, halo: int,
              dim: int) -> jnp.ndarray:
    """Circular halo exchange along `dim` over mesh axis `axis_name`.

    Returns f extended by `halo` on both sides of `dim`.  With n == 1 this
    degenerates to periodic wrap-padding (no communication).  `halo` must
    not exceed the local extent (a deeper exchange would need neighbors-of-
    neighbors data — callers check and raise)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    lo = take(f, slice(0, halo))          # my first rows -> neighbor below
    hi = take(f, slice(-halo, None))      # my last rows  -> neighbor above
    if n == 1:
        top, bot = hi, lo
    else:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = jax.lax.ppermute(hi, axis_name, perm=fwd)   # from rank-1
        bot = jax.lax.ppermute(lo, axis_name, perm=bwd)   # from rank+1
    return jnp.concatenate([top, f, bot], axis=dim)


def _exchange_packed(parts, axis_name: str, n: int, dim: int,
                     wire_dtype=None):
    """Circular halo exchange along `dim` for several tensors with
    PER-TENSOR — and per-SIDE — halo depths, packed into one flattened
    wire buffer per direction: exactly one `ppermute` pair regardless of
    operand count or depth raggedness.

    `parts` is a sequence of `(tensor, depth)` where `depth` is either an
    int (symmetric) or a `(lo_depth, hi_depth)` pair: the tensor comes
    back extended by `lo_depth` on the LOW side of `dim` (received from
    the lower-index neighbor) and `hi_depth` on the HIGH side (received
    from the upper-index neighbor).  This is how `wcon` ships its extra
    staggering column to the right side ONLY — `(k·HALO, k·HALO + 1)` —
    without forcing the whole stacked exchange one column deeper, and
    without wasting a never-read column on the left pad.

    Depths may be ZERO per side (and per operand): a zero side ships
    nothing for that operand, and when a direction's packed buffer is
    empty for EVERY operand the `ppermute` for that direction is elided
    entirely.  That is how a registered stencil op's declared footprint
    (`weather/stencil_ops.py`) lowers directly to the minimal collective
    set — e.g. vadvc's `(0, 1)` wcon ride is ONE ppermute (the right
    staggering column), not a pair.

    `wire_dtype` (e.g. bf16) casts the packed buffer before each
    `ppermute` and restores each tensor's dtype on arrival — half the
    wire bytes, rounding confined to the received halo ring.

    With n == 1 this degenerates to periodic wrap-padding (no
    communication, no cast)."""
    def take_last(a, d):
        idx = [slice(None)] * a.ndim
        # slice(-0, None) would be the WHOLE tensor; zero depth is empty.
        idx[dim] = slice(-d, None) if d else slice(0, 0)
        return a[tuple(idx)]

    def take_first(a, d):
        idx = [slice(None)] * a.ndim
        idx[dim] = slice(0, d)
        return a[tuple(idx)]

    depths = []
    for _, h in parts:
        lo_h, hi_h = (h, h) if isinstance(h, int) else h
        if lo_h < 0 or hi_h < 0:
            raise ValueError(f"packed-exchange depth {h!r} must be >= 0 "
                             f"on both sides")
        depths.append((lo_h, hi_h))
    # The LOW pad is the lower neighbor's LAST lo_h rows (forward ride);
    # the HIGH pad is the upper neighbor's FIRST hi_h rows (backward ride).
    hi_parts = [take_last(t, lo_h)
                for (t, _), (lo_h, _) in zip(parts, depths)]
    lo_parts = [take_first(t, hi_h)
                for (t, _), (_, hi_h) in zip(parts, depths)]

    def ride(xs, perm):
        """One packed ppermute of `xs`; elided when nothing rides."""
        if n == 1 or all(x.size == 0 for x in xs):
            return xs

        buf = jnp.concatenate([x.reshape(-1) for x in xs])
        if wire_dtype is not None:
            buf = buf.astype(wire_dtype)
        buf = jax.lax.ppermute(buf, axis_name, perm=perm)
        out, off = [], 0
        for x in xs:
            seg = buf[off:off + x.size]
            out.append(seg.reshape(x.shape).astype(x.dtype))
            off += x.size
        return out

    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    top = ride(hi_parts, fwd)
    bot = ride(lo_parts, bwd)
    return [jnp.concatenate([t_, t, b_], axis=dim)
            for (t, _), t_, b_ in zip(parts, top, bot)]


def _right_column(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """The x-staggered neighbor of the slab's last column: the x-neighbor
    shard's first column (periodic 1-column exchange)."""
    if nx_shards == 1:
        return wcon[..., :1]
    bwd = [(i, (i - 1) % nx_shards) for i in range(nx_shards)]
    return jax.lax.ppermute(wcon[..., :1], ax_x, perm=bwd)


def _staggered_w(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """w = wcon_i + wcon_{i+1} on the local slab (see _right_column)."""
    right = _right_column(wcon, ax_x, nx_shards)
    return wcon + jnp.concatenate([wcon[..., 1:], right], axis=-1)


def _local_hdiff(f: jnp.ndarray, coeff: float, ax_y: str, ax_x: str,
                 ny_shards: int, nx_shards: int) -> jnp.ndarray:
    """f: (E, nz, ly, lx) local slab -> diffused slab."""
    e, nz, ly, lx = f.shape
    g = _exchange(f, ax_y, ny_shards, HALO, dim=2)
    g = _exchange(g, ax_x, nx_shards, HALO, dim=3)
    out = hdiff_ref.hdiff(g.reshape(e * nz, ly + 2 * HALO, lx + 2 * HALO),
                          coeff=coeff)
    out = out.reshape(e, nz, ly + 2 * HALO, lx + 2 * HALO)
    return out[:, :, HALO:HALO + ly, HALO:HALO + lx]


def _local_vadvc(u_stage, wcon, u_pos, utens, utens_stage, ax_x, nx_shards):
    """All (E, nz, ly, lx); staggered wcon column fetched from x-neighbor."""
    wcon_s = jnp.concatenate(
        [wcon, _right_column(wcon, ax_x, nx_shards)], axis=-1)
    # vmap over ensemble; fields already (nz, ly, lx) per member.
    out = jax.vmap(vadvc_ref.vadvc)(u_stage, wcon_s, u_pos, utens,
                                    utens_stage)
    return out


def shard_state(state: WeatherState, mesh: Mesh, spec: P) -> WeatherState:
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda a: jax.device_put(a, sharding), state)


def gather_state(state: WeatherState) -> WeatherState:
    """Pull a (possibly sharded) state fully to host as numpy arrays —
    the unsharded-logical form every mesh can reshard from.  This is the
    reshard pivot of the elastic failover/restore path: gather on the old
    mesh, `shard_state` on the new one."""
    return jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)


def _mesh_from(devices, shape: Tuple[int, int], axes) -> Mesh:
    kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * len(axes)}
          if hasattr(jax.sharding, "AxisType") else {})
    n = shape[0] * shape[1]
    return Mesh(np.asarray(devices[:n]).reshape(shape), tuple(axes), **kw)


def failover_meshes(devices, grids: Iterable[Tuple[int, int, int]],
                    axes=("data", "model"),
                    like: Optional[Tuple[int, int]] = None) -> List[Mesh]:
    """Candidate meshes over surviving `devices`, best first.

    Every candidate's (py, px) divides EVERY grid in `grids` (ny over py,
    nx over px) — one mesh must carry every lane.  Ordering: more devices
    first; then shapes whose sharded-axis PATTERN matches `like` (the
    dying mesh's (py, px)).  The pattern preference is a bitwise-identity
    matter, not cosmetics: collapsing a sharded axis to 1 shard switches
    that axis from halo-exchange to wrap-padding lowering, which changes
    result bits for ops that are not sharding-transparent — whereas
    *shrinking* a sharded axis (4→2 shards) provably keeps bits (see
    tests/test_mesh_failover.py).  A caller walks the list and takes the
    first mesh its plans compile on."""
    devices = list(devices)
    grids = list(grids)
    cands: List[Tuple[int, int]] = []
    for n in range(len(devices), 0, -1):
        for py in range(n, 0, -1):
            if n % py:
                continue
            px = n // py
            if all(ny % py == 0 and nx % px == 0 for _, ny, nx in grids):
                cands.append((py, px))

    def score(pp):
        py, px = pp
        match = 0
        if like is not None:
            match = ((py > 1) == (like[0] > 1)) + ((px > 1) == (like[1] > 1))
        return (-(py * px), -match, -py)

    return [_mesh_from(devices, pp, axes)
            for pp in sorted(cands, key=score)]
