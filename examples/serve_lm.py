"""End-to-end serving driver: batched requests through prefill + decode
with continuous slot batching (reduced gemma3 config exercises the
local:global ring-buffer cache path).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.reduced_config(registry.get_config(args.arch))
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving reduced {args.arch}: "
          f"{cfg.param_count() / 1e6:.1f}M params (smoke scale)")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(4, 12))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine = ServeEngine(model, params, batch=args.batch, max_len=64)
    t0 = time.perf_counter()
    results = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"req {rid}: {results[rid][:8]}...")
    print(f"{len(results)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    assert len(results) == args.requests
    print("serve_lm OK")


if __name__ == "__main__":
    main()
