"""Whisper-style encoder-decoder backbone (conv audio frontend is a stub:
inputs are precomputed frame embeddings, per the assignment).

Encoder: bidirectional attention blocks over frames (+ sinusoidal pos).
Decoder: causal self-attention + cross-attention + FFN, scan-stacked.
Positional scheme: sinusoidal absolute embeddings (whisper); rope is
disabled via rope_theta=0 in the whisper config.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import blocks as B
from repro.models.common import embed_init, norm_apply, norm_init
from repro.parallel import policy


def sinusoid_at(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """positions: (B, T) -> (B, T, d) sinusoidal embedding (traced-safe)."""
    pos = positions.astype(jnp.float32)[..., None]
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    ed = cfg.encdec

    def enc_block(k):
        return B.block_init("attn", k, cfg, dtype)

    def dec_block(k):
        kk = jax.random.split(k, 2)
        p = B.block_init("attn", kk[0], cfg, dtype)
        p["xattn"] = B.attn_init(kk[1], cfg, dtype)
        p["norm_x"] = norm_init(cfg, cfg.d_model)
        return p

    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(enc_block)(
            jax.random.split(ks[1], ed.encoder_layers)),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(dec_block)(
            jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": norm_init(cfg, cfg.d_model),
        "head": embed_init(ks[3], cfg.padded_vocab, cfg.d_model, dtype).T,
    }


def encode(cfg: ModelConfig, params, frames: jnp.ndarray,
           scan_unroll: bool = False) -> jnp.ndarray:
    """frames: (B, F, D) stub conv-frontend output -> encoder states."""
    b, f, d = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f), (b, f))
    x = (frames.astype(jnp.dtype(cfg.dtype))
         + sinusoid_at(positions, d).astype(cfg.dtype))

    def body(xc, p):
        xc = policy.batch_only(xc)
        xc, _, _ = B.block_apply("attn", cfg, p, xc, positions=positions,
                                 mode="train", causal=False)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.encdec.encoder_layers if scan_unroll
                        else 1)
    return norm_apply(cfg, params["enc_norm"], x)


def _cross_attend(cfg: ModelConfig, p_blk, x, enc):
    b, t, d = x.shape
    f = enc.shape[1]
    hd = cfg.hd
    h = norm_apply(cfg, p_blk["norm_x"], x)
    q = (h @ p_blk["xattn"]["wq"]).reshape(b, t, cfg.n_heads, hd)
    k = (enc @ p_blk["xattn"]["wk"]).reshape(b, f, cfg.n_kv_heads, hd)
    v = (enc @ p_blk["xattn"]["wv"]).reshape(b, f, cfg.n_kv_heads, hd)
    out = attn_lib.dense_attention(q, k, v, causal=False)
    return x + out.reshape(b, t, cfg.n_heads * hd) @ p_blk["xattn"]["wo"]


def decode(cfg: ModelConfig, params, tokens, enc, *, mode="train",
           cache=None, pos=0, scan_unroll: bool = False,
           return_hidden: bool = False):
    """Decoder forward.  tokens (B, T); enc (B, F, D).
    Returns (logits, new_cache)."""
    b, t = tokens.shape
    d = cfg.d_model
    offset = pos if mode == "decode" else 0
    positions = jnp.broadcast_to(offset + jnp.arange(t), (b, t))
    x = (params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
         + sinusoid_at(positions, d).astype(cfg.dtype))

    if cache is not None:
        xs_cache = cache["dec"]
    else:
        xs_cache = jax.tree.map(
            lambda _: jnp.zeros((cfg.n_layers,), jnp.float32), {"self": 0.0})

    def body(xc, xs):
        xc = policy.batch_only(xc)
        p_blk, c_blk = xs
        c_self = c_blk["self"] if cache is not None else None
        xc, nc, _ = B.block_apply("attn", cfg, p_blk, xc,
                                  positions=positions, mode=mode,
                                  cache=c_self, pos=pos)
        xc = _cross_attend(cfg, p_blk, xc, enc)
        out_c = ({"self": nc} if cache is not None
                 else {"self": jnp.zeros((), jnp.float32)})
        return xc, out_c

    x, new_dec_cache = jax.lax.scan(body, x, (params["dec_blocks"], xs_cache),
                                    unroll=cfg.n_layers if scan_unroll else 1)
    x = norm_apply(cfg, params["final_norm"], x)
    new_cache = {"dec": new_dec_cache} if cache is not None else None
    if return_hidden:
        return x, new_cache
    from repro.models.lm import mask_padded_vocab
    logits = mask_padded_vocab(x @ params["head"].astype(x.dtype),
                               cfg.vocab_size)
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)

    def one(_):
        return {"self": B.init_block_cache("attn", cfg, batch, max_len,
                                           dtype)}

    return {"dec": jax.vmap(one)(jnp.arange(cfg.n_layers))}


def loss_fn(cfg: ModelConfig, params, batch, remat: str = "full",
            scan_unroll: bool = False, xent_chunk: int = 512):
    """batch: {"tokens": (B, T), "frames": (B, F, D)}."""
    from repro.models.lm import chunked_xent
    enc = encode(cfg, params, batch["frames"], scan_unroll=scan_unroll)
    hidden, _ = decode(cfg, params, batch["tokens"], enc, mode="train",
                       scan_unroll=scan_unroll, return_hidden=True)
    return chunked_xent(hidden[:, :-1], params["head"],
                        batch["tokens"][:, 1:], chunk=xent_chunk,
                        unroll=scan_unroll, vocab=cfg.vocab_size)
