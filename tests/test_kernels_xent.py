"""Fused cross-entropy Pallas kernel vs jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels.xent import ref, xent_pallas
from repro.kernels.xent.ops import fused_xent_mean


def _case(n, d, vp, vocab, dtype, softcap=0.0, bn=64, bv=128, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    hidden = jax.random.normal(ks[0], (n, d), jnp.float32).astype(dtype)
    head = jax.random.normal(ks[1], (d, vp), jnp.float32).astype(dtype) * 0.1
    targets = jax.random.randint(ks[2], (n,), 0, vocab)
    got = xent_pallas(hidden, head, targets, vocab=vocab, softcap=softcap,
                      block_n=bn, block_v=bv, interpret=True)
    want_sum = ref.xent(hidden, head, targets, vocab=vocab, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(float(got.sum()), float(want_sum),
                               rtol=tol)
    return got


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_basic(dtype):
    _case(128, 64, 512, 512, dtype)


def test_padded_vocab_columns_ignored():
    # vocab 300 inside physical 384: padding columns must not leak
    _case(64, 32, 384, 300, jnp.float32)


def test_softcap():
    _case(64, 32, 256, 256, jnp.float32, softcap=20.0)


def test_valid_mask_zeroes_rows():
    hidden = jnp.ones((64, 32), jnp.float32)
    head = jnp.ones((32, 128), jnp.float32)
    targets = jnp.zeros((64,), jnp.int32)
    valid = jnp.zeros((64,), jnp.float32).at[:10].set(1.0)
    nll = xent_pallas(hidden, head, targets, valid, interpret=True)
    assert float(jnp.abs(nll[10:]).max()) == 0.0
    assert float(jnp.abs(nll[:10]).min()) > 0.0


def test_fused_mean_matches_model_loss_shape():
    out = fused_xent_mean(jnp.ones((2, 32, 16), jnp.bfloat16),
                          jnp.ones((16, 256), jnp.bfloat16) * 0.01,
                          jnp.zeros((2, 32), jnp.int32),
                          vocab=250, interpret=True)
    assert out.shape == ()
    assert np.isfinite(float(out))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([32, 64]),
       st.sampled_from([(256, 256), (384, 300), (512, 500)]),
       st.integers(0, 100))
def test_property_sweep(n, d, vshape, seed):
    vp, vocab = vshape
    _case(n, d, vp, vocab, jnp.float32, seed=seed)
