"""Shared benchmark utilities: wall-clock timing + CSV/JSON emission.

Every `emit` call prints the CSV row AND records it in an in-process
registry, so benchmark modules can dump machine-readable `BENCH_*.json`
artifacts (`write_json`) for cross-PR perf tracking — see
docs/benchmarks.md ("Machine-readable output").  `BENCH_DIR` (env) picks
the output directory, default CWD.  `BENCH_SMOKE=1` asks modules to shrink
to CI-smoke sizes.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (blocks on device)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


_RECORDS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": us_per_call,
                     "derived": derived})


def records() -> List[Dict]:
    """All rows emitted so far in this process (CSV mirror)."""
    return list(_RECORDS)


def smoke_mode() -> bool:
    """CI smoke runs (BENCH_SMOKE=1) shrink grids/iters to stay fast."""
    return os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def bench_path(filename: str) -> str:
    """Where a BENCH_*.json artifact lands (BENCH_DIR env, default CWD)."""
    return os.path.join(os.environ.get("BENCH_DIR", "."), filename)


def write_json(filename: str, payload: Dict) -> str:
    """Dump `payload` (+ backend/smoke/fidelity metadata) to
    BENCH_DIR/filename.

    The `fidelity` block makes ROADMAP's interpreter caveat
    machine-readable: which jax backend measured the walltimes, whether
    Pallas ran interpreted, which hardware spec (by content fingerprint)
    the modeled numbers target, and whether the walltimes can be trusted
    as that machine's.  bench-smoke refuses an artifact whose fingerprint
    does not match the shipped spec."""
    from repro.core import hwspec

    path = bench_path(filename)
    payload = dict(payload)
    payload.setdefault("backend", jax.default_backend())
    payload.setdefault("smoke", smoke_mode())
    payload.setdefault("fidelity", hwspec.execution_fidelity())
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    print(f"# wrote {path}")
    return path
