"""Training step builder + fault-tolerant training loop.

`make_train_step` returns the jitted SPMD train step with the sharding rules
applied (FSDP+TP+DP per parallel/sharding.py), microbatch gradient
accumulation via lax.scan, and donated params/opt-state.

`fit` is the production loop: checkpoint/restart (atomic, keep-N, async),
deterministic data, a straggler/step-time watchdog, and metric logging.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.parallel import policy
from repro.parallel import sharding as shd
from repro.train import optim as opt_lib


def make_train_step(model: Model, mesh, opt_cfg: opt_lib.OptConfig,
                    microbatches: int = 1, remat: str = "full",
                    donate: bool = True, scan_unroll: bool = False,
                    grad_dtype: str = "float32"):
    """Returns (train_step, shardings) — train_step(params, opt, batch).

    grad_dtype="bfloat16" accumulates/reduces microbatch gradients in bf16
    (2x wire compression on the cross-data dW reductions — the gradient-
    compression knob for collective-bound cells; fp32 master weights keep
    the update exact)."""
    cfg = model.cfg
    acc_dtype = jnp.dtype(grad_dtype)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat,
                          scan_unroll=scan_unroll)

    shapes = model.param_shapes()
    p_shard = shd.params_sharding(shapes, mesh, "train")

    def _pin_grads(tree):
        """Keep the f32 grad accumulator on the FSDP/TP param layout.
        Unpinned, GSPMD replicates the scan carry and all-reduces FULL dW
        per microbatch (measured 802 GB/device on gemma3 train) instead of
        reduce-scattering into shards."""
        return jax.tree.map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            tree, p_shard)

    def step_fn(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype) / microbatches,
                    acc, grads)
                return _pin_grads(acc), loss

            split = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = _pin_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            grads, losses = jax.lax.scan(
                micro, zero, split,
                unroll=microbatches if scan_unroll else 1)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin_grads(grads)
        new_params, new_opt, metrics = opt_lib.apply_updates(
            opt_cfg, params, opt_state, grads)
        metrics["loss"] = loss
        return new_params, new_opt, metrics
    o_shard = {"m": p_shard, "v": p_shard, "master": p_shard,
               "step": NamedSharding(mesh, P())}
    rep = NamedSharding(mesh, P())

    def batch_shardings(batch_spec):
        return jax.tree.map(
            lambda s: NamedSharding(
                mesh, shd.data_spec(mesh, s.shape[0], len(s.shape))),
            batch_spec)

    def jit_for(batch_spec):
        b_shard = batch_shardings(batch_spec)
        m_shard = {"grad_norm": rep, "lr": rep, "loss": rep}
        return jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, m_shard),
            donate_argnums=(0, 1) if donate else ())

    return step_fn, jit_for, (p_shard, o_shard)


@dataclasses.dataclass
class WatchdogStats:
    """Straggler / slow-step detection: on real pods a slow step usually
    means a failing host or contended interconnect; we log and count so the
    launcher can decide to checkpoint-and-remesh."""
    times: list = dataclasses.field(default_factory=list)
    slow_steps: int = 0
    threshold: float = 3.0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 8:
            med = statistics.median(self.times[-64:])
            if dt > self.threshold * med:
                self.slow_steps += 1
                return True
        return False


def fit(model: Model, mesh, data_iter: Iterator[Dict[str, jnp.ndarray]],
        steps: int, opt_cfg: Optional[opt_lib.OptConfig] = None,
        microbatches: int = 1, remat: str = "full",
        ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
        log_every: int = 10, seed: int = 0,
        log_fn: Callable[[str], None] = print):
    """Train for `steps`, resuming from the latest checkpoint if present."""
    from repro.ckpt import checkpoint as ckpt_lib

    opt_cfg = opt_cfg or opt_lib.OptConfig(total_steps=steps)
    _, jit_for, (p_shard, o_shard) = make_train_step(
        model, mesh, opt_cfg, microbatches=microbatches, remat=remat)

    start_step = 0
    params = opt_state = None
    if ckpt_dir:
        latest = ckpt_lib.latest_step(ckpt_dir)
        if latest is not None:
            log_fn(f"[fit] resuming from step {latest}")
            params, opt_state, start_step = ckpt_lib.restore(
                ckpt_dir, latest, mesh, p_shard, o_shard)
    if params is None:
        key = jax.random.PRNGKey(seed)
        params = jax.device_put(model.init(key), p_shard)
        opt_state = jax.device_put(opt_lib.init_opt_state(params), o_shard)
    elif start_step:
        # Data contract: batches are a pure function of (seed, step), so a
        # resumed run must realign the stream — fast-forward the iterator
        # to start_step (iterators constructed with start_step=0).
        for _ in range(start_step):
            next(data_iter)

    step_jit = None
    watch = WatchdogStats()
    history = []
    saver = ckpt_lib.AsyncSaver(ckpt_dir) if ckpt_dir else None
    for step in range(start_step, steps):
        batch = next(data_iter)
        if step_jit is None:
            spec = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
            step_jit = jit_for(spec)
        t0 = time.perf_counter()
        batch_axes = shd.batch_sharding(
            mesh, jax.tree.leaves(batch)[0].shape[0])
        with mesh, policy.activation_rules(batch_axes):
            params, opt_state, metrics = step_jit(params, opt_state, batch)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        dt = time.perf_counter() - t0
        if watch.record(dt):
            log_fn(f"[watchdog] slow step {step}: {dt:.3f}s "
                   f"(median {statistics.median(watch.times[-64:]):.3f}s)")
        history.append({"step": step, "time_s": dt, **metrics})
        if log_every and step % log_every == 0:
            log_fn(f"[fit] step {step} loss {metrics['loss']:.4f} "
                   f"gnorm {metrics['grad_norm']:.3f} {dt * 1e3:.0f}ms")
        if saver and ckpt_every and (step + 1) % ckpt_every == 0:
            saver.save(step + 1, params, opt_state)
    if saver:
        saver.save(steps, params, opt_state)
        saver.wait()
    return params, opt_state, history
