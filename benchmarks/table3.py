"""Paper Table 3 — cross-work hdiff throughput comparison.

Paper entries are hard-coded from Table 3; our row is the model-projected
TPU v5e hdiff throughput (single chip, auto-tuned tiles) plus the measured
CPU reference for scale."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import perfmodel, tiling
from repro.core.autotune import tune
from repro.kernels.hdiff import ref as href

TABLE3 = [
    ("NARMADA[129]/XCVU3P", 129.9),
    ("StencilFlow[43]/Stratix10", 145.0),
    ("NERO[ours-paper]/XCVU37P", 608.4),
]


def run():
    grid = (64, 256, 256)
    tuned = tune(tiling.HDIFF, grid, "float32")
    est = perfmodel.estimate(tuned.plan)
    emit("table3/nero_tpu_v5e_model", est.time_s * 1e6,
         f"gflops={est.gflops:.0f}")
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.normal(size=grid).astype(np.float32))
    t = time_fn(jax.jit(href.hdiff), src)
    gf = tiling.HDIFF.flops_per_point * src.size / (t * 1e-6) / 1e9
    emit("table3/this_cpu_jnp", t, f"gflops={gf:.1f}")
    for name, gflops in TABLE3:
        emit(f"table3/{name}", 0.0, f"gflops={gflops}")


if __name__ == "__main__":
    run()
