"""Pure-jnp oracle for the fused cross-entropy kernel.

Materializes the full (N, V) logits — the thing the kernel exists to
avoid — so it is the correctness reference only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def xent(hidden: jnp.ndarray, head: jnp.ndarray, targets: jnp.ndarray,
         valid: jnp.ndarray | None = None, vocab: int = 0,
         softcap: float = 0.0) -> jnp.ndarray:
    """Sum of next-token NLL.

    hidden: (N, D); head: (D, Vp); targets: (N,) int32 < vocab;
    valid: (N,) bool mask (None -> all valid); vocab: logical vocab size
    (masks physical padding columns of Vp).  Returns scalar f32 sum.
    """
    n, d = hidden.shape
    vp = head.shape[1]
    lg = (hidden.astype(jnp.float32) @ head.astype(jnp.float32))
    if softcap:
        lg = jnp.tanh(lg / softcap) * softcap
    if vocab and vocab < vp:
        lg = jnp.where(jnp.arange(vp) < vocab, lg, -1e30)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    nll = logz - gold
    if valid is not None:
        nll = nll * valid.astype(jnp.float32)
    return nll.sum()
