"""Moonshot-v1-16B-A3B (Moonlight) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    pattern=("attn",), rope_theta=5e4,
    norm="rms", gated_mlp=True, act="silu",
    moe=MoEConfig(n_experts=64, top_k=6),
    skip_shapes=(("long_500k", "pure full-attention arch"),),
)
