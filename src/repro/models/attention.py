"""GQA attention: dense, chunked-flash (memory-efficient), and decode paths.

The chunked-flash path is the NERO idea applied to sequence dimension: the
KV stream is tiled into VMEM-sized windows, consumed with an online-softmax
dataflow, never materializing the (T, S) score matrix in HBM.  It is pure
JAX (differentiable, GSPMD-shardable); the Pallas twin for the TPU serving
path lives in kernels/flash_attention.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel import policy

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: int):
    """(..., Tq, Tk) boolean validity mask from global positions."""
    m = jnp.ones(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]),
                 dtype=bool)
    d = qpos[..., :, None] - kpos[..., None, :]
    if causal:
        m &= d >= 0
    if window:
        m &= d < window
    return m


def dense_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, softcap: float = 0.0):
    """q: (B, T, H, hd); k, v: (B, S, K, hd).  Materializes scores — use for
    short T·S only (decode, smoke tests)."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    qs = (q * (hd ** -0.5)).reshape(b, t, kh, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qs.astype(jnp.float32),
                        k.astype(jnp.float32))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = q_offset + jnp.arange(t)
    kpos = jnp.arange(s)
    m = _mask(qpos, kpos, causal, window)
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)


def _divisor_chunk(t: int, chunk: int) -> int:
    """Largest chunk size <= `chunk` that divides t (whisper's encoder
    length 1500 is not a power-of-two multiple)."""
    c = min(chunk, t)
    while t % c:
        c -= 1
    return c


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    softcap: float = 0.0):
    """Two-level chunked online-softmax attention (no (T,S) materialization).

    Baseline computes every (q_chunk, kv_chunk) block with masking; the
    block-skip optimization for causal/windowed patterns is a §Perf item.
    """
    with jax.named_scope("flash_mha"):
        return _flash_attention(q, k, v, causal=causal, window=window,
                                q_chunk=q_chunk, kv_chunk=kv_chunk,
                                softcap=softcap)


def _flash_attention(q, k, v, *, causal, window, q_chunk, kv_chunk, softcap):
    """Body of flash_attention.  The named scope tags every op (incl. the
    q/kv scan bodies) in HLO metadata: kernels/flash_attention is the Pallas
    twin whose VMEM-resident blocks the roofline's kernelized variant
    credits via hlo_cost zero_byte_scopes — this pure-JAX form is what
    compiles on the CPU dry-run host and stays differentiable/shardable."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    q_chunk = _divisor_chunk(t, q_chunk)
    kv_chunk = _divisor_chunk(s, kv_chunk)
    nq, nk = t // q_chunk, s // kv_chunk

    qs = (q * (hd ** -0.5)).reshape(b, nq, q_chunk, kh, g, hd)
    ks = k.reshape(b, nk, kv_chunk, kh, hd)
    vs = v.reshape(b, nk, kv_chunk, kh, hd)
    # Pin batch + kv-head sharding so the scan accumulators (created fresh
    # inside the loop) don't end up replicated by sharding propagation.
    qs = policy.batch_model_at(qs, 3)
    ks = policy.batch_model_at(ks, 3)
    vs = policy.batch_model_at(vs, 3)

    def q_body(_, qi_blk):
        qi, q_blk = qi_blk                      # q_blk: (b, qc, kh, g, hd)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki_blk):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = ki_blk
            scores = jnp.einsum("bqkgh,bskh->bkgqs",
                                q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32))
            if softcap:
                scores = jnp.tanh(scores / softcap) * softcap
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(qpos, kpos, causal, window)
            scores = jnp.where(msk, scores, NEG_INF)
            m_new = jnp.maximum(m_run, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bqkgh", p, v_blk.astype(jnp.float32))
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = policy.batch_model_at(
            jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32), 1)
        l0 = policy.batch_model_at(
            jnp.zeros((b, kh, g, q_chunk), jnp.float32), 1)
        a0 = policy.batch_model_at(
            jnp.zeros((b, q_chunk, kh, g, hd), jnp.float32), 2)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), ks.swapaxes(0, 1), vs.swapaxes(0, 1)))
        l_t = l_f.transpose(0, 3, 1, 2)[..., None]
        out_blk = acc / jnp.maximum(l_t, 1e-37)
        return None, out_blk

    _, out = jax.lax.scan(q_body, None,
                          (jnp.arange(nq), qs.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(b, t, h, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     softcap: float = 0.0):
    """One-token attention over a cache.

    q: (B, 1, H, hd); caches (B, S, K, hd).  `pos` is the index of the token
    being generated (its K/V already written at `pos` — or `pos % S` for
    ring-buffer local caches).  Validity: written slots only.
    """
    b, _, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qs = (q * (hd ** -0.5)).reshape(b, kh, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qs.astype(jnp.float32),
                        k_cache.astype(jnp.float32))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    slot = jnp.arange(s)
    if window:
        # ring buffer of size s == window: slots written iff slot <= pos
        # (before wrap) or always (after wrap).
        valid = jnp.where(pos >= s, True, slot <= pos)
    else:
        valid = slot <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
