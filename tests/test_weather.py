"""Weather dycore: single-device correctness + distributed equivalence.

Everything goes through the plan API (`repro.weather.program.compile`) —
the legacy `dycore_step`/`run`/`make_distributed_step` shims are gone
(retired ROADMAP item)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.weather import dycore, fields
from repro.weather.program import DycoreProgram, compile_dycore


def _plan(grid, ensemble=1, variant="auto", k_steps=1, **kw):
    return compile_dycore(DycoreProgram(grid_shape=grid, ensemble=ensemble,
                                        variant=variant, k_steps=k_steps),
                          **kw)


def test_dycore_step_finite_and_shaped():
    st = fields.initial_state(jax.random.PRNGKey(0), (8, 16, 16),
                              ensemble=2)
    out = _plan((8, 16, 16), ensemble=2).step(st)
    for name in fields.PROGNOSTIC:
        f = np.asarray(out.fields[name])
        assert f.shape == (2, 8, 16, 16)
        assert np.isfinite(f).all()


def test_dycore_run_scan():
    st = fields.initial_state(jax.random.PRNGKey(1), (4, 8, 8))
    out = _plan((4, 8, 8)).run(st, 3)
    f = np.asarray(out.fields["t"])
    assert np.isfinite(f).all()


def _roughness(f):
    return float(jnp.abs(jnp.diff(f, axis=-1)).sum()
                 + jnp.abs(jnp.diff(f, axis=-2)).sum())


def test_diffusion_damps_checkerboard_and_conserves():
    """hdiff is 4th-order hyperdiffusion: it damps the 2Δx (checkerboard)
    mode hardest — amplification factor g = 1 - 64c at the spectrum peak —
    and, being in flux form on a periodic domain, conserves the mean.
    (It is NOT total-variation-diminishing: ∇⁴ overshoots at plateau
    edges, which is correct physics, so we don't assert on TV.)"""
    z, ny, nx = 4, 32, 32
    yy, xx = jnp.meshgrid(jnp.arange(ny), jnp.arange(nx), indexing="ij")
    checker = ((-1.0) ** (yy + xx)).astype(jnp.float32)
    base = jnp.sin(2 * jnp.pi * xx / nx).astype(jnp.float32)
    f0 = jnp.broadcast_to(base + 0.5 * checker, (z, ny, nx))
    f1 = dycore.hdiff_periodic(f0, coeff=0.02)
    amp0 = float(jnp.abs((f0 * checker).mean()))
    amp1 = float(jnp.abs((f1 * checker).mean()))
    assert amp1 < amp0 * 0.7, (amp0, amp1)
    assert abs(float(f1.mean() - f0.mean())) < 1e-5


def test_diffusion_unstable_above_cfl():
    """Above the stability bound the explicit step amplifies noise — the
    documented reason programs default to coeff=0.025."""
    st = fields.initial_state(jax.random.PRNGKey(2), (4, 32, 32))
    f0 = st.fields["t"]
    f = f0
    for _ in range(8):
        f = dycore.hdiff_periodic(f, coeff=0.12)
    assert _roughness(f) > _roughness(f0)


_DIST_SNIPPET = r"""
import jax, numpy as np
from repro.weather import fields, domain
from repro.weather.program import DycoreProgram, compile_dycore
key = jax.random.PRNGKey(0)
st = fields.initial_state(key, (6, 8, 8), ensemble=2)
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
outs = {}
for variant in ("whole_state", "per_field", "unfused"):
    # like-for-like: distributed vs single-device on the SAME path.  Even
    # so the graphs differ (pad/crop vs wrap, shard shapes), so a handful
    # of flux-limiter branch flips are legitimate (see
    # kernels/dycore_fused/ref.py::limiter_fragile_mask); tolerate <=2
    # flipped points per field under a loose physical bound.
    prog = DycoreProgram(grid_shape=(6, 8, 8), ensemble=2, variant=variant,
                         k_steps=1)
    ref = compile_dycore(prog).step(st)
    plan = compile_dycore(prog, mesh=mesh)
    out = plan.step(domain.shard_state(st, mesh, plan.state_spec))
    outs[variant] = out
    for name in fields.PROGNOSTIC:
        err = np.abs(np.asarray(ref.fields[name])
                     - np.asarray(out.fields[name]))
        bad = int((err > 1e-5).sum())
        assert bad <= 2 and err.max() < 0.05, (variant, name, bad, err.max())
        errs = np.abs(np.asarray(ref.stage_tens[name])
                      - np.asarray(out.stage_tens[name])).max()
        assert errs < 1e-5, (variant, name, errs)  # stage: no limiter upstream
# stacked exchange vs per-field exchange, head-to-head on the same shards
for name in fields.PROGNOSTIC:
    a = np.asarray(outs["whole_state"].fields[name])
    b = np.asarray(outs["per_field"].fields[name])
    bad = int((np.abs(a - b) > 1e-5).sum())
    assert bad <= 2 and np.abs(a - b).max() < 0.05, (name, bad)
    sa = np.asarray(outs["whole_state"].stage_tens[name])
    sb = np.asarray(outs["per_field"].stage_tens[name])
    assert np.abs(sa - sb).max() < 1e-5, name
print("DIST_OK")
"""


_KSTEP_SNIPPET = r"""
import jax, numpy as np
from repro.core import trace_stats
from repro.weather import fields, domain
from repro.weather.program import DycoreProgram, compile_dycore
K = 2
grid = (4, 8, 16)
st = fields.initial_state(jax.random.PRNGKey(1), grid, ensemble=2)
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
def plan_for(variant="auto", k=1, **kwargs):
    return compile_dycore(DycoreProgram(grid_shape=grid, ensemble=2,
                                        variant=variant, k_steps=k,
                                        **kwargs), mesh=mesh)
planK = plan_for("kstep", K)
plan1 = plan_for("whole_state", 1)

# structural win of the k-step round, asserted via trace_stats: exactly
# ONE pallas_call (the in-kernel k-step scan — not one launch per local
# step) and ONE ppermute pair per mesh direction (4 collectives) per round
j = jax.make_jaxpr(planK.step)(st)
trace_stats.assert_kstep_structure(j)
j1 = jax.make_jaxpr(plan1.step)(st)
assert trace_stats.count_primitive(j1, "ppermute") == 4
jpf = jax.make_jaxpr(plan_for("per_field").step)(st)
n_pf = trace_stats.count_primitive(jpf, "ppermute")
assert n_pf >= 4 * len(fields.PROGNOSTIC), n_pf   # per-field/per-input cost

# K-step deep halo == K sequential exchanged steps (tolerance: fp32 round)
sst = domain.shard_state(st, mesh, planK.state_spec)
outK = planK.step(sst)
seq = sst
for _ in range(K):
    seq = plan1.step(seq)
for name in fields.PROGNOSTIC:
    err = np.abs(np.asarray(outK.fields[name])
                 - np.asarray(seq.fields[name]))
    bad = int((err > 1e-5).sum())
    assert bad <= 2 and err.max() < 0.05, (name, bad, err.max())
    errs = np.abs(np.asarray(outK.stage_tens[name])
                  - np.asarray(seq.stage_tens[name])).max()
    assert errs < 1e-5, (name, errs)

# the deep halo cannot exceed the local slab: loud error at COMPILE time
try:
    plan_for("kstep", 3)
except ValueError as e:
    assert "halo" in str(e), e
else:
    raise AssertionError("k_steps=3 on a 4-row slab should refuse")

# bf16 stacked exchange: same 4-collective structure, results within bf16
# halo rounding of the fp32-wire round
planB = plan_for("kstep", K, exchange_dtype="bfloat16")
trace_stats.assert_kstep_structure(jax.make_jaxpr(planB.step)(st))
outB = planB.step(sst)
for name in fields.PROGNOSTIC:
    err = np.abs(np.asarray(outB.fields[name])
                 - np.asarray(outK.fields[name]))
    assert np.isfinite(np.asarray(outB.fields[name])).all(), name
    assert err.max() < 0.1, (name, err.max())   # halo-ring bf16 rounding
    assert err.max() > 0.0, name                # the cast actually happened

# k_steps="auto": resolved by the planner at compile time
planA = plan_for("auto", "auto")
outA = planA.step(domain.shard_state(st, mesh, planA.state_spec))
kA = planA.k_steps
assert isinstance(kA, int) and kA >= 1, kA
ref = sst
for _ in range(kA):
    ref = plan1.step(ref)
for name in fields.PROGNOSTIC:
    err = np.abs(np.asarray(outA.fields[name])
                 - np.asarray(ref.fields[name]))
    bad = int((err > 1e-5).sum())
    assert bad <= 2 and err.max() < 0.05, (name, kA, bad, err.max())
print("KSTEP_OK")
"""


def _run_forced_device_snippet(snippet: str, marker: str):
    """Run `snippet` in a subprocess with 4 forced host CPU devices."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, r.stderr[-2000:]


def test_distributed_matches_single_device():
    """Halo-exchange domain decomposition == single-device periodic step on
    all three local-compute paths, and stacked-exchange == per-field
    exchange head-to-head (subprocess with 4 forced host devices)."""
    _run_forced_device_snippet(_DIST_SNIPPET, "DIST_OK")


def test_kstep_communication_avoiding():
    """K-step deep-halo mode: one ppermute pair per direction per K steps,
    ONE pallas_call per round, equivalent to K sequential exchanged
    steps, and a loud error when the halo outgrows the local slab."""
    _run_forced_device_snippet(_KSTEP_SNIPPET, "KSTEP_OK")


def test_run_whole_state_matches_per_field():
    """Whole-state and per-field plans agree over multi-step trajectories."""
    st = fields.initial_state(jax.random.PRNGKey(5), (4, 8, 8))
    out_w = _plan((4, 8, 8), variant="whole_state").run(st, 3)
    out_p = _plan((4, 8, 8), variant="per_field").run(st, 3)
    for name in fields.PROGNOSTIC:
        err = np.abs(np.asarray(out_w.fields[name])
                     - np.asarray(out_p.fields[name]))
        bad = int((err > 1e-5).sum())
        assert bad <= 2 and err.max() < 0.05, (name, bad, err.max())


def test_run_kstep_matches_sequential():
    """Single-chip k-step mode: plan.run(steps) on a k-step plan — steps/k
    rounds of ONE in-kernel-scan launch each — matches the step-by-step
    trajectory to fp32 rounding (limiter-fragile flips tolerated)."""
    grid = (4, 12, 16)
    st = fields.initial_state(jax.random.PRNGKey(6), grid, ensemble=2)
    out_seq = _plan(grid, ensemble=2).run(st, 4)
    out_k = _plan(grid, ensemble=2, variant="kstep", k_steps=2).run(st, 4)
    for name in fields.PROGNOSTIC:
        err = np.abs(np.asarray(out_k.fields[name])
                     - np.asarray(out_seq.fields[name]))
        bad = int((err > 1e-5).sum())
        assert bad <= 4 and err.max() < 0.05, (name, bad, err.max())
    with pytest.raises(ValueError):
        # k_steps > 1 is the k-step strategy; a one-step variant refuses
        DycoreProgram(grid_shape=grid, variant="per_field", k_steps=2)


def test_run_kstep_ragged_tail():
    """steps % k_steps != 0 is not an error: the plan runs the full k-step
    rounds and finishes with one shorter TAIL round at k' = steps mod k
    (ISSUE 4 satellite) — equivalent to sequential stepping within the
    usual limiter-fragile tolerance."""
    grid = (4, 12, 16)
    st = fields.initial_state(jax.random.PRNGKey(7), grid, ensemble=2)
    out_seq = _plan(grid, ensemble=2).run(st, 5)     # 5 sequential steps
    out_k = _plan(grid, ensemble=2, variant="kstep",
                  k_steps=2).run(st, 5)              # 2 rounds + k'=1 tail
    out_k3 = _plan(grid, ensemble=2, variant="kstep",
                   k_steps=3).run(st, 5)             # 1 round + k'=2 tail
    for out in (out_k, out_k3):
        for name in fields.PROGNOSTIC:
            err = np.abs(np.asarray(out.fields[name])
                         - np.asarray(out_seq.fields[name]))
            bad = int((err > 1e-5).sum())
            assert bad <= 4 and err.max() < 0.05, (name, bad, err.max())
