"""Forecast-as-a-service engine: batching invariance, plan cache, restarts.

The core correctness contract of admission batching (ISSUE 6): every
request served through `ForecastEngine` — batched into the ensemble axis
of a shared plan, retired raggedly at round boundaries, backfilled from
the queue — is BIT-IDENTICAL to the same request run solo through
`compile(program).run()`.  The property harness below drives that over
random mixes of grids / ops / step counts / precisions; it uses
`hypothesis` when the dev extra is installed and a seeded deterministic
sweep of the same property otherwise (so the module never skips).
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.serve.forecast import (ForecastEngine, ForecastRequest,
                                  ForecastResult)
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import fields
from repro.weather import program as wprog
from repro.weather.program import StencilProgram, plan_cache_key

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

# Small grids keep interpret-mode Pallas fast; two shapes + two dtypes +
# three ops + a pinned-k program span the scenario axes.
_GRIDS = ((3, 8, 8), (4, 12, 16))
_OPS = ("dycore", "hdiff", "vadvc")
_DTYPES = ("float32", "bfloat16")

_SOLO_PLANS = {}


def _solo_plan(prog):
    plan = _SOLO_PLANS.get(prog)
    if plan is None:
        plan = _SOLO_PLANS.setdefault(prog, wprog.compile(prog))
    return plan


def _mk_request(seed, grid_i, op_i, dtype_i, steps, pinned_k=False):
    grid = _GRIDS[grid_i % len(_GRIDS)]
    op = _OPS[op_i % len(_OPS)]
    dtype = _DTYPES[dtype_i % len(_DTYPES)]
    kw = {}
    if pinned_k and op == "dycore":
        kw = {"variant": "kstep", "k_steps": 2}
    prog = StencilProgram(grid_shape=grid, ensemble=1, op=op, dtype=dtype,
                          **kw)
    state = fields.initial_state(jax.random.PRNGKey(seed), grid,
                                 ensemble=1, dtype=dtype)
    return ForecastRequest(program=prog, state=state, steps=steps)


def _assert_bit_identical(result: ForecastResult, request_state):
    """result == compile(program).run(state, steps), every field, bitwise."""
    want = _solo_plan(result.program).run(request_state, result.steps)
    for name in result.program.fields:
        np.testing.assert_array_equal(
            np.asarray(result.state.fields[name]),
            np.asarray(want.fields[name]),
            err_msg=f"fields[{name}] steps={result.steps} "
                    f"op={result.program.op}")
        np.testing.assert_array_equal(
            np.asarray(result.state.stage_tens[name]),
            np.asarray(want.stage_tens[name]),
            err_msg=f"stage_tens[{name}] steps={result.steps} "
                    f"op={result.program.op}")


# One engine for the whole property run: its plan cache persists across
# examples exactly like a long-lived service's would.
_ENGINE = ForecastEngine(slots=2)


def _check_mix(mix):
    """Serve `mix` (list of request descriptors) and compare every result
    to its solo run, bitwise."""
    reqs = []
    for seed, (grid_i, op_i, dtype_i, steps, pinned) in enumerate(mix):
        req = _mk_request(seed, grid_i, op_i, dtype_i, steps, pinned)
        state = req.state        # keep a handle: engine may donate/stage
        rid = _ENGINE.submit(req)
        reqs.append((rid, state))
    results = _ENGINE.drain()
    for rid, state in reqs:
        _assert_bit_identical(results[rid], state)


_CASE = st.tuples(st.integers(0, 1), st.integers(0, 2), st.integers(0, 1),
                  st.integers(0, 4),
                  st.booleans()) if HAVE_HYPOTHESIS else None

if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(_CASE, min_size=2, max_size=4))
    def test_batching_invariance_property(mix):
        _check_mix(mix)
else:
    def test_batching_invariance_property():
        """Seeded fallback: the same property over deterministic random
        mixes (hypothesis drives this when the dev extra is present)."""
        rng = np.random.default_rng(0)
        for _ in range(3):
            n = int(rng.integers(2, 5))
            mix = [(int(rng.integers(0, 2)), int(rng.integers(0, 3)),
                    int(rng.integers(0, 2)), int(rng.integers(0, 5)),
                    bool(rng.integers(0, 2))) for _ in range(n)]
            _check_mix(mix)


def test_ragged_pinned_k_rollback_bit_identical():
    """Mixed step counts on a pinned k_steps=2 program force the rollback
    scheduler (slots whose next canonical part is deeper than the round
    sit it out uncredited) — results must still be solo-bit-identical and
    the engine must report the rollbacks it performed."""
    grid = (3, 8, 8)
    prog = StencilProgram(grid_shape=grid, ensemble=1, variant="kstep",
                          k_steps=2)
    eng = ForecastEngine(slots=3)
    reqs = []
    for i, steps in enumerate([7, 10, 3, 4, 1]):
        st_ = fields.initial_state(jax.random.PRNGKey(10 + i), grid,
                                   ensemble=1)
        rid = eng.submit(ForecastRequest(program=prog, state=st_,
                                         steps=steps))
        reqs.append((rid, st_))
    results = eng.drain()
    for rid, st_ in reqs:
        _assert_bit_identical(results[rid], st_)
    assert eng.stats()["rolled_back_slot_rounds"] > 0


def test_request_validation_and_zero_steps():
    grid = (3, 8, 8)
    st_ = fields.initial_state(jax.random.PRNGKey(0), grid, ensemble=1)
    prog = StencilProgram(grid_shape=grid, ensemble=1)
    with pytest.raises(ValueError, match="ensemble"):
        ForecastRequest(program=StencilProgram(grid_shape=grid, ensemble=2),
                        state=st_, steps=1).validate()
    with pytest.raises(ValueError, match="steps"):
        ForecastRequest(program=prog, state=st_, steps=-1).validate()
    with pytest.raises(ValueError, match="dtype"):
        ForecastRequest(program=StencilProgram(grid_shape=grid,
                                               dtype="bfloat16"),
                        state=st_, steps=1).validate()
    with pytest.raises(ValueError, match="grid"):
        ForecastRequest(program=StencilProgram(grid_shape=(4, 12, 16)),
                        state=st_, steps=1).validate()
    # steps == 0 finishes immediately (no slot) and returns the input
    eng = ForecastEngine(slots=1)
    rid = eng.submit(ForecastRequest(program=prog, state=st_, steps=0))
    res = eng.drain()[rid]
    assert res.rounds == 0
    for name in prog.fields:
        np.testing.assert_array_equal(np.asarray(res.state.fields[name]),
                                      np.asarray(st_.fields[name]))


def test_plan_cache_exactly_m_compiles(monkeypatch):
    """N requests over M distinct programs compile exactly M plans (the
    compile-once-serve-forever contract), observed by a spy on
    `repro.weather.program.compile`, and the engine's own cache counters
    agree: M misses, N-M hits."""
    calls = []
    real_compile = wprog.compile

    def spy(program, *a, **kw):
        calls.append(program)
        return real_compile(program, *a, **kw)

    monkeypatch.setattr(wprog, "compile", spy)
    progs = [StencilProgram(grid_shape=(3, 8, 8), ensemble=1),
             StencilProgram(grid_shape=(3, 8, 8), ensemble=1, op="hdiff")]
    eng = ForecastEngine(slots=2)
    reqs = []
    for i in range(6):
        prog = progs[i % 2]
        st_ = fields.initial_state(jax.random.PRNGKey(20 + i),
                                   prog.grid_shape, ensemble=1)
        rid = eng.submit(ForecastRequest(program=prog, state=st_,
                                         steps=1 + i % 3))
        reqs.append((rid, st_))
    results = eng.drain()
    assert sorted(results) == sorted(r for r, _ in reqs)
    assert len(calls) == 2, [p.op for p in calls]
    assert {p.ensemble for p in calls} == {eng.slots}
    s = eng.stats()
    assert s["plan_cache_misses"] == 2 and s["plan_cache_hits"] == 4
    assert s["plan_cache_hit_rate"] == pytest.approx(4 / 6)
    # the cache key canonicalizes the request program onto the slot count
    assert plan_cache_key(progs[0], ensemble=2) in eng._plans


def test_per_request_latency_accounting():
    """Each result carries ITS OWN admit->finish latency (the seed
    `ServeEngine` bug gave every request the whole-wave wall time): a
    request that queues behind a full engine records a strictly larger
    queue wait, and a longer forecast a larger latency than a short one
    admitted together."""
    grid = (3, 8, 8)
    prog = StencilProgram(grid_shape=grid, ensemble=1)
    eng = ForecastEngine(slots=2)
    rids = []
    for i, steps in enumerate([1, 6, 4]):
        st_ = fields.initial_state(jax.random.PRNGKey(30 + i), grid,
                                   ensemble=1)
        rids.append(eng.submit(ForecastRequest(program=prog, state=st_,
                                               steps=steps)))
    res = eng.drain()
    short, long_, queued = (res[r] for r in rids)
    assert short.latency_s > 0 and long_.latency_s > 0
    # same admission wave: the 6-step forecast finishes after the 1-step
    assert long_.latency_s > short.latency_s
    assert long_.rounds == 6 and short.rounds == 1
    # the third request waited for a slot: strictly positive queue wait
    assert queued.queue_wait_s > short.queue_wait_s
    occ = eng.stats()["occupancy"]
    assert 0 < occ <= 1


# ---------------------------------------------------------------------------
# Subprocess variants: forced-4-device batching + fresh-process restart
# ---------------------------------------------------------------------------

_WORKLOAD_SNIPPET = r"""
import jax, numpy as np
from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.weather import fields
from repro.weather.program import StencilProgram, compile as pcompile

def workload():
    progs = [StencilProgram(grid_shape=(3, 8, 8), ensemble=1),
             StencilProgram(grid_shape=(3, 8, 8), ensemble=1, op="hdiff"),
             StencilProgram(grid_shape=(4, 12, 16), ensemble=1,
                            dtype="bfloat16")]
    reqs = []
    for i, steps in enumerate([3, 5, 2, 4, 1]):
        prog = progs[i % 3]
        st = fields.initial_state(jax.random.PRNGKey(100 + i),
                                  prog.grid_shape, ensemble=1,
                                  dtype=prog.dtype)
        reqs.append(ForecastRequest(program=prog, state=st, steps=steps,
                                    rid=i))
    return reqs

def save_results(path, results):
    arrays = {}
    for rid, r in results.items():
        for name in r.program.fields:
            arrays[f"{rid}/{name}"] = np.asarray(r.state.fields[name],
                                                 np.float32)
    np.savez(path, **arrays)
"""

_DIST_SERVE_SNIPPET = _WORKLOAD_SNIPPET + r"""
from repro.weather import domain
from repro.weather.program import plan_cache_key
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
grid = (4, 16, 16)
prog = StencilProgram(grid_shape=grid, ensemble=1)
eng = ForecastEngine(slots=2, mesh=mesh)
reqs = []
for i, steps in enumerate([3, 2, 4]):
    st = fields.initial_state(jax.random.PRNGKey(i), grid, ensemble=1)
    rid = eng.submit(ForecastRequest(program=prog, state=st, steps=steps))
    reqs.append((rid, st, steps))
res = eng.drain()

# Batch-folding requests into the ensemble axis must NOT change the
# round's structure: same collectives, same single launch as solo.
solo = pcompile(prog, mesh=mesh)
batched = eng._plans[plan_cache_key(prog, ensemble=2)]
srep, brep = solo.report(), batched.report()
assert brep["collectives_per_round"] == srep["collectives_per_round"] == 4
assert brep["pallas_calls_per_round"] == srep["pallas_calls_per_round"] == 1

# ... and every batched result is bit-identical to its solo run.
for rid, st, steps in reqs:
    sst = domain.shard_state(st, mesh, solo.state_spec)
    want = solo.run(sst, steps)
    got = res[rid].state
    for name in prog.fields:
        assert np.array_equal(np.asarray(got.fields[name]),
                              np.asarray(want.fields[name])), (rid, name)
print("SERVE_DIST_OK")
"""

_CKPT_PHASE_A = _WORKLOAD_SNIPPET + r"""
import os
eng = ForecastEngine(slots=2, ckpt_dir=os.environ["FORECAST_CKPT"])
for r in workload():
    eng.submit(r)
eng.pump()
eng.pump()
eng.checkpoint()
assert eng.has_work(), "checkpoint must land mid-queue, not after drain"
print("SERVE_CKPT_A_OK")
"""

_CKPT_PHASE_B = _WORKLOAD_SNIPPET + r"""
import os
eng = ForecastEngine.restore(os.environ["FORECAST_CKPT"])
assert eng.has_work()
results = eng.drain()
assert sorted(results) == [0, 1, 2, 3, 4]
save_results(os.path.join(os.environ["FORECAST_CKPT"], "restored.npz"),
             results)
print("SERVE_CKPT_B_OK")
"""


def _run_snippet(snippet, marker, extra_env=None):
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu"}
    env.update(extra_env or {})
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert marker in r.stdout, r.stderr[-2000:]


def test_batched_serving_keeps_plan_structure_forced_4dev():
    """Forced-4-device subprocess: admission batching into the ensemble
    axis leaves `collectives_per_round` (and the single launch) unchanged
    vs the solo plan, and distributed batched results stay bit-identical
    to solo distributed runs."""
    _run_snippet(
        _DIST_SERVE_SNIPPET, "SERVE_DIST_OK",
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})


def test_checkpoint_restart_matches_uninterrupted(tmp_path):
    """Crash/restart equivalence: checkpoint the engine mid-queue, restart
    in a FRESH process, drain — the results must be bit-identical to an
    uninterrupted run of the same workload."""
    ckpt_dir = str(tmp_path / "engine_ckpt")
    env = {"FORECAST_CKPT": ckpt_dir}
    _run_snippet(_CKPT_PHASE_A, "SERVE_CKPT_A_OK", env)
    _run_snippet(_CKPT_PHASE_B, "SERVE_CKPT_B_OK", env)

    # Uninterrupted reference, in-process (deterministic same workload).
    ns = {}
    exec(compile(_WORKLOAD_SNIPPET, "<workload>", "exec"), ns)
    eng = ForecastEngine(slots=2)
    for r in ns["workload"]():
        eng.submit(r)
    want = eng.drain()
    got = np.load(os.path.join(ckpt_dir, "restored.npz"))
    for rid, res in want.items():
        for name in res.program.fields:
            np.testing.assert_array_equal(
                got[f"{rid}/{name}"],
                np.asarray(res.state.fields[name], np.float32),
                err_msg=f"rid={rid} field={name}")


# ---------------------------------------------------------------------------
# Supervision (ISSUE 7): poisoned-slot isolation, crash/restore sweep,
# and the combined acceptance scenario
# ---------------------------------------------------------------------------


def _check_poison_isolation(mix, poison_round, seed):
    """Serve `mix` with a NaN poison injected at `poison_round` into a
    seeded-random busy slot: AT MOST the poisoned request fails (with a
    validity-guard diagnosis) and every other result is bitwise equal to
    its solo run — the quarantine never perturbs a healthy slot."""
    inj = FaultInjector([FaultSpec(kind="poison_nan", round=poison_round)],
                        seed=seed)
    eng = ForecastEngine(slots=2, fault_injector=inj)
    reqs = []
    for s, (grid_i, op_i, dtype_i, steps, pinned) in enumerate(mix):
        req = _mk_request(200 + 17 * seed + s, grid_i, op_i, dtype_i,
                          steps, pinned)
        state = req.state
        rid = eng.submit(req)
        reqs.append((rid, state))
    results = eng.drain()
    failed = [r for r in results.values() if r.status == "failed"]
    assert len(failed) == inj.fired("poison_nan") <= 1
    assert eng.stats()["quarantined"] == len(failed)
    for r in failed:
        assert r.diagnosis["reason"] == "validity_guard"
        assert r.diagnosis["bad_leaves"]
    for rid, state in reqs:
        if results[rid].status == "ok":
            _assert_bit_identical(results[rid], state)


_POISON_CASE = st.tuples(
    st.integers(0, 1), st.integers(0, 2), st.integers(0, 1),
    st.integers(1, 4), st.booleans()) if HAVE_HYPOTHESIS else None

if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(st.lists(_POISON_CASE, min_size=2, max_size=4),
           st.integers(0, 1), st.integers(0, 5))
    def test_poisoned_slot_isolation_property(mix, poison_round, seed):
        _check_poison_isolation(mix, poison_round, seed)
else:
    def test_poisoned_slot_isolation_property():
        """Seeded fallback: same property over deterministic mixes."""
        rng = np.random.default_rng(7)
        for case in range(3):
            n = int(rng.integers(2, 5))
            mix = [(int(rng.integers(0, 2)), int(rng.integers(0, 3)),
                    int(rng.integers(0, 2)), int(rng.integers(1, 5)),
                    bool(rng.integers(0, 2))) for _ in range(n)]
            _check_poison_isolation(mix, int(rng.integers(0, 2)), case)


def test_crash_restore_at_every_round_boundary(tmp_path):
    """Kill-at-every-round-boundary sweep: run a workload under the
    watchdog (`ckpt_every_rounds=1`, keep everything), then for EVERY
    saved checkpoint simulate a crash there — restore, drain — and assert
    the full result set is bit-identical to the uninterrupted run."""
    grid = (3, 8, 8)
    prog = StencilProgram(grid_shape=grid, ensemble=1)

    def submit_all(eng):
        rids = []
        for i, steps in enumerate([3, 1, 2, 4]):
            st_ = fields.initial_state(jax.random.PRNGKey(60 + i), grid,
                                       ensemble=1)
            rids.append(eng.submit(ForecastRequest(program=prog, state=st_,
                                                   steps=steps)))
        return rids

    ref_eng = ForecastEngine(slots=2)
    rids = submit_all(ref_eng)
    want = ref_eng.drain()

    d = str(tmp_path)
    wd_eng = ForecastEngine(slots=2, ckpt_dir=d, ckpt_every_rounds=1,
                            ckpt_keep=0)
    assert submit_all(wd_eng) == rids
    wd_eng.drain()
    saved = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_"))
    assert len(saved) == wd_eng.stats()["watchdog_checkpoints"] >= 3

    for step in saved:
        eng = ForecastEngine.restore(d, step)
        # the resumed engine inherits the watchdog config; mute it so the
        # sweep's remaining checkpoints aren't overwritten/GC'd mid-sweep
        eng.ckpt_every_rounds = None
        res = eng.drain()
        assert sorted(res) == sorted(rids), f"crash at checkpoint {step}"
        for rid in rids:
            for name in prog.fields:
                np.testing.assert_array_equal(
                    np.asarray(res[rid].state.fields[name]),
                    np.asarray(want[rid].state.fields[name]),
                    err_msg=f"crash at checkpoint {step}, rid={rid}, "
                            f"field={name}")


def test_supervised_acceptance_combo(tmp_path):
    """The ISSUE 7 acceptance scenario in one run: a poisoned request, an
    injected mid-round device loss, a forced lowering fallback, AND a hard
    crash resumed from the watchdog's checkpoint — every healthy request
    bit-identical to its solo run, the poisoned request `failed` with a
    diagnosis, and the engine drains the full queue without intervention."""
    grid = (3, 8, 8)
    prog = StencilProgram(grid_shape=grid, ensemble=1)
    inj = FaultInjector([
        FaultSpec(kind="compile_fail", op="dycore", attempt="native"),
        FaultSpec(kind="poison_nan", round=1),
        FaultSpec(kind="device_loss", round=2),
    ], seed=3)
    eng = ForecastEngine(slots=2, ckpt_dir=str(tmp_path),
                         ckpt_every_rounds=1, ckpt_keep=0,
                         retry_backoff_s=0.0, fault_injector=inj)
    sts = [fields.initial_state(jax.random.PRNGKey(300 + i), grid,
                                ensemble=1) for i in range(4)]
    rids = [eng.submit(ForecastRequest(program=prog, state=s, steps=5))
            for s in sts]
    while eng.stats()["rounds"] < 3 and eng.has_work():
        eng.pump()
    assert inj.fired() == 3, inj.log   # all three faults hit pre-crash

    # Hard crash: abandon the warm engine, resume from the watchdog's
    # last auto-checkpoint in a fresh one (no injector — faults are over).
    eng2 = ForecastEngine.restore(str(tmp_path))
    res = eng2.drain()
    assert not eng2.has_work()
    assert sorted(res) == sorted(rids)

    failed = [rid for rid in rids if res[rid].status == "failed"]
    assert len(failed) == 1
    diag = res[failed[0]].diagnosis
    assert diag["reason"] == "validity_guard" and diag["bad_leaves"]
    for rid, s in zip(rids, sts):
        if rid != failed[0]:
            assert res[rid].status == "ok"
            _assert_bit_identical(res[rid], s)
    st2 = eng2.stats()
    assert st2["quarantined"] == 1
    assert st2["fallback_compiles"] >= 1
    assert st2["round_retries"] >= 1
    assert st2["watchdog_checkpoints"] >= 3


def test_checkpoint_restore_in_process(tmp_path):
    """Same-process restore: the cheap API-level path (no subprocess) —
    queue, in-flight slots, finished results and counters all survive."""
    grid = (3, 8, 8)
    prog = StencilProgram(grid_shape=grid, ensemble=1)
    eng = ForecastEngine(slots=1, ckpt_dir=str(tmp_path))
    sts = [fields.initial_state(jax.random.PRNGKey(40 + i), grid,
                                ensemble=1) for i in range(3)]
    rids = [eng.submit(ForecastRequest(program=prog, state=st_, steps=2))
            for st_ in sts]
    eng.pump()                                   # rid0 in flight, rest queued
    step = eng.checkpoint()
    eng2 = ForecastEngine.restore(str(tmp_path), step)
    assert eng2.slots == 1 and eng2.has_work()
    res = eng2.drain()
    assert sorted(res) == sorted(rids)
    for rid, st_ in zip(rids, sts):
        _assert_bit_identical(res[rid], st_)
