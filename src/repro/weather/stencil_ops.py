"""The StencilOp registry: declared operators the planner compiles.

NERO evaluates its two compound kernels SEPARATELY — vadvc (5.3x, 1.61
GFLOPS/W) and hdiff (12.7x, 21.01 GFLOPS/W) — and the per-kernel contrast
(hdiff's star footprint vs vadvc's tridiagonal z-sweep) is the paper's core
result.  The PR-4 plan API was hardwired to the single fused vadvc+hdiff
dycore; this module turns it into a platform: each operator is a
`StencilOpDef` declaring

* which state operands it streams (`reads`/`writes`),
* its per-operand, PER-SIDE halo footprint (`OperandRide`: `(lo, hi)`
  depths in y and x per local step, plus k-independent fixed columns like
  wcon's right-only staggering `+1`),
* its stencil reach (`halo`, the per-step validity shrink), flop count,
  supported execution variants, and tile search spaces (names in the
  `core/autotune` registry),
* its lowerings: tile resolution, the single-chip step, and the
  shard-local compute the distributed round wraps.

`weather/program.py::compile` consumes ONLY this declaration: the exchange
schedule, collective/launch counts, traffic and k-step models are all
derived from the footprint — no op-specific branches in the planner.
Registered out of the box:

  "dycore"       — the fused compound step (vadvc + point-wise + hdiff),
                   with the in-kernel k-step round;
  "hdiff"        — compound horizontal diffusion alone (fields only,
                   (2,2)/(2,2) footprint; the k-step round is ONE
                   `hdiff_kstep_pallas` launch on a k·2-deep halo);
  "vadvc"        — vertical advection alone (updates the stage tendencies;
                   the only exchanged operand is wcon's RIGHT staggering
                   column, a `(0, 1)` x-ride that lowers to ONE ppermute);
  "vadvc_update" — the paper's ablation composition: vadvc fused with the
                   point-wise leapfrog update (writes fields AND
                   stage_tens; no hdiff);
  "hadv_upwind"  — first-order upwind horizontal advection; its donor-cell
                   stencil reaches BACKWARD only, so its rides are
                   asymmetric ((1,0) in y and x);
  "asselin"      — point-wise leapfrog time filter from the stored
                   tendencies: zero rides, zero collectives (exercises the
                   empty-direction elision path end to end).

`register_stencil_op` admits new operators without touching the planner.
Ops that additionally provide `apply_stage` can ride inside a
`weather/pipeline.py::PipelineProgram`: the hook returns the op's
FULL-SLAB stage function (no exchange, no crop — the pipeline planner owns
both), which is how a chain keeps intermediates resident between stages.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune, memmodel, tiling
from repro.kernels.dycore_fused import ops as fused_ops
from repro.kernels.dycore_fused.fused import (fused_dycore_kstep_pallas,
                                              fused_dycore_pallas,
                                              fused_dycore_whole_state_pallas)
from repro.kernels.hadv import ops as hadv_ops
from repro.kernels.hadv import ref as hadv_ref
from repro.kernels.hadv.hadv import hadv_pallas
from repro.kernels.hdiff import ops as hdiff_ops
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.hdiff.hdiff import hdiff_kstep_pallas, hdiff_pallas
from repro.kernels.vadvc import ops as vadvc_ops
from repro.kernels.vadvc import ref as vadvc_ref
from repro.kernels.vadvc.vadvc import vadvc_pallas
from repro.weather import domain as _domain
from repro.weather import dycore as _dycore
from repro.weather.dycore import HALO
from repro.weather.fields import WeatherState

VARIANTS = ("auto", "unfused", "per_field", "whole_state", "kstep")


@dataclasses.dataclass(frozen=True)
class OperandRide:
    """One operand's declared halo footprint on the packed exchange wire.

    Per mesh direction the resolved per-side depth at steps-per-round k is
    `k * base + fixed`: `y`/`x` are the `(lo, hi)` PER-STEP reaches that
    deepen with the communication-avoiding k, `y_fixed`/`x_fixed` the
    k-independent extra rows/columns (e.g. wcon's right-only staggering
    column `x_fixed=(0, 1)`).  `per_field` operands ride once per program
    field; others (wcon) once per state."""

    operand: str
    y: Tuple[int, int] = (0, 0)
    x: Tuple[int, int] = (0, 0)
    y_fixed: Tuple[int, int] = (0, 0)
    x_fixed: Tuple[int, int] = (0, 0)
    per_field: bool = False

    def depths(self, k: int):
        """Resolved ((y_lo, y_hi), (x_lo, x_hi)) at steps-per-round `k`."""
        return ((k * self.y[0] + self.y_fixed[0],
                 k * self.y[1] + self.y_fixed[1]),
                (k * self.x[0] + self.x_fixed[0],
                 k * self.x[1] + self.x_fixed[1]))

    def describe(self, k: int) -> Dict[str, Any]:
        dy, dx = self.depths(k)
        return {"operand": self.operand, "per_field": self.per_field,
                "depth_y": list(dy), "depth_x": list(dx)}


@dataclasses.dataclass(frozen=True)
class StencilOpDef:
    """A registered stencil operator: footprint declaration + lowerings.

    The declaration part (`reads`/`writes`/`halo`/`flops_per_point`/
    `rides`/`variants`/`tile_spaces`) is what the planner and the models
    consume; the callables are the op's lowerings:

    * `resolve_tile(variant, compute_grid, dtype, n_fields, ensemble, k)`
      -> Optional[tiling.TilePlan] (None for the oracle variants);
    * `build_shard_local(plan)` -> `(fields, wcon, tens, stage) ->
      (new_fields, new_stage)`, the chip-local round the distributed step
      shard_maps (and, for ops with `pads_single_chip`, the single-chip
      step too — the packed exchange degenerates to wrap padding);
    * `build_local_step(plan)` -> jitted `state -> state`, or None to
      derive it from `build_shard_local` on a 1x1 "mesh";
    * `collectives(variant, n_fields, py, px, k)` -> ppermutes per round,
      or None to derive generically from the rides (a collective per mesh
      direction and side anything rides);
    * `traffic(plan)` / `exchange_model(plan)` -> the report()'s modeled
      HBM / wire-byte blocks;
    * `apply_stage(prog, names, interpret, use_ref)` -> the op's FULL-SLAB
      stage function `(fields, wconp, tens, stage_tens) -> (new_fields,
      new_stage_tens)` for pipeline chaining (`weather/pipeline.py`): all
      dict values are padded slabs, `names` the stage's bound fields, and
      the op must neither exchange nor crop — the pipeline planner owns
      the fused exchange and the final interior crop.  None => the op
      cannot ride in a pipeline;
    * `kstep_vmem_check(program, shards)` -> per-k legality callable for
      `autotune.resolve_k_steps` — ops with their OWN in-kernel k-step
      round (not the fused dycore's) declare how a candidate k's working
      slab is checked.
    """

    name: str
    title: str
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    halo: int                                # per-step stencil reach (y, x)
    flops_per_point: float                   # per field per step
    rides: Tuple[OperandRide, ...]
    variants: Tuple[str, ...]
    tile_spaces: Tuple[Tuple[str, str], ...]  # (variant, autotune op name)
    inkernel_kstep: bool = False             # k-step round is ONE launch
    pads_single_chip: bool = False           # single chip wrap-pads + crops
    packed_variants: Tuple[str, ...] = ()    # variants on the packed wire
    resolve_tile: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    build_shard_local: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    build_local_step: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    pallas_calls: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    collectives: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    traffic: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    exchange_model: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    apply_stage: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)
    kstep_vmem_check: Optional[Callable] = dataclasses.field(
        default=None, compare=False, repr=False)

    # -- footprint-derived accounting ---------------------------------------
    def resolved_rides(self, k: int):
        """((operand, (y_lo, y_hi), (x_lo, x_hi)), ...) at depth k."""
        return tuple((r.operand,) + r.depths(k) for r in self.rides)

    def memmodel_rides(self, n_fields: int):
        """The rides in `memmodel.packed_exchange_model` form."""
        return tuple((r.operand, n_fields if r.per_field else 1,
                      r.y, r.x, r.y_fixed, r.x_fixed) for r in self.rides)

    def generic_collectives(self, py: int, px: int, k: int) -> int:
        """Collectives per packed round, derived from the footprint: one
        ppermute per mesh direction and SIDE any operand rides (a side
        nothing rides is elided by `domain._exchange_packed`)."""
        total = 0
        for axis, n in (("y", py), ("x", px)):
            if n <= 1:
                continue
            lo = hi = False
            for r in self.rides:
                dy, dx = r.depths(k)
                d = dy if axis == "y" else dx
                lo |= d[0] > 0
                hi |= d[1] > 0
            total += int(lo) + int(hi)
        return total

    def describe(self, n_fields: int = 4, k: int = 1) -> Dict[str, Any]:
        """JSON footprint declaration — `plan.report()["footprint"]` and
        the docs/kernels.md StencilOpDef table."""
        return {"op": self.name,
                "reads": list(self.reads),
                "writes": list(self.writes),
                "halo": self.halo,
                "flops_per_point": self.flops_per_point,
                "rides": [r.describe(k) for r in self.rides],
                "variants": list(self.variants),
                "inkernel_kstep": self.inkernel_kstep}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

STENCIL_OPS: Dict[str, StencilOpDef] = {}


def register_stencil_op(op: StencilOpDef) -> StencilOpDef:
    """Add (or replace) a stencil operator; returns it for chaining."""
    STENCIL_OPS[op.name] = op
    return op


def get_stencil_op(name: str) -> StencilOpDef:
    try:
        return STENCIL_OPS[name]
    except KeyError:
        raise KeyError(f"unknown stencil op {name!r}; registered: "
                       f"{sorted(STENCIL_OPS)}") from None


def registered_stencil_ops() -> Tuple[str, ...]:
    return tuple(sorted(STENCIL_OPS))


# ---------------------------------------------------------------------------
# "dycore" — the fused compound step (the PR-1..4 tentpole kernels)
# ---------------------------------------------------------------------------


def _dycore_resolve_tile(variant, compute_grid, dtype, n_fields, ensemble,
                         k):
    ty = fused_ops.resolve_tile(variant, compute_grid, dtype, n_fields, k)
    if ty is None:
        return None
    spec = {"per_field": tiling.DYCORE_FUSED,
            "whole_state": tiling.dycore_whole_state_spec(n_fields),
            "kstep": tiling.dycore_kstep_spec(n_fields, k)}[variant]
    return tiling.TilePlan(op=spec, grid_shape=tuple(compute_grid),
                           tile=(compute_grid[0], ty, compute_grid[2]),
                           dtype=str(jnp.dtype(dtype)))


def _dycore_local_step(plan):
    """Single-chip lowering: the periodic-domain kernels at the plan's
    resolved tile/precision/interpret settings.  Every variant is wrapped
    in ONE jax.jit so a round is a single dispatch (stack/unstack and the
    per-field loop trace into the same computation)."""
    prog = plan.program
    names, coeff, dt = prog.fields, prog.coeff, prog.dt
    variant, interp = plan.variant, plan.interpret
    ty = plan.tile_ty
    stack = lambda d: _dycore.stack_state(d, names)
    unstack = lambda a: _dycore.unstack_state(a, names)

    if variant == "unfused":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            new_fields, new_stage = {}, {}
            for name in names:
                f = state.fields[name]
                stage = _dycore.vadvc_field(
                    u_stage=f, wcon=state.wcon, u_pos=f,
                    utens=state.tens[name],
                    utens_stage=state.stage_tens[name])
                f = f + dt * stage
                f = _dycore.hdiff_periodic(f, coeff)
                new_fields[name] = f
                new_stage[name] = stage
            return WeatherState(fields=new_fields, wcon=state.wcon,
                                tens=state.tens, stage_tens=new_stage)
        return step

    if variant == "per_field":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            new_fields, new_stage = {}, {}
            for name in names:
                f_new, stage = fused_ops.fused_step(
                    state.fields[name], state.wcon, state.tens[name],
                    state.stage_tens[name], coeff=coeff, dt=dt, ty=ty,
                    interpret=interp)
                new_fields[name] = f_new
                new_stage[name] = stage
            return WeatherState(fields=new_fields, wcon=state.wcon,
                                tens=state.tens, stage_tens=new_stage)
        return step

    if variant == "whole_state":
        @jax.jit
        def step(state: WeatherState) -> WeatherState:
            f_new, stage = fused_ops.fused_step_whole_state(
                stack(state.fields), state.wcon, stack(state.tens),
                stack(state.stage_tens), coeff=coeff, dt=dt, ty=ty,
                interpret=interp)
            return WeatherState(fields=unstack(f_new), wcon=state.wcon,
                                tens=state.tens, stage_tens=unstack(stage))
        return step

    k = plan.k_steps

    @jax.jit
    def step(state: WeatherState) -> WeatherState:
        f_new, stage = fused_ops.fused_step_kstep(
            stack(state.fields), state.wcon, stack(state.tens),
            stack(state.stage_tens), k_steps=k, coeff=coeff, dt=dt, ty=ty,
            interpret=interp, prefetch_w=plan.prefetch_w)
        return WeatherState(fields=unstack(f_new), wcon=state.wcon,
                            tens=state.tens, stage_tens=unstack(stage))
    return step


def _dycore_shard_local(plan):
    """Chip-local round of the distributed dycore: exchange (per the
    plan's schedule) + local kernel + interior crop — the function
    `program._build_distributed_step` shard_maps.  See `weather/domain.py`
    for the exchange primitives and the design rationale."""
    prog = plan.program
    ax_e, ax_y, ax_x = plan.mesh_axes
    names, nf = prog.fields, prog.n_fields
    coeff, dt, halo = prog.coeff, prog.dt, HALO
    k, ty, interp = plan.k_steps, plan.tile_ty, plan.interpret
    py, px = plan.shards

    def local_step_unfused(fields, wcon, tens, stage_tens):
        new_fields, new_stage = {}, {}
        for name in names:
            f = fields[name]
            stage = _domain._local_vadvc(f, wcon, f, tens[name],
                                         stage_tens[name], ax_x, px)
            f = f + dt * stage
            f = _domain._local_hdiff(f, coeff, ax_y, ax_x, py, px)
            new_fields[name] = f
            new_stage[name] = stage
        return new_fields, new_stage

    def local_step_per_field(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape

        def pad(a):
            a = _domain._exchange(a, ax_y, py, halo, dim=2)
            return _domain._exchange(a, ax_x, px, halo, dim=3)

        # One exchange of the pre-combined staggered velocity serves all
        # fields; the per-field inputs are exchanged so the halo ring's
        # vadvc tendency is recomputed locally.
        wp = pad(_domain._staggered_w(wcon, ax_x, px))
        crop = lambda a: a[:, :, halo:halo + ly, halo:halo + lx]
        new_fields, new_stage = {}, {}
        for name in names:
            f_new, stage = fused_dycore_pallas(
                pad(fields[name]), wp, pad(tens[name]),
                pad(stage_tens[name]), coeff=coeff, dt=dt, ty=ty,
                interpret=interp)
            new_fields[name] = crop(f_new)
            new_stage[name] = crop(stage)
        return new_fields, new_stage

    def local_step_packed(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape
        sched = plan.exchange
        hy, hx = sched.depth_y, sched.depth_x
        # ONE packed exchange per direction covers every operand: fields,
        # slow tendencies, stage tendencies at the k-step stencil reach and
        # raw wcon at its own RAGGED depth — the +1 staggering column
        # (w[c] = wcon[c] + wcon[c+1]) comes from the RIGHT neighbor only,
        # so wcon's x-ride is (hx, hx+1), not a symmetric hx+1.
        stacked = jnp.stack(
            [fields[n] for n in names]
            + [tens[n] for n in names]
            + [stage_tens[n] for n in names], axis=1)
        stacked, wconp = _domain._exchange_packed(
            [(stacked, hy), (wcon, hy)], ax_y, py, dim=-2,
            wire_dtype=sched.wire_dtype)
        stacked, wconp = _domain._exchange_packed(
            [(stacked, hx), (wconp, sched.wcon_depth_x)], ax_x, px, dim=-1,
            wire_dtype=sched.wire_dtype)
        fs, ts, ss = (stacked[:, :nf], stacked[:, nf:2 * nf],
                      stacked[:, 2 * nf:])
        # Staggered velocity on the padded slab — valid everywhere: the
        # right-only extra wcon column supplies the outermost neighbor.
        w = wconp[..., :-1] + wconp[..., 1:]

        if k == 1:
            fs, ss = fused_dycore_whole_state_pallas(
                fs, w, ts, ss, coeff=coeff, dt=dt, ty=ty, interpret=interp)
        else:
            # The WHOLE round in one launch: the kernel iterates the k
            # local steps with state held in VMEM (no scan of launches,
            # no HBM state round-trips between steps).
            fs, ss = fused_dycore_kstep_pallas(
                fs, w, ts, ss, k_steps=k, coeff=coeff, dt=dt, ty=ty,
                interpret=interp, prefetch_w=plan.prefetch_w)
        crop = lambda a: a[..., hy:hy + ly, hx:hx + lx]
        new_fields = {n: crop(fs[:, i]) for i, n in enumerate(names)}
        new_stage = {n: crop(ss[:, i]) for i, n in enumerate(names)}
        return new_fields, new_stage

    return {"unfused": local_step_unfused,
            "per_field": local_step_per_field,
            "whole_state": local_step_packed,
            "kstep": local_step_packed}[plan.variant]


def _dycore_collectives(variant, n_fields, py, px, k):
    if variant in ("whole_state", "kstep"):
        return None          # derive from the rides (one pair per direction)
    ey = 2 if py > 1 else 0  # one ppermute pair per active direction
    ex = 2 if px > 1 else 0
    rc = 1 if px > 1 else 0  # wcon's right-column fetch
    if variant == "per_field":
        # shared staggered-w pad + 3 per-operand pads per field
        return rc + (ey + ex) + n_fields * 3 * (ey + ex)
    # unfused: per-field vadvc + hdiff pads
    return n_fields * (rc + ey + ex)


def _dycore_traffic(plan, model_ty):
    prog = plan.program
    return memmodel.dycore_step_traffic(
        prog.grid_shape, prog.dtype, n_fields=prog.n_fields, ty=model_ty,
        k_steps=plan.k_steps)


def _dycore_exchange_model(plan):
    prog = plan.program
    return memmodel.kstep_exchange_model(
        prog.grid_shape, prog.dtype, n_fields=prog.n_fields,
        k=plan.k_steps, shards=plan.exchange.shards, halo=HALO,
        exchange_dtype=prog.exchange_dtype)


register_stencil_op(StencilOpDef(
    name="dycore",
    title="fused compound dycore step (vadvc + point-wise + hdiff)",
    reads=("fields", "wcon", "tens", "stage_tens"),
    writes=("fields", "stage_tens"),
    halo=HALO,
    flops_per_point=tiling.DYCORE_FUSED.flops_per_point,
    rides=(OperandRide("fields", y=(HALO, HALO), x=(HALO, HALO),
                       per_field=True),
           OperandRide("tens", y=(HALO, HALO), x=(HALO, HALO),
                       per_field=True),
           OperandRide("stage_tens", y=(HALO, HALO), x=(HALO, HALO),
                       per_field=True),
           OperandRide("wcon", y=(HALO, HALO), x=(HALO, HALO),
                       x_fixed=(0, 1))),
    variants=("unfused", "per_field", "whole_state", "kstep"),
    tile_spaces=(("per_field", "dycore_fused"),
                 ("whole_state", "dycore_whole_state"),
                 ("kstep", "dycore_kstep")),
    inkernel_kstep=True,
    pads_single_chip=False,
    packed_variants=("whole_state", "kstep"),
    resolve_tile=_dycore_resolve_tile,
    build_shard_local=_dycore_shard_local,
    build_local_step=_dycore_local_step,
    pallas_calls=lambda variant, nf, k: {"unfused": 0, "per_field": nf,
                                         "whole_state": 1, "kstep": 1}[
                                             variant],
    collectives=_dycore_collectives,
    traffic=_dycore_traffic,
    exchange_model=_dycore_exchange_model,
))


# ---------------------------------------------------------------------------
# "hdiff" — compound horizontal diffusion alone (paper: 12.7x, 21.01 GF/W)
# ---------------------------------------------------------------------------


def _hdiff_resolve_tile(variant, compute_grid, dtype, n_fields, ensemble,
                        k):
    if variant == "unfused":
        return None
    return hdiff_ops.resolve_tile(compute_grid, dtype)


def _hdiff_kstep_ty(Y: int, ty: int, k: int) -> int:
    """The in-kernel k-step window: the divisor of the slab height `Y`
    closest to the tuned `ty` with at least `max(2, 2k)` rows — each
    in-slab step shrinks the window's valid interior by 2 rows per side,
    so smaller windows would self-corrupt before the round ends.  `Y` is
    always a legal fallback (the deep-ride compile check keeps
    `Y = ly + 4k > 2k`)."""
    lo = max(2, 2 * k)
    cands = [d for d in range(lo, Y + 1) if Y % d == 0]
    return min(cands, key=lambda d: (abs(d - ty), d))


def _hdiff_kstep_vmem_check(program, shards):
    """Per-k legality for `autotune.resolve_k_steps`: the k-step round
    must find a legal tuned window on the k·2-padded local slab."""
    nz, ny, nx = program.grid_shape
    py, px = shards

    def check(kk):
        hdiff_ops.resolve_tile(
            (nz, ny // py + 4 * kk, nx // px + 4 * kk), program.dtype)
    return check


def _hdiff_shard_local(plan):
    """Chip-local hdiff round, ALL variants: ONE packed exchange per
    direction at the k-scaled footprint depth, then the local compute —
    oracle / one launch per field / one launch for the whole state (the
    fully-z-parallel stencil folds (ensemble, field, z) into the kernel's
    batch axis) / ONE `hdiff_kstep_pallas` launch that iterates the k
    local steps with the slab held in VMEM (validity shrinks HALO per
    in-slab step; the crop keeps the k-step-valid interior) — and the
    interior crop.  With 1 shard the exchange degenerates to periodic
    wrap-padding, so this same lowering IS the single-chip step."""
    prog = plan.program
    names = prog.fields
    coeff, variant, interp = prog.coeff, plan.variant, plan.interpret
    k = plan.k_steps
    ty = plan.tile_ty
    _, ax_y, ax_x = plan.mesh_axes
    py, px = plan.shards
    (_, (hy_lo, hy_hi), (hx_lo, hx_hi)), = plan.rides
    wire = prog.exchange_dtype

    def local(fields, wcon, tens, stage_tens):
        fs = _dycore.stack_state(fields, names)   # (e, nf, nz, ly, lx)
        e, nf, nz, ly, lx = fs.shape
        (fs,) = _domain._exchange_packed([(fs, (hy_lo, hy_hi))], ax_y, py,
                                         dim=-2, wire_dtype=wire)
        (fs,) = _domain._exchange_packed([(fs, (hx_lo, hx_hi))], ax_x, px,
                                         dim=-1, wire_dtype=wire)
        Y, X = fs.shape[-2:]

        def one_launch(a):
            """One hdiff_pallas launch over a (..., nz, Y, X) stack."""
            out = hdiff_pallas(a.reshape(-1, Y, X), coeff=coeff, ty=ty,
                               interpret=interp)
            return out.reshape(a.shape)

        if variant == "unfused":
            fs = hdiff_ref.hdiff(fs.reshape(-1, Y, X),
                                 coeff=coeff).reshape(fs.shape)
        elif variant == "per_field":
            fs = jnp.concatenate([one_launch(fs[:, i:i + 1])
                                  for i in range(nf)], axis=1)
        elif k == 1:   # whole_state
            fs = one_launch(fs)
        else:
            # kstep: the WHOLE round in ONE launch (ROADMAP item 2) — the
            # kernel iterates the k local steps with each window's slab
            # held in VMEM, matching the dycore's one-launch-per-round
            # contract.  Bit-equal to k sequential launches: every step
            # round-trips through the storage dtype in-kernel.
            out = hdiff_kstep_pallas(fs.reshape(-1, Y, X), coeff=coeff,
                                     ty=_hdiff_kstep_ty(Y, ty, k),
                                     k_steps=k, interpret=interp)
            fs = out.reshape(fs.shape)
        out = fs[..., hy_lo:hy_lo + ly, hx_lo:hx_lo + lx]
        new_fields = {n: out[:, i] for i, n in enumerate(names)}
        return new_fields, dict(stage_tens)
    return local


def _hdiff_traffic(plan, model_ty):
    prog = plan.program
    nz, ny, nx = prog.grid_shape
    # model_ty may have been resolved on a padded/folded grid (distributed
    # or unfused plans); the traffic model runs on the physical grid, so
    # snap to a legal window of it.
    tile = (1, tiling.snap_to_divisor(model_ty, ny, lo=1), nx)
    return memmodel.stencil_op_traffic(
        autotune.get_op("hdiff"), prog.grid_shape, prog.dtype,
        n_fields=prog.n_fields, tile=tile, k_steps=plan.k_steps)


def _hdiff_apply_stage(prog, names, interpret, use_ref):
    """Full-slab hdiff stage for pipeline chaining: the bound fields fold
    into the kernel's batch axis; the window is re-tuned on the ACTUAL
    slab (merged pipeline rides make it wider than the solo compute grid
    — harmless, the kernel is bitwise tile-invariant)."""
    coeff = prog.coeff

    def fn(fields, wconp, tens, stage_tens):
        fs = jnp.stack([fields[n] for n in names], axis=1)
        e, nb, nz, Y, X = fs.shape
        if use_ref:
            out = hdiff_ref.hdiff(fs.reshape(-1, Y, X), coeff=coeff)
        else:
            ty = hdiff_ops.plan_tile((e * nb * nz, Y, X), fs.dtype)
            out = hdiff_pallas(fs.reshape(-1, Y, X), coeff=coeff, ty=ty,
                               interpret=interpret)
        out = out.reshape(fs.shape)
        new_fields = dict(fields)
        for i, n in enumerate(names):
            new_fields[n] = out[:, i]
        return new_fields, dict(stage_tens)
    return fn


# ---------------------------------------------------------------------------
# "vadvc" — vertical advection alone (paper: 5.3x, 1.61 GF/W)
# ---------------------------------------------------------------------------


def _vadvc_fold_grid(variant, local_grid, n_fields, ensemble):
    """The grid the vadvc kernel actually tiles: the horizontally-parallel
    sweep folds (ensemble [, field]) into y."""
    nz, ly, lx = local_grid
    fold = ensemble * (n_fields if variant == "whole_state" else 1)
    return (nz, fold * ly, lx)


def _vadvc_resolve_tile(variant, compute_grid, dtype, n_fields, ensemble,
                        k):
    if variant == "unfused":
        return None
    return vadvc_ops.resolve_tile(
        _vadvc_fold_grid(variant, compute_grid, n_fields, ensemble), dtype)


def _vadvc_launch_whole_state(fs, wconp, ts, ss, tile, interp):
    """ONE vadvc launch over stacked (e, nf, nz, ly, lx) operands —
    (ensemble, field) folded into the kernel's y axis, the shared wcon
    (already carrying its +1 staggering column) replicated across the
    field fold.  Returns the stage-tendency stack.  Shared by the solo
    whole-state lowering and the `vadvc`/`vadvc_update` pipeline stages;
    `tile` extents are re-snapped to the actual fold (the Thomas sweep is
    bitwise tile-invariant, so snapping never changes results)."""
    e, nf, nz, ly, lx = fs.shape
    _, tj, ti = tile
    ti = tiling.snap_to_divisor(ti, lx, lo=1)
    tj = tiling.snap_to_divisor(tj, e * nf * ly, lo=1)

    def foldf(a):            # (e, nf, nz, ly, lx') -> (nz, e*nf*ly, lx')
        return a.transpose(2, 0, 1, 3, 4).reshape(nz, e * nf * ly,
                                                  a.shape[-1])

    wrep = jnp.broadcast_to(wconp[:, None], (e, nf) + wconp.shape[1:])
    out = vadvc_pallas(foldf(fs), foldf(wrep), foldf(fs), foldf(ts),
                       foldf(ss), tj=tj, ti=ti, interpret=interp)
    return out.reshape(nz, e, nf, ly, lx).transpose(1, 2, 0, 3, 4)


def _vadvc_shard_local(plan):
    """Chip-local vadvc round: the ONLY exchanged operand is wcon's RIGHT
    staggering column — the `(0, 1)` x-ride declared in the registry, ONE
    ppermute (the forward direction ships nothing and is elided).  Fields/
    tendencies have a zero footprint (the z-sweep is pointwise in the
    horizontal), so there is no pad-and-crop: the updated stage tendencies
    are full-slab valid.  per_field folds the ensemble into the kernel's
    y axis; whole_state folds (ensemble, field) and replicates the shared
    wcon across the field fold."""
    prog = plan.program
    names = prog.fields
    variant, interp = plan.variant, plan.interpret
    _, _, ax_x = plan.mesh_axes
    py, px = plan.shards
    (_, _ydepth, (wx_lo, wx_hi)), = plan.rides
    wire = prog.exchange_dtype
    tile = plan.tile_plan.tile if plan.tile_plan is not None else None

    def local(fields, wcon, tens, stage_tens):
        e, nz, ly, lx = wcon.shape
        (wconp,) = _domain._exchange_packed([(wcon, (wx_lo, wx_hi))], ax_x,
                                            px, dim=-1, wire_dtype=wire)
        if variant == "unfused":
            new_stage = {
                n: jax.vmap(vadvc_ref.vadvc)(fields[n], wconp, fields[n],
                                             tens[n], stage_tens[n])
                for n in names}
            return dict(fields), new_stage

        # The planner resolved (tj, ti) against the GLOBAL ensemble fold;
        # under an ensemble-sharded ("pod") mesh the local fold is
        # smaller, so re-snap to the shard's actual extents (static at
        # trace time; a no-op when they already divide).
        _, tj, ti = tile
        ti = tiling.snap_to_divisor(ti, lx, lo=1)
        if variant == "per_field":
            tj_l = tiling.snap_to_divisor(tj, e * ly, lo=1)

            def fold(a):         # (e, nz, ly, lx') -> (nz, e*ly, lx')
                return a.transpose(1, 0, 2, 3).reshape(nz, e * ly,
                                                       a.shape[-1])
            wf = fold(wconp)
            new_stage = {}
            for n in names:
                out = vadvc_pallas(fold(fields[n]), wf, fold(fields[n]),
                                   fold(tens[n]), fold(stage_tens[n]),
                                   tj=tj_l, ti=ti, interpret=interp)
                new_stage[n] = out.reshape(nz, e, ly, lx).transpose(
                    1, 0, 2, 3)
            return dict(fields), new_stage

        # whole_state: ONE launch — (ensemble, field) folded into y, the
        # shared wcon replicated across the field fold.
        stk = lambda d: _dycore.stack_state(d, names)  # (e,nf,nz,ly,lx)
        out = _vadvc_launch_whole_state(stk(fields), wconp, stk(tens),
                                        stk(stage_tens), tile, interp)
        new_stage = {n: out[:, i] for i, n in enumerate(names)}
        return dict(fields), new_stage
    return local


def _vadvc_apply_stage(prog, names, interpret, use_ref):
    """Full-slab vadvc stage: updates the bound stage tendencies only
    (fields pass through).  `wconp` is the pipeline's wcon slab — one
    column wider on the high-x side than the field slabs, exactly the
    solo lowering's staggering contract."""
    def fn(fields, wconp, tens, stage_tens):
        new_stage = dict(stage_tens)
        if use_ref:
            for n in names:
                new_stage[n] = jax.vmap(vadvc_ref.vadvc)(
                    fields[n], wconp, fields[n], tens[n], stage_tens[n])
            return dict(fields), new_stage
        stk = lambda d: jnp.stack([d[n] for n in names], axis=1)
        fs = stk(fields)
        e, nb, nz, Y, X = fs.shape
        tile = vadvc_ops.resolve_tile((nz, e * nb * Y, X), fs.dtype).tile
        out = _vadvc_launch_whole_state(fs, wconp, stk(tens),
                                        stk(stage_tens), tile, interpret)
        for i, n in enumerate(names):
            new_stage[n] = out[:, i]
        return dict(fields), new_stage
    return fn


def _vadvc_traffic(plan, model_ty):
    prog = plan.program
    nz, ny, nx = prog.grid_shape
    # The resolved tile lives on the ensemble/field-FOLDED grid; the
    # traffic model runs on the physical grid, so snap its (tj, ti) to
    # legal extents of (ny, nx) (z stays whole — the sweep is sequential).
    if plan.tile_plan is not None:
        _, tj, ti = plan.tile_plan.tile
    else:
        tj, ti = model_ty, nx
    tile = (nz, tiling.snap_to_divisor(tj, ny, lo=1),
            tiling.snap_to_divisor(ti, nx, lo=1))
    return memmodel.stencil_op_traffic(
        autotune.get_op("vadvc"), prog.grid_shape, prog.dtype,
        n_fields=prog.n_fields, tile=tile, k_steps=plan.k_steps)


def _generic_exchange_model(op: StencilOpDef):
    def model(plan):
        prog = plan.program
        return memmodel.packed_exchange_model(
            prog.grid_shape, prog.dtype, rides=op.memmodel_rides(
                prog.n_fields),
            k=plan.k_steps, shards=plan.exchange.shards,
            compute_halo=(plan.k_steps * op.halo, plan.k_steps * op.halo),
            exchange_dtype=prog.exchange_dtype)
    return model


_HDIFF_OP = register_stencil_op(StencilOpDef(
    name="hdiff",
    title="compound horizontal diffusion (laplace -> limited flux -> out)",
    reads=("fields",),
    writes=("fields",),
    halo=hdiff_ops.HALO,
    flops_per_point=tiling.HDIFF.flops_per_point,
    rides=(OperandRide("fields", y=(hdiff_ops.HALO, hdiff_ops.HALO),
                       x=(hdiff_ops.HALO, hdiff_ops.HALO), per_field=True),),
    variants=("unfused", "per_field", "whole_state", "kstep"),
    tile_spaces=(("per_field", "hdiff"), ("whole_state", "hdiff"),
                 ("kstep", "hdiff")),
    inkernel_kstep=True,
    pads_single_chip=True,
    packed_variants=("unfused", "per_field", "whole_state", "kstep"),
    resolve_tile=_hdiff_resolve_tile,
    build_shard_local=_hdiff_shard_local,
    pallas_calls=lambda variant, nf, k: {"unfused": 0, "per_field": nf,
                                         "whole_state": 1, "kstep": 1}[
                                             variant],
    traffic=_hdiff_traffic,
    kstep_vmem_check=_hdiff_kstep_vmem_check,
))
_HDIFF_OP = dataclasses.replace(
    _HDIFF_OP, exchange_model=_generic_exchange_model(_HDIFF_OP),
    apply_stage=_hdiff_apply_stage)
register_stencil_op(_HDIFF_OP)

_VADVC_OP = register_stencil_op(StencilOpDef(
    name="vadvc",
    title="vertical advection (implicit Thomas solve; updates stage_tens)",
    reads=("fields", "wcon", "tens", "stage_tens"),
    writes=("stage_tens",),
    halo=0,
    flops_per_point=tiling.VADVC.flops_per_point,
    rides=(OperandRide("wcon", x_fixed=(0, 1)),),
    variants=("unfused", "per_field", "whole_state"),
    tile_spaces=(("per_field", "vadvc"), ("whole_state", "vadvc")),
    inkernel_kstep=False,
    pads_single_chip=True,
    packed_variants=("unfused", "per_field", "whole_state"),
    resolve_tile=_vadvc_resolve_tile,
    build_shard_local=_vadvc_shard_local,
    pallas_calls=lambda variant, nf, k: {"unfused": 0, "per_field": nf,
                                         "whole_state": 1}[variant],
    traffic=_vadvc_traffic,
))
_VADVC_OP = dataclasses.replace(
    _VADVC_OP, exchange_model=_generic_exchange_model(_VADVC_OP),
    apply_stage=_vadvc_apply_stage)
register_stencil_op(_VADVC_OP)


# ---------------------------------------------------------------------------
# "vadvc_update" — the paper's ablation composition: vadvc + point-wise
# leapfrog update (no hdiff)
# ---------------------------------------------------------------------------


def _vadvc_update_resolve_tile(variant, compute_grid, dtype, n_fields,
                               ensemble, k):
    if variant == "unfused":
        return None
    tj, ti = vadvc_ops.plan_tile(
        _vadvc_fold_grid("whole_state", compute_grid, n_fields, ensemble),
        dtype)
    return tiling.TilePlan(op=autotune.get_op("vadvc_update"),
                           grid_shape=tuple(int(g) for g in compute_grid),
                           tile=(int(compute_grid[0]), tj, ti),
                           dtype=str(jnp.dtype(dtype)))


def _vadvc_update_shard_local(plan):
    """Chip-local vadvc_update round: the vadvc lowering (ONE wcon
    right-column ppermute, full-slab-valid stage tendencies) followed by
    the resident point-wise update `f += dt * stage` — the composition
    never round-trips the stage tendency through HBM between the solve
    and the update."""
    prog = plan.program
    names, dt = prog.fields, prog.dt
    variant, interp = plan.variant, plan.interpret
    _, _, ax_x = plan.mesh_axes
    py, px = plan.shards
    (_, _ydepth, (wx_lo, wx_hi)), = plan.rides
    wire = prog.exchange_dtype
    tile = plan.tile_plan.tile if plan.tile_plan is not None else None

    def local(fields, wcon, tens, stage_tens):
        (wconp,) = _domain._exchange_packed([(wcon, (wx_lo, wx_hi))], ax_x,
                                            px, dim=-1, wire_dtype=wire)
        if variant == "unfused":
            new_fields, new_stage = {}, {}
            for n in names:
                stage = jax.vmap(vadvc_ref.vadvc)(
                    fields[n], wconp, fields[n], tens[n], stage_tens[n])
                new_fields[n] = fields[n] + dt * stage
                new_stage[n] = stage
            return new_fields, new_stage
        stk = lambda d: _dycore.stack_state(d, names)
        fs = stk(fields)
        ss = _vadvc_launch_whole_state(fs, wconp, stk(tens),
                                       stk(stage_tens), tile, interp)
        fs = fs + dt * ss
        new_fields = {n: fs[:, i] for i, n in enumerate(names)}
        new_stage = {n: ss[:, i] for i, n in enumerate(names)}
        return new_fields, new_stage
    return local


def _vadvc_update_apply_stage(prog, names, interpret, use_ref):
    """Full-slab vadvc_update stage: solve + resident point-wise update of
    the bound fields; writes fields AND stage tendencies."""
    dt = prog.dt

    def fn(fields, wconp, tens, stage_tens):
        new_fields, new_stage = dict(fields), dict(stage_tens)
        if use_ref:
            for n in names:
                stage = jax.vmap(vadvc_ref.vadvc)(
                    fields[n], wconp, fields[n], tens[n], stage_tens[n])
                new_fields[n] = fields[n] + dt * stage
                new_stage[n] = stage
            return new_fields, new_stage
        stk = lambda d: jnp.stack([d[n] for n in names], axis=1)
        fs = stk(fields)
        e, nb, nz, Y, X = fs.shape
        tile = vadvc_ops.resolve_tile((nz, e * nb * Y, X), fs.dtype).tile
        ss = _vadvc_launch_whole_state(fs, wconp, stk(tens),
                                       stk(stage_tens), tile, interpret)
        fs = fs + dt * ss
        for i, n in enumerate(names):
            new_fields[n] = fs[:, i]
            new_stage[n] = ss[:, i]
        return new_fields, new_stage
    return fn


def _vadvc_update_traffic(plan, model_ty):
    prog = plan.program
    nz, ny, nx = prog.grid_shape
    if plan.tile_plan is not None:
        _, tj, ti = plan.tile_plan.tile
    else:
        tj, ti = model_ty, nx
    tile = (nz, tiling.snap_to_divisor(tj, ny, lo=1),
            tiling.snap_to_divisor(ti, nx, lo=1))
    return memmodel.stencil_op_traffic(
        autotune.get_op("vadvc_update"), prog.grid_shape, prog.dtype,
        n_fields=prog.n_fields, tile=tile, k_steps=plan.k_steps)


_VADVC_UPDATE_OP = register_stencil_op(StencilOpDef(
    name="vadvc_update",
    title="vertical advection + fused point-wise update (no hdiff)",
    reads=("fields", "wcon", "tens", "stage_tens"),
    writes=("fields", "stage_tens"),
    halo=0,
    flops_per_point=tiling.VADVC_UPDATE.flops_per_point,
    rides=(OperandRide("wcon", x_fixed=(0, 1)),),
    variants=("unfused", "whole_state"),
    tile_spaces=(("whole_state", "vadvc_update"),),
    inkernel_kstep=False,
    pads_single_chip=True,
    packed_variants=("unfused", "whole_state"),
    resolve_tile=_vadvc_update_resolve_tile,
    build_shard_local=_vadvc_update_shard_local,
    pallas_calls=lambda variant, nf, k: {"unfused": 0,
                                         "whole_state": 1}[variant],
    traffic=_vadvc_update_traffic,
))
_VADVC_UPDATE_OP = dataclasses.replace(
    _VADVC_UPDATE_OP,
    exchange_model=_generic_exchange_model(_VADVC_UPDATE_OP),
    apply_stage=_vadvc_update_apply_stage)
register_stencil_op(_VADVC_UPDATE_OP)


# ---------------------------------------------------------------------------
# "hadv_upwind" — first-order upwind horizontal advection (backward-only
# reach: the registry's asymmetric-ride op)
# ---------------------------------------------------------------------------


def _hadv_resolve_tile(variant, compute_grid, dtype, n_fields, ensemble, k):
    if variant == "unfused":
        return None
    return hadv_ops.resolve_tile(compute_grid, dtype)


def _hadv_shard_local(plan):
    """Chip-local hadv round: ONE packed exchange per direction at the
    asymmetric (1, 0) depth — the donor cell only looks backward, so the
    high sides ship NOTHING and `domain._exchange_packed` elides those
    halves of the wire buffer.  With 1 shard the exchange degenerates to
    periodic wrap-padding (the op is periodic, like hdiff programs)."""
    prog = plan.program
    names = prog.fields
    cfl, variant, interp = prog.coeff, plan.variant, plan.interpret
    ty = plan.tile_ty
    _, ax_y, ax_x = plan.mesh_axes
    py, px = plan.shards
    (_, (hy_lo, hy_hi), (hx_lo, hx_hi)), = plan.rides
    wire = prog.exchange_dtype

    def local(fields, wcon, tens, stage_tens):
        fs = _dycore.stack_state(fields, names)   # (e, nf, nz, ly, lx)
        e, nf, nz, ly, lx = fs.shape
        (fs,) = _domain._exchange_packed([(fs, (hy_lo, hy_hi))], ax_y, py,
                                         dim=-2, wire_dtype=wire)
        (fs,) = _domain._exchange_packed([(fs, (hx_lo, hx_hi))], ax_x, px,
                                         dim=-1, wire_dtype=wire)
        Y, X = fs.shape[-2:]
        if variant == "unfused":
            fs = hadv_ref.hadv_upwind(fs.reshape(-1, Y, X),
                                      cfl=cfl).reshape(fs.shape)
        else:
            # The compute grid the planner tuned on is symmetrically
            # padded; the actual slab only grows on the low sides — snap
            # the window to it (the kernel is bitwise tile-invariant).
            ty_l = tiling.snap_to_divisor(ty, Y, lo=1)
            fs = hadv_pallas(fs.reshape(-1, Y, X), cfl=cfl, ty=ty_l,
                             interpret=interp).reshape(fs.shape)
        out = fs[..., hy_lo:hy_lo + ly, hx_lo:hx_lo + lx]
        new_fields = {n: out[:, i] for i, n in enumerate(names)}
        return new_fields, dict(stage_tens)
    return local


def _hadv_apply_stage(prog, names, interpret, use_ref):
    """Full-slab upwind-advection stage for pipeline chaining."""
    cfl = prog.coeff

    def fn(fields, wconp, tens, stage_tens):
        fs = jnp.stack([fields[n] for n in names], axis=1)
        e, nb, nz, Y, X = fs.shape
        if use_ref:
            out = hadv_ref.hadv_upwind(fs.reshape(-1, Y, X), cfl=cfl)
        else:
            ty = hadv_ops.plan_tile((e * nb * nz, Y, X), fs.dtype)
            out = hadv_pallas(fs.reshape(-1, Y, X), cfl=cfl, ty=ty,
                              interpret=interpret)
        out = out.reshape(fs.shape)
        new_fields = dict(fields)
        for i, n in enumerate(names):
            new_fields[n] = out[:, i]
        return new_fields, dict(stage_tens)
    return fn


def _hadv_traffic(plan, model_ty):
    prog = plan.program
    nz, ny, nx = prog.grid_shape
    tile = (1, tiling.snap_to_divisor(model_ty, ny, lo=1), nx)
    return memmodel.stencil_op_traffic(
        autotune.get_op("hadv_upwind"), prog.grid_shape, prog.dtype,
        n_fields=prog.n_fields, tile=tile, k_steps=plan.k_steps)


_HADV_OP = register_stencil_op(StencilOpDef(
    name="hadv_upwind",
    title="upwind horizontal advection (donor cell, backward-only reach)",
    reads=("fields",),
    writes=("fields",),
    halo=hadv_ops.HALO,
    flops_per_point=tiling.HADV_UPWIND.flops_per_point,
    rides=(OperandRide("fields", y=(hadv_ops.HALO, 0),
                       x=(hadv_ops.HALO, 0), per_field=True),),
    variants=("unfused", "whole_state"),
    tile_spaces=(("whole_state", "hadv_upwind"),),
    inkernel_kstep=False,
    pads_single_chip=True,
    packed_variants=("unfused", "whole_state"),
    resolve_tile=_hadv_resolve_tile,
    build_shard_local=_hadv_shard_local,
    pallas_calls=lambda variant, nf, k: {"unfused": 0,
                                         "whole_state": 1}[variant],
    traffic=_hadv_traffic,
))
_HADV_OP = dataclasses.replace(
    _HADV_OP, exchange_model=_generic_exchange_model(_HADV_OP),
    apply_stage=_hadv_apply_stage)
register_stencil_op(_HADV_OP)


# ---------------------------------------------------------------------------
# "asselin" — point-wise leapfrog time filter (zero rides, zero exchange)
# ---------------------------------------------------------------------------


def _asselin_shard_local(plan):
    """Chip-local asselin round: pure point-wise jnp — no exchange at all
    (the registry's zero-ride op; every direction is elided), no Pallas
    launch (XLA fuses the three-operand FMA fine on its own)."""
    prog = plan.program
    names, coeff, dt = prog.fields, prog.coeff, prog.dt

    def local(fields, wcon, tens, stage_tens):
        new_fields = {n: fields[n] + coeff * dt * (tens[n] - stage_tens[n])
                      for n in names}
        return new_fields, dict(stage_tens)
    return local


def _asselin_apply_stage(prog, names, interpret, use_ref):
    """Full-slab asselin stage: the same point-wise filter the solo
    lowering runs (there is no kernel to dispatch either way)."""
    coeff, dt = prog.coeff, prog.dt

    def fn(fields, wconp, tens, stage_tens):
        new_fields = dict(fields)
        for n in names:
            new_fields[n] = (fields[n]
                             + coeff * dt * (tens[n] - stage_tens[n]))
        return new_fields, dict(stage_tens)
    return fn


def _asselin_traffic(plan, model_ty):
    prog = plan.program
    nz, ny, nx = prog.grid_shape
    tile = (1, tiling.snap_to_divisor(model_ty, ny, lo=1), nx)
    return memmodel.stencil_op_traffic(
        autotune.get_op("asselin"), prog.grid_shape, prog.dtype,
        n_fields=prog.n_fields, tile=tile, k_steps=plan.k_steps)


_ASSELIN_OP = register_stencil_op(StencilOpDef(
    name="asselin",
    title="leapfrog time filter from stored tendencies (point-wise)",
    reads=("fields", "tens", "stage_tens"),
    writes=("fields",),
    halo=0,
    flops_per_point=tiling.ASSELIN.flops_per_point,
    rides=(),
    variants=("unfused", "whole_state"),
    tile_spaces=(),
    inkernel_kstep=False,
    pads_single_chip=False,
    packed_variants=("unfused", "whole_state"),
    resolve_tile=lambda variant, compute_grid, dtype, nf, e, k: None,
    build_shard_local=_asselin_shard_local,
    pallas_calls=lambda variant, nf, k: 0,
    traffic=_asselin_traffic,
))
_ASSELIN_OP = dataclasses.replace(
    _ASSELIN_OP, exchange_model=_generic_exchange_model(_ASSELIN_OP),
    apply_stage=_asselin_apply_stage)
register_stencil_op(_ASSELIN_OP)
