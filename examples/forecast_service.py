"""Forecast-as-a-service demo: concurrent requests through ForecastEngine.

Submits a mix of forecast requests — different stencil programs, member
initial conditions, step counts, precisions — to one engine.  The engine
compiles each distinct program ONCE (plan cache), folds admitted requests
into the ensemble axis of the shared plan (continuous batching), retires
each request at the round boundary where its step count completes, and
backfills the freed slot from the queue.  Every served result is
bit-identical to a solo `compile(program).run(state, steps)`.

`--chaos` turns on the supervision demo (docs/robustness.md): a NaN
poison and a transient device loss are injected mid-run; the engine
quarantines the poisoned request (with a per-field diagnosis), retries
through the device loss, and serves everyone else bit-identically.

Run:  PYTHONPATH=src python examples/forecast_service.py
      PYTHONPATH=src python examples/forecast_service.py \
          --slots 4 --requests 10 --ckpt /tmp/forecast_ckpt
      PYTHONPATH=src python examples/forecast_service.py --chaos
"""

import argparse

import jax

from repro.serve.forecast import ForecastEngine, ForecastRequest
from repro.testing.faults import FaultInjector, FaultSpec
from repro.weather import fields
from repro.weather.program import StencilProgram


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slots", type=int, default=2,
                    help="ensemble slots per cached plan")
    ap.add_argument("--requests", type=int, default=6,
                    help="number of forecast requests to submit")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir: snapshot the warm engine mid-"
                         "drain and finish from the restored engine")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a NaN poison + a transient device loss "
                         "and show quarantine/retry in action")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded queue: submit() raises QueueFullError "
                         "past this (backpressure)")
    args = ap.parse_args()

    inj = None
    if args.chaos:
        inj = FaultInjector([FaultSpec(kind="poison_nan", round=1),
                             FaultSpec(kind="device_loss", round=2)],
                            seed=0)

    catalog = (
        StencilProgram(grid_shape=(4, 16, 16), op="dycore"),
        StencilProgram(grid_shape=(4, 16, 16), op="dycore",
                       dtype="bfloat16"),
        StencilProgram(grid_shape=(3, 8, 8), op="hdiff"),
    )
    eng = ForecastEngine(slots=args.slots, ckpt_dir=args.ckpt,
                         max_queue=args.max_queue, fault_injector=inj)
    print(f"== forecast service: {args.requests} requests over "
          f"{len(catalog)} programs, {args.slots} slots ==")
    for i in range(args.requests):
        prog = catalog[i % len(catalog)]
        state = fields.initial_state(jax.random.PRNGKey(i),
                                     prog.grid_shape, ensemble=1,
                                     dtype=prog.dtype)
        rid = eng.submit(ForecastRequest(program=prog, state=state,
                                         steps=2 + 3 * (i % 3)))
        print(f"submitted rid={rid} op={prog.op} dtype={prog.dtype} "
              f"steps={2 + 3 * (i % 3)}")

    if args.ckpt:
        # a few scheduler beats, then snapshot + restore the warm engine:
        # in-flight lane batches, queue, and finished results all survive
        eng.pump()
        step = eng.checkpoint()
        print(f"checkpointed warm engine at step {step} -> {args.ckpt}")
        eng = ForecastEngine.restore(args.ckpt)
        print(f"restored: {eng.stats()['active']} active, "
              f"{eng.stats()['queued']} queued")

    results = eng.drain()
    print(f"{'rid':>3} {'op':>6} {'dtype':>8} {'steps':>5} "
          f"{'rounds':>6} {'wait_ms':>8} {'latency_ms':>10} {'status':>8}")
    for rid in sorted(results):
        r = results[rid]
        print(f"{rid:>3} {r.program.op:>6} {r.program.dtype:>8} "
              f"{r.steps:>5} {r.rounds:>6} {r.queue_wait_s * 1e3:>8.1f} "
              f"{r.latency_s * 1e3:>10.1f} {r.status:>8}")
        if r.diagnosis is not None:
            print(f"     diagnosis: {r.diagnosis.get('reason')} "
                  f"{r.diagnosis.get('bad_leaves', '')}")
    s = eng.stats()
    print(f"stats: plans_cached={s['plans_cached']} "
          f"cache_hit_rate={s['plan_cache_hit_rate']:.2f} "
          f"occupancy={s['occupancy']:.2f} rounds={s['rounds']} "
          f"rolled_back={s['rolled_back_slot_rounds']}")
    if args.chaos:
        print(f"chaos: faults_fired={inj.fired()} "
              f"quarantined={s['quarantined']} "
              f"round_retries={s['round_retries']} "
              f"failed={s['failed']}")
    print("forecast service OK")


if __name__ == "__main__":
    main()
