"""COSMO-like dynamical core built from the paper's compound kernels.

One `dycore_step` applies the three computational patterns the paper names
(§1): horizontal stencils (hdiff), tridiagonal solves in the vertical
(vadvc), and point-wise computation (the explicit update).  It is a
*representative* dycore, faithful to the kernels and their composition, not a
full COSMO port.

Two execution paths (see docs/architecture.md for the dataflow diagram):

  * `fused=True` (default): the whole field step runs as ONE Pallas compound
    kernel (kernels/dycore_fused) — the vadvc tendency, the explicitly
    updated field, and the hdiff working set never leave VMEM, which is
    NERO's in-fabric fusion (arxiv 2107.08716 §3).
  * `fused=False`: the original unfused composition — wrap-pad, per-kernel
    jnp oracles, every intermediate materialized in HBM.  It is kept both as
    the fallback for backends without Pallas support and as the equivalence
    oracle the fused path is tested against.

The domain is doubly periodic in (y, x) — the standard dycore test setup —
so the distributed version (weather/domain.py) only needs circular halo
exchanges.  Periodic variants of the kernels are expressed with jnp.roll on
top of the validated interior kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dycore_fused import ops as fused_ops
from repro.kernels.dycore_fused.ref import pad_periodic
from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import PROGNOSTIC, WeatherState

HALO = 2   # hdiff needs 2; vadvc needs 1 (staggered wcon)


def hdiff_periodic(src: jnp.ndarray, coeff: float) -> jnp.ndarray:
    """Periodic compound horizontal diffusion of a (..., nz, ny, nx) field."""
    ny, nx = src.shape[-2:]
    flat = src.reshape((-1,) + src.shape[-3:])

    def one(f):
        padded = pad_periodic(f, HALO)
        out = hdiff_ref.hdiff(padded, coeff=coeff)
        return out[:, HALO:HALO + ny, HALO:HALO + nx]

    return jax.vmap(one)(flat).reshape(src.shape)


def vadvc_field(u_stage, wcon, u_pos, utens, utens_stage):
    """vadvc over a (..., nz, ny, nx) field.  `wcon` is (..., nz, ny, nx)
    and is wrap-padded to the staggered (nx+1) extent (periodic domain)."""
    shape = u_stage.shape
    wcon_s = jnp.concatenate([wcon, wcon[..., :1]], axis=-1)
    flat = lambda a: a.reshape((-1,) + a.shape[-3:])
    out = jax.vmap(vadvc_ref.vadvc)(flat(u_stage), flat(wcon_s), flat(u_pos),
                                    flat(utens), flat(utens_stage))
    return out.reshape(shape)


def _auto_interpret() -> bool:
    """Pallas runs natively on TPU, in interpreter mode everywhere else."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "fused",
                                             "interpret"))
def dycore_step(state: WeatherState, coeff: float = 0.025,
                dt: float = 0.1, fused: bool = True,
                interpret: bool | None = None) -> WeatherState:
    """One large-timestep: vertical-implicit advection per field, explicit
    point-wise update, horizontal diffusion smoothing.

    `fused=True` routes each field through the single-pass Pallas pipeline;
    `fused=False` is the unfused oracle composition (identical math, every
    intermediate round-tripping HBM)."""
    new_fields, new_stage = {}, {}
    if fused:
        if interpret is None:
            interpret = _auto_interpret()
        for name in PROGNOSTIC:
            f_new, stage = fused_ops.fused_step(
                state.fields[name], state.wcon, state.tens[name],
                state.stage_tens[name], coeff=coeff, dt=dt,
                interpret=interpret)
            new_fields[name] = f_new
            new_stage[name] = stage
    else:
        for name in PROGNOSTIC:
            f = state.fields[name]
            # 1) tridiagonal vertical solve -> updated stage tendency
            stage = vadvc_field(u_stage=f, wcon=state.wcon, u_pos=f,
                                utens=state.tens[name],
                                utens_stage=state.stage_tens[name])
            # 2) point-wise explicit update
            f = f + dt * stage
            # 3) compound horizontal diffusion
            f = hdiff_periodic(f, coeff)
            new_fields[name] = f
            new_stage[name] = stage
    return WeatherState(fields=new_fields, wcon=state.wcon,
                        tens=state.tens, stage_tens=new_stage)


def run(state: WeatherState, steps: int, coeff: float = 0.025,
        dt: float = 0.1, fused: bool = True) -> WeatherState:
    def body(s, _):
        return dycore_step(s, coeff=coeff, dt=dt, fused=fused), ()

    final, _ = jax.lax.scan(body, state, (), length=steps)
    return final
