"""Unfused oracle for the compound dycore field step (the fusion baseline).

One field step is the composition the weather dycore applies per prognostic
field (weather/dycore.py): implicit vertical advection (Thomas solve) ->
point-wise explicit update -> periodic compound horizontal diffusion.  This
module expresses that composition with the *validated* per-kernel oracles
(vadvc ref, hdiff ref) and full HBM round-trips between stages — exactly the
baseline NERO measures against (arxiv 2107.08716 §3: on the CPU/GPU baseline
"intermediate results are stored in main memory" between kernels).

The fused Pallas kernel (fused.py) must match this bit-for-bit up to fp32
rounding; it is the equivalence oracle for every dycore_fused test.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref

DEFAULT_COEFF = hdiff_ref.DEFAULT_COEFF
DEFAULT_DT = 0.1
HALO = 2   # hdiff halo depth; the fused kernel's in-kernel y/x halo


def pad_periodic(f: jnp.ndarray, halo: int = HALO) -> jnp.ndarray:
    """Wrap-pad the two horizontal axes (..., ny, nx) by `halo`."""
    f = jnp.concatenate([f[..., -halo:, :], f, f[..., :halo, :]], axis=-2)
    f = jnp.concatenate([f[..., :, -halo:], f, f[..., :, :halo]], axis=-1)
    return f


def fused_step_ref(f: jnp.ndarray, wcon: jnp.ndarray, utens: jnp.ndarray,
                   utens_stage: jnp.ndarray, coeff: float = DEFAULT_COEFF,
                   dt: float = DEFAULT_DT):
    """One dycore field step, unfused.  All inputs (nz, ny, nx); the domain
    is doubly periodic in (y, x); wcon is the *unstaggered* field (the
    i+1-staggered neighbor is the periodic next column).

    Returns (f_new, stage) — the diffused updated field and the vadvc-updated
    stage tendency, both shaped/typed like `f`.
    """
    ny, nx = f.shape[-2:]
    # 1) tridiagonal vertical solve (u_pos == u_stage == f in the dycore).
    wcon_s = jnp.concatenate([wcon, wcon[..., :1]], axis=-1)
    stage = vadvc_ref.vadvc(f, wcon_s, f, utens, utens_stage)
    # 2) point-wise explicit update.
    f2 = f + dt * stage
    # 3) periodic compound horizontal diffusion (pad -> interior -> crop).
    padded = pad_periodic(f2, HALO)
    out = hdiff_ref.hdiff(padded, coeff=coeff)
    f_new = out[..., HALO:HALO + ny, HALO:HALO + nx]
    return f_new, stage


def limiter_fragile_mask(f2: jnp.ndarray, noise: float = 1e-5) -> jnp.ndarray:
    """Points whose COSMO flux-limiter branch decision sits within fp32
    noise of flipping.

    The limiter zeroes a flux when `flux * Δf > 0`.  That comparison is
    discontinuous: when the product is within rounding noise of zero (e.g.
    Δf == ±0.0 at a local plateau), two numerically equivalent evaluation
    orders of the *same* scheme — fused vs unfused — may take different
    branches and legitimately differ by O(coeff·|flux|) at that point.  The
    equivalence tests use this mask to separate those measure-zero branch
    flips from real defects: outside the mask the paths must agree to 1e-5;
    inside it only a loose physical bound applies.

    `f2` is the point-wise-updated field the hdiff stage consumes
    (f + dt·stage), any shape (..., ny, nx), periodic in (y, x).
    """
    a = f2.astype(jnp.float32)

    def sh(v, dj, di):   # value at (j+dj, i+di), periodic
        return jnp.roll(jnp.roll(v, -dj, axis=-2), -di, axis=-1)

    lap = (sh(a, 0, -1) + sh(a, 0, 1) + sh(a, -1, 0) + sh(a, 1, 0)) - 4.0 * a
    pairs = [
        (sh(lap, 0, 1) - lap, sh(a, 0, 1) - a),      # flx
        (lap - sh(lap, 0, -1), a - sh(a, 0, -1)),    # flx_m
        (sh(lap, 1, 0) - lap, sh(a, 1, 0) - a),      # fly
        (lap - sh(lap, -1, 0), a - sh(a, -1, 0)),    # fly_m
    ]
    fragile = jnp.zeros(a.shape, bool)
    for flux, df in pairs:
        tol = noise * (jnp.abs(flux) + jnp.abs(df)) + 1e-12
        fragile |= jnp.abs(flux * df) <= tol
    return fragile


def fused_step_ref_batched(f, wcon, utens, utens_stage,
                           coeff: float = DEFAULT_COEFF,
                           dt: float = DEFAULT_DT):
    """`fused_step_ref` over arbitrary leading batch dims (..., nz, ny, nx)."""
    shape = f.shape
    if len(shape) == 3:
        return fused_step_ref(f, wcon, utens, utens_stage, coeff, dt)
    flat = lambda a: a.reshape((-1,) + a.shape[-3:])
    step = lambda ff, ww, tt, ss: fused_step_ref(ff, ww, tt, ss, coeff, dt)
    f_new, stage = jax.vmap(step)(flat(f), flat(wcon), flat(utens),
                                  flat(utens_stage))
    return f_new.reshape(shape), stage.reshape(shape)
