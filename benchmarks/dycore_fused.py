"""Fused vs unfused dycore step — the NERO fusion claim, measured + modeled.

Paper §3 (arxiv 2107.08716): the CPU/GPU baseline round-trips every
intermediate through main memory; the in-fabric pipeline streams each field
once.  This benchmark reports that claim three ways for one full dycore step
(4 prognostic fields):

  * measured wall-clock of `dycore_step` on its three paths — unfused
    oracle, per-field fused (4 Pallas launches), whole-state fused (ONE
    launch, shared staggered-velocity slab).  (CPU note: without a TPU the
    fused kernels run in the Pallas *interpreter*, so their wall-clock here
    validates the pipelines, it does not demonstrate the speedup — the
    modeled rows do);
  * modeled HBM traffic per step from core/memmodel.dycore_step_traffic
    (array-level reads/writes each pipeline materializes), with the fused
    y-window halo re-read overhead from the auto-tuned TilePlan;
  * modeled TPU time/energy for the fused plan from core/perfmodel, and the
    k-step communication-avoiding exchange model
    (core/memmodel.kstep_exchange_model).

Emitted metric names (docs/benchmarks.md):
  dycore_fused/walltime_{unfused,fused,whole_state}  us per step (measured)
  dycore_fused/traffic_{unfused,fused,whole_state}_* modeled MB per step
  dycore_fused/model_{fused}                         modeled TPU time
  dycore_fused/kstep_k<k>                            k-step exchange model

Also writes BENCH_dycore.json (walltime, modeled HBM bytes, steps/s) for
cross-PR perf tracking.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, smoke_mode, time_fn, write_json
from repro.core import hierarchy as hw
from repro.core import memmodel, perfmodel, tiling, trace_stats
from repro.kernels.dycore_fused import ops as fused_ops
from repro.weather import dycore, fields

# Measured grid: deliberately small.  The Pallas interpreter's grid loop
# carries the full output state per iteration (O(grid_steps x state) copy
# overhead that real hardware does not have), which at large grids swamps —
# and inverts — the launch-amortization effect the whole-state step
# targets.  At this size the per-`pallas_call` dispatch cost is the visible
# term, which is exactly the 4-launches-vs-1 comparison; HBM-traffic
# effects are covered by the modeled rows at the paper's domain.
GRID = (4, 16, 16)
ENSEMBLE = 1
MODEL_GRID = (64, 256, 256)  # the paper's domain, for the modeled rows
SMOKE_GRID = (4, 16, 16)     # CI smoke job (tiny, interpret mode)
KSTEP_K = 2                  # depth of the measured/traced k-step round


# Structural counts of the distributed k-step round need >1 shard per mesh
# axis, so they are traced in a subprocess with forced host devices (same
# trick as tests/test_weather.py) and read back as JSON.
_STRUCT_SNIPPET = r"""
import json, jax
from repro.core import trace_stats
from repro.weather import domain, fields
st = fields.initial_state(jax.random.PRNGKey(0), (4, 16, 16), ensemble=1)
kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
      if hasattr(jax.sharding, "AxisType") else {})
mesh = jax.make_mesh((2, 2), ("data", "model"), **kw)
step, _ = domain.make_distributed_step(mesh, k_steps=%d)
j = jax.make_jaxpr(step)(st)
print("STRUCT=" + json.dumps(trace_stats.launch_and_collective_counts(j)))
"""


def _kstep_round_structure(k: int) -> dict:
    """Trace the distributed k-step round on a forced 4-device CPU mesh and
    return {"pallas_call": ..., "ppermute": ...} per round."""
    env = {k_: v for k_, v in os.environ.items() if k_ != "XLA_FLAGS"}
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _STRUCT_SNIPPET % k], env=env,
                       capture_output=True, text=True, timeout=600)
    for line in r.stdout.splitlines():
        if line.startswith("STRUCT="):
            return json.loads(line[len("STRUCT="):])
    raise RuntimeError(f"k-step structure trace failed: {r.stderr[-2000:]}")


def run():
    smoke = smoke_mode()
    grid = SMOKE_GRID if smoke else GRID
    iters, warmup = (1, 1) if smoke else (7, 2)
    st = fields.initial_state(jax.random.PRNGKey(0), grid,
                              ensemble=ENSEMBLE)
    n_fields = len(fields.PROGNOSTIC)
    backend = jax.default_backend()
    interp_note = ("" if backend == "tpu"
                   else " (Pallas interpreter — validates, not representative)")

    walltime = {}
    t_unfused = time_fn(lambda s: dycore.dycore_step(s, fused=False), st,
                        iters=iters, warmup=warmup)
    walltime["unfused"] = t_unfused
    emit("dycore_fused/walltime_unfused", t_unfused,
         f"grid={grid} ensemble={ENSEMBLE}")
    t_fused = time_fn(
        lambda s: dycore.dycore_step(s, fused=True, whole_state=False), st,
        iters=iters, warmup=warmup)
    walltime["fused_per_field"] = t_fused
    emit("dycore_fused/walltime_fused", t_fused,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 4 launches{interp_note}")
    t_whole = time_fn(
        lambda s: dycore.dycore_step(s, fused=True, whole_state=True), st,
        iters=iters, warmup=warmup)
    walltime["fused_whole_state"] = t_whole
    emit("dycore_fused/walltime_whole_state", t_whole,
         f"grid={grid} ensemble={ENSEMBLE} backend={backend}"
         f" 1 launch, shared w{interp_note} "
         f"vs_per_field={t_fused / max(t_whole, 1e-9):.2f}x")
    # The k-step round: KSTEP_K timesteps in ONE launch (in-kernel scan,
    # state in VMEM between local steps) vs KSTEP_K whole-state launches.
    t_kstep = time_fn(
        lambda s: dycore.run(s, steps=KSTEP_K, k_steps=KSTEP_K), st,
        iters=iters, warmup=warmup)
    t_kseq = time_fn(
        lambda s: dycore.run(s, steps=KSTEP_K), st,
        iters=iters, warmup=warmup)
    walltime["kstep_round"] = t_kstep
    walltime["kstep_scan_of_launches"] = t_kseq
    emit("dycore_fused/walltime_kstep", t_kstep,
         f"grid={grid} k={KSTEP_K} backend={backend} 1 launch/round"
         f"{interp_note} vs_scan={t_kseq / max(t_kstep, 1e-9):.2f}x")

    # Modeled HBM traffic at the paper's domain, auto-tuned fused window.
    model_grid = grid if smoke else MODEL_GRID
    traffic = {}
    for dtype in ("float32", "bfloat16"):
        ty = fused_ops.plan_tile(model_grid, jnp.dtype(dtype))
        t = memmodel.dycore_step_traffic(model_grid, dtype,
                                         n_fields=n_fields, ty=ty,
                                         k_steps=KSTEP_K)
        traffic[dtype] = {
            "unfused": t["unfused"]["total"],
            "fused_per_field": t["fused"]["total"],
            "fused_whole_state": t["fused_whole"]["total"],
            "fused_kstep": t["fused_kstep"]["total"],
            "fused_kstep_scan": t["fused_kstep"]["scan_total"],
            "interstep_state": t["fused_kstep"]["interstep_state"],
            "interstep_state_scan": t["fused_kstep"]["interstep_state_scan"],
            "reduction_x_whole": t["reduction_x_whole"],
            "interstep_reduction_x": t["interstep_reduction_x"],
        }
        mb = 1.0 / 2**20
        emit(f"dycore_fused/traffic_unfused_{dtype}", 0.0,
             f"MB={t['unfused']['total'] * mb:.0f} "
             f"vadvc={t['unfused']['vadvc'] * mb:.0f} "
             f"pointwise={t['unfused']['pointwise'] * mb:.0f} "
             f"hdiff={(t['unfused']['hdiff'] + t['unfused']['hdiff_pad']) * mb:.0f}")
        emit(f"dycore_fused/traffic_fused_{dtype}", 0.0,
             f"MB={t['fused']['total'] * mb:.0f} ty={ty} "
             f"halo_overhead={t['halo_overhead'] * 100:.1f}% "
             f"reduction={t['reduction_x']:.2f}x "
             f"(aliased-window pessimistic bound: "
             f"MB={t['fused']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_window_reads']:.2f}x)")
        emit(f"dycore_fused/traffic_whole_state_{dtype}", 0.0,
             f"MB={t['fused_whole']['total'] * mb:.0f} ty={ty} "
             f"reduction={t['reduction_x_whole']:.2f}x "
             f"vs_per_field="
             f"{t['fused']['total'] / max(t['fused_whole']['total'], 1):.3f}x "
             f"(pessimistic bound: "
             f"MB={t['fused_whole']['stream_window_reads'] * mb:.0f}, "
             f"{t['reduction_x_whole_window_reads']:.2f}x)")
        emit(f"dycore_fused/traffic_kstep_{dtype}", 0.0,
             f"MB={t['fused_kstep']['total'] * mb:.0f}/round k={KSTEP_K} "
             f"vs_scan={t['reduction_x_kstep_vs_scan']:.2f}x "
             f"interstep_state_MB={t['fused_kstep']['interstep_state'] * mb:.0f}"
             f" vs {t['fused_kstep']['interstep_state_scan'] * mb:.0f} "
             f"({t['interstep_reduction_x']:.0f}x fewer HBM state "
             f"round-trips)")

        # Modeled TPU time for the fused plan (per field pipeline pass).
        plan = tiling.TilePlan(op=tiling.DYCORE_FUSED, grid_shape=model_grid,
                               tile=(model_grid[0], ty, model_grid[2]),
                               dtype=dtype)
        est = perfmodel.estimate(plan)
        emit(f"dycore_fused/model_fused_{dtype}",
             est.time_s * n_fields * 1e6,
             f"bottleneck={est.bottleneck} gflops={est.gflops:.0f} "
             f"vmem={100.0 * plan.vmem_bytes / hw.tpu_v5e().vmem.capacity_bytes:.0f}%")

    # Communication-avoiding k-step exchange model (weather/domain.py).
    kstep = {}
    for k in (1, 2, 4):
        try:
            m = memmodel.kstep_exchange_model(model_grid, "float32",
                                              n_fields=n_fields, k=k)
        except ValueError:
            continue
        kstep[str(k)] = m
        emit(f"dycore_fused/kstep_k{k}", 0.0,
             f"rounds={m['rounds_kstep']}v{m['rounds_sequential']} "
             f"bytes_ratio={m['bytes_ratio']:.2f} "
             f"redundant_flops={m['redundant_flops_frac'] * 100:.0f}%")

    # Structural counts of the k-step round — the regression guard that is
    # immune to interpreter-walltime noise: the single-chip round must be
    # ONE pallas_call; the distributed round additionally one ppermute pair
    # per mesh direction (traced on a forced 4-device mesh in a subprocess).
    st_small = fields.initial_state(jax.random.PRNGKey(0), SMOKE_GRID)
    j = jax.make_jaxpr(
        lambda s: dycore.run(s, steps=KSTEP_K, k_steps=KSTEP_K,
                             interpret=True))(st_small)
    calls_local = trace_stats.count_primitive(j, "pallas_call")
    try:
        struct = _kstep_round_structure(KSTEP_K)
    except (RuntimeError, subprocess.SubprocessError) as e:
        print(f"# distributed structure trace unavailable: {e}")
        struct = {"pallas_call": calls_local, "ppermute": None}
    calls_round = max(calls_local, struct["pallas_call"])
    emit("dycore_fused/kstep_structure", 0.0,
         f"pallas_calls_per_round={calls_round} "
         f"collectives_per_round={struct['ppermute']} k={KSTEP_K}")

    write_json("BENCH_dycore.json", {
        "grid": list(grid),
        "model_grid": list(model_grid),
        "ensemble": ENSEMBLE,
        "n_fields": n_fields,
        "k_steps": KSTEP_K,
        "pallas_calls_per_round": calls_round,
        "collectives_per_round": struct["ppermute"],
        "walltime_us": walltime,
        # steps_per_s counts SIMULATED timesteps: the kstep entries' walltime
        # covers a whole KSTEP_K-step round, the others a single step.
        "steps_per_s": {
            k: (KSTEP_K if k.startswith("kstep") else 1) * 1e6
            / max(v, 1e-9) for k, v in walltime.items()},
        "modeled_hbm_bytes": traffic,
        "kstep_exchange": kstep,
    })

    if calls_round > 1:
        # Structural regression: the k-step round fragmented into multiple
        # launches.  Fail the bench (and the CI smoke job) loudly.
        raise SystemExit(
            f"k-step structural regression: {calls_round} pallas_calls per "
            f"round (expected 1)")


if __name__ == "__main__":
    run()
