"""Architecture registry: --arch <id> -> ModelConfig, plus reduced configs
for CPU smoke tests (full configs are exercised only via the dry-run)."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (EncDecConfig, ModelConfig, MoEConfig,
                                RecurrentConfig, SSDConfig, SHAPES,
                                ShapeConfig)

from repro.configs.yi_34b import CONFIG as _yi
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.granite_moe_3b import CONFIG as _granite
from repro.configs.moonshot_v1_16b import CONFIG as _moonshot
from repro.configs.recurrentgemma_9b import CONFIG as _rgemma
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2vl

REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in (
    _yi, _olmo, _tinyllama, _gemma3, _granite, _moonshot, _rgemma,
    _whisper, _mamba2, _qwen2vl)}

ARCH_IDS = tuple(sorted(REGISTRY))


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def skips(cfg: ModelConfig, shape_name: str) -> str | None:
    for s, why in cfg.skip_shapes:
        if s == shape_name:
            return why
    return None


def reduced_config(cfg: ModelConfig, layers: int = 0) -> ModelConfig:
    """Small same-family config for CPU smoke tests: few layers (at least
    one full pattern period + remainder), narrow width, tiny vocab/experts."""
    period = len(cfg.pattern)
    n_layers = layers or (period + min(period, 2))
    d_model = 64
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads > 1 else 1
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=max(1, min(n_kv, 2)), head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        window=16,
    )
    if cfg.mrope_sections:
        kw["mrope_sections"] = (2, 3, 3)     # sums to head_dim/2 = 8
    if cfg.moe:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, router_chunk=64)
    if cfg.ssd:
        kw["ssd"] = SSDConfig(d_state=16, head_dim=16, expand=2, chunk=16,
                              conv_width=4, n_groups=1)
    if cfg.rec:
        kw["rec"] = RecurrentConfig(rnn_width=64, conv_width=4)
    if cfg.encdec:
        kw["encdec"] = EncDecConfig(encoder_layers=2, encoder_len=32)
        kw["n_layers"] = 2
    return dataclasses.replace(cfg, **kw)
