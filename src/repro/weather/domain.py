"""Distributed dycore primitives: halo exchange + sharding utilities.

This is NERO's scale-out story made real (paper §5: "HBM provides an
attractive solution for scale-out computation" with one memory channel per
PE): every chip owns an (ny/Py, nx/Px) slab of the horizontal domain in its
own HBM; the compound stencils run chip-locally out of VMEM; the only
communication is a circular halo exchange (`jax.lax.ppermute` over the mesh
axes).  Vertical columns are never split (vadvc's z dependency), matching
the paper's PE design.

The strategy that *uses* these primitives — which variant runs chip-locally,
how deep each operand's halo is, what rides the wire at which dtype — is
resolved by the plan API (`weather/program.py::compile_dycore`); the
distributed lowering there composes:

* `_exchange` — per-operand circular exchange (the per-field paths);
* `_exchange_packed` — the stacked RAGGED exchange: several tensors with
  PER-TENSOR (and per-SIDE) halo depths share one flattened wire buffer
  per direction, so the collective count stays one `ppermute` pair per
  mesh direction per round no matter how many operands ride or how ragged
  their depths are.  `wcon` ships its `+1` staggering x-column to the
  RIGHT side only (`w[c] = wcon[c] + wcon[c+1]` needs the right neighbor,
  never the left — the left pad's extra column was provably unread);
* `_staggered_w` / `_right_column` — the x-staggered velocity build;
* `_local_hdiff` / `_local_vadvc` — exchanged per-kernel local stencils
  (the unfused oracle's distributed form);
* `shard_state` — placing a `WeatherState` onto the mesh.

`make_distributed_step(...)` is the LEGACY flag-soup entry point, kept as a
thin deprecated shim over `compile_dycore` (bit-identical results) so the
historical equivalence tests keep their meaning.  Ensemble members ride the
"pod" axis of the multi-pod mesh — see docs/architecture.md ("Scale-out:
domain decomposition and ensemble pods").
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.hdiff import ref as hdiff_ref
from repro.kernels.vadvc import ref as vadvc_ref
from repro.weather.fields import WeatherState
from repro.weather.dycore import HALO


def _exchange(f: jnp.ndarray, axis_name: str, n: int, halo: int,
              dim: int) -> jnp.ndarray:
    """Circular halo exchange along `dim` over mesh axis `axis_name`.

    Returns f extended by `halo` on both sides of `dim`.  With n == 1 this
    degenerates to periodic wrap-padding (no communication).  `halo` must
    not exceed the local extent (a deeper exchange would need neighbors-of-
    neighbors data — callers check and raise)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    lo = take(f, slice(0, halo))          # my first rows -> neighbor below
    hi = take(f, slice(-halo, None))      # my last rows  -> neighbor above
    if n == 1:
        top, bot = hi, lo
    else:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = jax.lax.ppermute(hi, axis_name, perm=fwd)   # from rank-1
        bot = jax.lax.ppermute(lo, axis_name, perm=bwd)   # from rank+1
    return jnp.concatenate([top, f, bot], axis=dim)


def _exchange_packed(parts, axis_name: str, n: int, dim: int,
                     wire_dtype=None):
    """Circular halo exchange along `dim` for several tensors with
    PER-TENSOR — and per-SIDE — halo depths, packed into one flattened
    wire buffer per direction: exactly one `ppermute` pair regardless of
    operand count or depth raggedness.

    `parts` is a sequence of `(tensor, depth)` where `depth` is either an
    int (symmetric) or a `(lo_depth, hi_depth)` pair: the tensor comes
    back extended by `lo_depth` on the LOW side of `dim` (received from
    the lower-index neighbor) and `hi_depth` on the HIGH side (received
    from the upper-index neighbor).  This is how `wcon` ships its extra
    staggering column to the right side ONLY — `(k·HALO, k·HALO + 1)` —
    without forcing the whole stacked exchange one column deeper, and
    without wasting a never-read column on the left pad.

    `wire_dtype` (e.g. bf16) casts the packed buffer before the `ppermute`
    pair and restores each tensor's dtype on arrival — half the wire
    bytes, rounding confined to the received halo ring.

    With n == 1 this degenerates to periodic wrap-padding (no
    communication, no cast)."""
    def take(a, sl):
        idx = [slice(None)] * a.ndim
        idx[dim] = sl
        return a[tuple(idx)]

    depths = []
    for _, h in parts:
        lo_h, hi_h = (h, h) if isinstance(h, int) else h
        if lo_h < 1 or hi_h < 1:
            raise ValueError(f"packed-exchange depth {h!r} must be >= 1 "
                             f"on both sides")
        depths.append((lo_h, hi_h))
    # The LOW pad is the lower neighbor's LAST lo_h rows (forward ride);
    # the HIGH pad is the upper neighbor's FIRST hi_h rows (backward ride).
    hi_parts = [take(t, slice(-lo_h, None))
                for (t, _), (lo_h, _) in zip(parts, depths)]
    lo_parts = [take(t, slice(0, hi_h))
                for (t, _), (_, hi_h) in zip(parts, depths)]
    if n == 1:
        top, bot = hi_parts, lo_parts
    else:
        def pack(xs):
            buf = jnp.concatenate([x.reshape(-1) for x in xs])
            return buf.astype(wire_dtype) if wire_dtype is not None else buf

        def unpack(buf, like):
            out, off = [], 0
            for x in like:
                seg = buf[off:off + x.size]
                out.append(seg.reshape(x.shape).astype(x.dtype))
                off += x.size
            return out

        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        top = unpack(jax.lax.ppermute(pack(hi_parts), axis_name, perm=fwd),
                     hi_parts)
        bot = unpack(jax.lax.ppermute(pack(lo_parts), axis_name, perm=bwd),
                     lo_parts)
    return [jnp.concatenate([t_, t, b_], axis=dim)
            for (t, _), t_, b_ in zip(parts, top, bot)]


def _right_column(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """The x-staggered neighbor of the slab's last column: the x-neighbor
    shard's first column (periodic 1-column exchange)."""
    if nx_shards == 1:
        return wcon[..., :1]
    bwd = [(i, (i - 1) % nx_shards) for i in range(nx_shards)]
    return jax.lax.ppermute(wcon[..., :1], ax_x, perm=bwd)


def _staggered_w(wcon: jnp.ndarray, ax_x: str, nx_shards: int) -> jnp.ndarray:
    """w = wcon_i + wcon_{i+1} on the local slab (see _right_column)."""
    right = _right_column(wcon, ax_x, nx_shards)
    return wcon + jnp.concatenate([wcon[..., 1:], right], axis=-1)


def _local_hdiff(f: jnp.ndarray, coeff: float, ax_y: str, ax_x: str,
                 ny_shards: int, nx_shards: int) -> jnp.ndarray:
    """f: (E, nz, ly, lx) local slab -> diffused slab."""
    e, nz, ly, lx = f.shape
    g = _exchange(f, ax_y, ny_shards, HALO, dim=2)
    g = _exchange(g, ax_x, nx_shards, HALO, dim=3)
    out = hdiff_ref.hdiff(g.reshape(e * nz, ly + 2 * HALO, lx + 2 * HALO),
                          coeff=coeff)
    out = out.reshape(e, nz, ly + 2 * HALO, lx + 2 * HALO)
    return out[:, :, HALO:HALO + ly, HALO:HALO + lx]


def _local_vadvc(u_stage, wcon, u_pos, utens, utens_stage, ax_x, nx_shards):
    """All (E, nz, ly, lx); staggered wcon column fetched from x-neighbor."""
    wcon_s = jnp.concatenate(
        [wcon, _right_column(wcon, ax_x, nx_shards)], axis=-1)
    # vmap over ensemble; fields already (nz, ly, lx) per member.
    out = jax.vmap(vadvc_ref.vadvc)(u_stage, wcon_s, u_pos, utens,
                                    utens_stage)
    return out


def make_distributed_step(mesh: Mesh, *, coeff: float = 0.025,
                          dt: float = 0.1, ax_e: str | None = "pod",
                          ax_y: str = "data", ax_x: str = "model",
                          fused: bool = True, whole_state: bool = True,
                          k_steps: int | str = 1,
                          exchange_dtype=None,
                          prefetch_w: bool | None = None,
                          interpret: bool | None = None):
    """DEPRECATED shim: build the distributed dycore step from flags.

    The flags map onto a `DycoreProgram` + `compile_dycore(..., mesh=mesh)`
    on the first call (the grid is only known from the state), cached per
    (grid, dtype); results are bit-identical to the equivalent plan's
    `step`.  The returned `step` advances `k_steps` timesteps per call and
    exposes `step.resolved_k()` (the planner's k after a `k_steps="auto"`
    resolution).  New code should call `compile_dycore` directly — the
    plan also exposes `run` (ragged tails allowed) and `report`."""
    warnings.warn(
        "weather.domain.make_distributed_step(fused=..., whole_state=..., "
        "...) is deprecated: build a DycoreProgram and call "
        "repro.weather.program.compile_dycore(program, mesh=mesh) — the "
        "ExecutionPlan resolves variant/tile/k-step/exchange once and "
        "exposes step()/run()/report().", DeprecationWarning, stacklevel=2)
    from repro.weather.program import DycoreProgram, compile_dycore

    auto_k = k_steps == "auto"
    if not auto_k and (not isinstance(k_steps, int) or k_steps < 1):
        raise ValueError(f"k_steps={k_steps!r} must be a positive int "
                         f"or 'auto'")
    if (auto_k or k_steps > 1) and not (fused and whole_state):
        raise ValueError("k_steps > 1 requires the fused whole-state path")
    if exchange_dtype is not None and not (fused and whole_state):
        raise ValueError("exchange_dtype requires the stacked (whole-state) "
                         "exchange path")
    have_e = ax_e is not None and ax_e in mesh.axis_names
    spec = P(ax_e if have_e else None, None, ax_y, ax_x)
    if fused and whole_state:
        variant, k = "auto", k_steps
    elif fused:
        variant, k = "per_field", 1
    else:
        variant, k = "unfused", 1

    cache: dict = {}
    last_key: list = []

    def step(state: WeatherState) -> WeatherState:
        ensemble = (int(state.wcon.shape[0]) if state.wcon.ndim == 4
                    else 1)
        key = (state.grid_shape, str(state.wcon.dtype), ensemble)
        if key not in cache:
            prog = DycoreProgram(
                grid_shape=state.grid_shape, ensemble=ensemble,
                dtype=str(state.wcon.dtype), coeff=coeff, dt=dt,
                variant=variant, k_steps=k, exchange_dtype=exchange_dtype)
            cache[key] = compile_dycore(prog, mesh=mesh, ax_e=ax_e,
                                        ax_y=ax_y, ax_x=ax_x,
                                        interpret=interpret,
                                        prefetch_w=prefetch_w)
        last_key[:] = [key]
        return cache[key].step(state)

    step.resolved_k = lambda: (cache[last_key[0]].k_steps if last_key
                               else None)
    return step, spec


def shard_state(state: WeatherState, mesh: Mesh, spec: P) -> WeatherState:
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda a: jax.device_put(a, sharding), state)
