"""hdiff Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip(   # degrade, don't error, without the dev extra
    "hypothesis", reason="needs hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels.hdiff import ref
from repro.kernels.hdiff.hdiff import hdiff_pallas
from repro.kernels.hdiff.ops import hdiff as hdiff_op

SHAPES = [(1, 8, 8), (4, 8, 16), (8, 16, 32), (3, 32, 8), (2, 64, 64)]
TILES = {8: [2, 4, 8], 16: [4, 8], 32: [8, 16], 64: [8, 32]}


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pallas_matches_ref(shape, dtype, rng):
    src = rng.normal(size=shape).astype(np.float32)
    src = jnp.asarray(src, dtype)
    want = np.asarray(ref.hdiff(src), np.float32)
    for ty in TILES[shape[1]]:
        got = np.asarray(hdiff_pallas(src, ty=ty, interpret=True),
                         np.float32)
        atol = 1e-5 if dtype == np.float32 else 0.15
        np.testing.assert_allclose(got, want, atol=atol,
                                   err_msg=f"ty={ty} shape={shape}")


def test_ops_dispatch(rng):
    src = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32))
    a = np.asarray(hdiff_op(src, use_pallas=False))
    b = np.asarray(hdiff_op(src, use_pallas=True, ty=4))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_boundary_ring_passthrough(rng):
    src = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32))
    out = np.asarray(ref.hdiff(src))
    s = np.asarray(src)
    assert np.array_equal(out[:, :2, :], s[:, :2, :])
    assert np.array_equal(out[:, -2:, :], s[:, -2:, :])
    assert np.array_equal(out[:, :, :2], s[:, :, :2])
    assert np.array_equal(out[:, :, -2:], s[:, :, -2:])


def test_constant_field_is_fixed_point():
    src = jnp.full((3, 16, 16), 3.25, jnp.float32)
    out = np.asarray(ref.hdiff(src))
    np.testing.assert_allclose(out, 3.25, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.005, 0.031))
def test_limiter_bounds_output(seed, coeff):
    """With the flux limiter, diffusion must not amplify the field range —
    within the explicit-step stability region coeff < 1/32 (above it the
    scheme amplifies by von-Neumann analysis, limiter or not)."""
    r = np.random.default_rng(seed)
    src = jnp.asarray(r.normal(size=(2, 12, 12)).astype(np.float32))
    out = np.asarray(ref.hdiff(src, coeff=coeff))
    s = np.asarray(src)
    # interior values remain bounded by a modest expansion of input range
    span = s.max() - s.min()
    assert out.max() <= s.max() + 0.5 * span + 1e-5
    assert out.min() >= s.min() - 0.5 * span + -1e-5


def test_linearity_of_unlimited_variant(rng):
    a = jnp.asarray(rng.normal(size=(2, 12, 12)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 12, 12)).astype(np.float32))
    lhs = np.asarray(ref.hdiff_simple(a + b))
    rhs = np.asarray(ref.hdiff_simple(a)) + np.asarray(ref.hdiff_simple(b))
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)
