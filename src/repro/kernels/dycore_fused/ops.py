"""Jitted public entry points for the fused dycore step (planner-aware).

`fused_step(...)` is what the weather dycore calls per prognostic field: it
builds the pre-combined staggered vertical velocity, picks the auto-tuned
y-window (NERO's OpenTuner stage via core/autotune.py), and dispatches to the
Pallas compound kernel — or to the unfused oracle composition when
`use_pallas=False` (the differentiable fallback path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.kernels.dycore_fused import ref as _ref
from repro.kernels.dycore_fused.fused import fused_dycore_pallas

DEFAULT_COEFF = _ref.DEFAULT_COEFF
DEFAULT_DT = _ref.DEFAULT_DT


def snap_ty(ty: int, ny: int) -> int:
    """Largest legal y-window <= `ty`: a divisor of ny, >= 2 (falling back to
    a single whole-y window when ny has no divisor in [2, ty])."""
    ty = max(2, min(int(ty), ny))
    while ny % ty and ty > 2:
        ty -= 1
    return ty if ny % ty == 0 else ny


def plan_tile(grid_shape, dtype) -> int:
    """Auto-tuned y-window for the fused kernel (paper Fig. 6 stage)."""
    tuned = autotune.tune_named("dycore_fused", grid_shape, dtype)
    return snap_ty(tuned.plan.tile[1], grid_shape[1])


@functools.partial(jax.jit, static_argnames=("coeff", "dt", "use_pallas",
                                             "ty", "interpret"))
def fused_step(f: jnp.ndarray, wcon: jnp.ndarray, utens: jnp.ndarray,
               utens_stage: jnp.ndarray, coeff: float = DEFAULT_COEFF,
               dt: float = DEFAULT_DT, use_pallas: bool = True, ty: int = 0,
               interpret: bool = True):
    """One fused dycore field step on a doubly-periodic (..., nz, ny, nx)
    domain.  `wcon` is the unstaggered vertical velocity; the kernel's
    staggered neighbor is the periodic next x-column.  Returns
    (f_new, stage)."""
    if not use_pallas:
        return _ref.fused_step_ref_batched(f, wcon, utens, utens_stage,
                                           coeff=coeff, dt=dt)
    ny = f.shape[-2]
    ty = snap_ty(ty, ny) if ty else plan_tile(f.shape[-3:], f.dtype)
    w = wcon + jnp.roll(wcon, -1, axis=-1)   # wcon_i + wcon_{i+1}, periodic
    return fused_dycore_pallas(f, w, utens, utens_stage, coeff=coeff, dt=dt,
                               ty=ty, interpret=interpret)
