"""Paper Fig. 2b — copy-stencil bandwidth vs number of PEs.

On the FPGA each PE owns one HBM pseudo-channel (12.8 GB/s); saturation at
~16 PEs.  TPU analogue: the copy kernel's achieved bandwidth as a function
of parallel grid tiles ("PEs"), from the perf model; wall-clock column is
the measured jnp copy on this CPU, which also yields the CPU's measured
memory bandwidth for calibration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import hierarchy as hw
from repro.core import perfmodel, tiling
from repro.kernels.copy_stencil.ref import copy_stencil


def run():
    rng = np.random.default_rng(0)
    grid = (64, 256, 256)
    src = jnp.asarray(rng.normal(size=grid).astype(np.float32))
    t_us = time_fn(jax.jit(copy_stencil), src)
    nbytes = 2 * src.size * 4
    cpu_bw = nbytes / (t_us * 1e-6) / 1e9
    emit("fig2b/copy_cpu", t_us, f"cpu_bw={cpu_bw:.1f}GB/s")

    # PE scaling model: tiles processed in parallel up to HBM saturation —
    # mirrors the paper's per-channel saturation at 16 PEs.
    hier = hw.tpu_v5e()
    total_bytes = 2 * np.prod(grid) * 4
    channel_bw = hier.hbm.bandwidth_bytes_per_s / 16   # "channel" analogue
    for pes in (1, 2, 4, 8, 16, 32):
        bw = min(pes * channel_bw, hier.hbm.bandwidth_bytes_per_s)
        t = total_bytes / bw
        emit(f"fig2b/copy_model_pe{pes}", t * 1e6,
             f"model_bw={bw / 1e9:.0f}GB/s sat={'yes' if bw >= hier.hbm.bandwidth_bytes_per_s else 'no'}")


if __name__ == "__main__":
    run()
