"""Mixture-of-Experts: top-k routing with chunked GShard capacity dispatch.

TPU-idiomatic dense dispatch (one-hot einsums lower to all-to-alls under
expert parallelism) — but *chunked* over tokens so the (tokens, E, C)
dispatch tensor stays VMEM-scale: the NERO windowing discipline applied to
routing.  Capacity per chunk C = ceil(chunk·k/E · capacity_factor); overflow
tokens drop to the residual path (standard GShard semantics).

Returns the load-balancing auxiliary loss (Switch-style) alongside outputs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def moe_init(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi": jnp.stack([dense_init(k, d, f, dtype)
                         for k in jax.random.split(ks[1], e)]),
        "wo": jnp.stack([dense_init(k, f, d, dtype)
                         for k in jax.random.split(ks[2], e)]),
    }
    if cfg.gated_mlp:
        p["wg"] = jnp.stack([dense_init(k, d, f, dtype)
                             for k in jax.random.split(ks[3], e)])
    return p


def _capacity(chunk: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(chunk * m.top_k / m.n_experts * m.capacity_factor)
    return max(4, -(-c // 4) * 4)   # round up to multiple of 4


def moe_apply(cfg: ModelConfig, params, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (out, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    act = _ACTS[cfg.act]
    chunk = min(m.router_chunk, b * t)
    xt = x.reshape(b * t, d)
    n_tok = xt.shape[0]
    pad = (-n_tok) % chunk
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    nchunks = xt.shape[0] // chunk
    xc = xt.reshape(nchunks, chunk, d)
    cap = _capacity(chunk, cfg)
    e, k = m.n_experts, m.top_k

    impl = getattr(m, "impl", "onehot")

    def _route(xs):
        """Shared: router -> top-k gates + in-expert queue positions."""
        logits = (xs.astype(jnp.float32) @ params["router"])   # (chunk, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (chunk, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        # position of each (token, slot) within its expert queue
        onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (chunk,k,E)
        flat = onehot.reshape(chunk * k, e)
        pos_in_e = jnp.cumsum(flat, axis=0) - flat             # (chunk*k, E)
        pos = (pos_in_e * flat).sum(-1).reshape(chunk, k)
        keep = pos < cap
        # Switch aux loss: fraction routed vs mean prob per expert.
        me = probs.mean(axis=0)                                 # (E,)
        ce = jax.nn.one_hot(gate_idx[:, 0], e).mean(axis=0)
        aux = e * jnp.sum(me * ce)
        return gate_vals, gate_idx, pos, keep, aux

    def _experts(xe):
        """(E, cap, d) -> (E, cap, d) expert FFN."""
        h = jnp.einsum("ecd,edf->ecf", xe, params["wi"])
        if cfg.gated_mlp:
            h = act(jnp.einsum("ecd,edf->ecf", xe, params["wg"])) * h
        else:
            h = act(h)
        return jnp.einsum("ecf,efd->ecd", h, params["wo"])

    def route_onehot(xs):
        """Paper-era GShard dispatch: dense one-hot combine tensors.  The
        (chunk, k, E, cap) tensor is the HBM hot spot the roofline pass
        flags on the MoE cells — kept as the measured baseline."""
        gate_vals, gate_idx, pos, keep, aux = _route(xs)
        disp = (jax.nn.one_hot(gate_idx, e, dtype=xs.dtype)[..., None]
                * jax.nn.one_hot(pos, cap, dtype=xs.dtype)[..., None, :])
        disp = disp * keep[..., None, None].astype(xs.dtype)   # (chunk,k,E,cap)
        xe = jnp.einsum("td,tkec->ecd", xs, disp)              # (E,cap,d)
        ye = _experts(xe)
        comb = disp * gate_vals[..., None, None].astype(xs.dtype)
        y = jnp.einsum("ecd,tkec->td", ye, comb)               # (chunk,d)
        return y, aux

    def route_gather(xs):
        """Beyond-paper dispatch (§Perf): scatter slot->token indices, gather
        tokens into expert queues — O(E·cap·d + chunk·k·d) traffic instead
        of the O(chunk·k·E·cap) one-hot tensor."""
        gate_vals, gate_idx, pos, keep, aux = _route(xs)
        tok_ids = jnp.broadcast_to(jnp.arange(chunk)[:, None],
                                   (chunk, k)).astype(jnp.int32)
        # overflow slots (pos >= cap) fall out of bounds -> mode="drop"
        slot_tok = jnp.zeros((e, cap), jnp.int32).at[
            gate_idx, pos].set(tok_ids, mode="drop")
        slot_ok = jnp.zeros((e, cap), jnp.bool_).at[
            gate_idx, pos].set(True, mode="drop")
        xe = xs[slot_tok] * slot_ok[..., None].astype(xs.dtype)
        ye = _experts(xe)
        pos_c = jnp.minimum(pos, cap - 1)
        back = ye[gate_idx, pos_c]                             # (chunk,k,d)
        w = (gate_vals * keep).astype(xs.dtype)
        y = (back * w[..., None]).sum(axis=1)                  # (chunk,d)
        return y, aux

    route_one = route_gather if impl == "gather" else route_onehot
    ys, auxs = jax.lax.map(route_one, xc)
    y = ys.reshape(-1, d)[:n_tok].reshape(b, t, d)
    return y.astype(x.dtype), auxs.mean()
